"""Process-global metrics registry: named counters and gauges.

One lock-protected :class:`MetricsRegistry` per process (the
:func:`metrics` accessor), incremented from the hot paths that already
hold no other locks: trial start/finish in the executor backends, trial
cache appends, run-ledger appends, trace-event emission.  Sessions never
reset the registry — concurrent sessions share the process — instead
they take a :meth:`MetricsRegistry.snapshot` at ``tune()`` entry and
report the :meth:`MetricsRegistry.delta` against it, so back-to-back
sessions each see only their own activity (the same discipline
``ExecCacheStats.delta`` applies to the executable cache).

Counter names are dotted, lowercase, and stable once shipped:
``trials.started`` / ``trials.completed`` / ``trials.pruned`` /
``trials.cached``, ``exec_cache.hits`` / ``.misses`` / ``.compiles``,
``cache.appends`` / ``cache.bytes_written``, ``ledger.appends``,
``trace.events``.
"""

from __future__ import annotations

import threading
from typing import Optional

__all__ = ["MetricsRegistry", "metrics"]


class MetricsRegistry:
    """Thread-safe named counters and gauges."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}

    def inc(self, name: str, value: float = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + value

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = value

    def counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0)

    def snapshot(self) -> dict:
        """Point-in-time copy: ``{"counters": {...}, "gauges": {...}}``."""
        with self._lock:
            return {"counters": dict(self._counters),
                    "gauges": dict(self._gauges)}

    def delta(self, since: Optional[dict] = None) -> dict:
        """Counters advanced since ``since`` (a prior :meth:`snapshot`).

        Only counters that moved appear; gauges report their current
        value.  ``since=None`` degrades to a full snapshot.
        """
        cur = self.snapshot()
        base = (since or {}).get("counters", {})
        counters = {k: v - base.get(k, 0)
                    for k, v in cur["counters"].items()
                    if v != base.get(k, 0)}
        return {"counters": counters, "gauges": cur["gauges"]}

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()


_GLOBAL: Optional[MetricsRegistry] = None
_GLOBAL_LOCK = threading.Lock()


def metrics() -> MetricsRegistry:
    """The process-global registry (created on first use)."""
    global _GLOBAL
    reg = _GLOBAL
    if reg is None:
        with _GLOBAL_LOCK:
            reg = _GLOBAL
            if reg is None:
                reg = _GLOBAL = MetricsRegistry()
    return reg

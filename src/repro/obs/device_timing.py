"""Optional on-device timing via ``jax.profiler.trace``.

Host clock brackets (what the samplers measure) include dispatch
latency, transfer waits and scheduler jitter on top of the kernel's
device-side busy time.  :func:`profile_sample` runs one invocation
inside a profiler window, parses the ``perfetto_trace.json.gz`` the
profiler writes, sums the duration of complete events on device-side
tracks, and reports the host-vs-device skew — the first direct
measurement of what the host brackets miss.

Caveats, all by design:

- a profiled invocation is *slower* than an unprofiled one (the trace
  collector adds overhead), so the evaluator only profiles one extra
  sample per incumbent-candidate trial, never the measured samples;
- on CPU backends XLA usually emits no device tracks, so the parse
  finds nothing and the function returns ``None`` — callers degrade to
  host timing (off-GPU/TPU graceful degradation);
- overlapping device events (multi-stream) are summed, not unioned, so
  the busy time is an upper bound on wall occupancy.

Every failure path — jax missing, profiler unavailable, no trace file,
unparseable JSON, no device track — returns ``None`` rather than
raising.
"""

from __future__ import annotations

import dataclasses
import gzip
import json
import shutil
import tempfile
import time
from pathlib import Path
from typing import Callable, Optional

__all__ = ["DeviceOps", "DeviceTiming", "device_timing_available",
           "profile_ops", "profile_sample"]

# substrings that mark a profiler process/track as device-side; host
# tracks are named after python threads or "/host:CPU"
_DEVICE_MARKERS = ("/device:gpu", "/device:tpu", "gpu:", "tpu:", "stream")


@dataclasses.dataclass(frozen=True)
class DeviceTiming:
    """One profiled invocation: device busy time vs the host bracket."""

    device_time_s: float
    host_time_s: float
    skew_s: float  # host bracket minus device busy time
    n_events: int
    source: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def device_timing_available() -> bool:
    """True when jax's profiler is importable (not whether a device
    track will actually appear — that depends on the backend)."""
    try:
        import jax

        return hasattr(jax, "profiler") and hasattr(jax.profiler, "trace")
    except Exception:
        return False


@dataclasses.dataclass(frozen=True)
class DeviceOps:
    """Per-op device busy time of one profiled invocation.

    ``by_name`` keys are normalized event names (leading ``%`` and any
    ``scope/`` prefix stripped) so they join against HLO instruction
    names; overlapping events under one name are summed.
    """

    total_s: float
    by_name: dict[str, float]
    n_events: int
    source: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _looks_device(track_name: str) -> bool:
    name = track_name.lower()
    return any(marker in name for marker in _DEVICE_MARKERS)


def normalize_op_name(name: str) -> str:
    """Trace event name -> HLO instruction name (best effort): profilers
    prefix op names with module scopes (``jit_f/.../%fusion.1``)."""
    return name.rsplit("/", 1)[-1].strip().lstrip("%")


def _parse_device_ops(root: Path) -> Optional[DeviceOps]:
    candidates = sorted(root.rglob("perfetto_trace.json.gz"))
    if not candidates:
        return None
    source = candidates[-1]
    try:
        with gzip.open(source, "rt", encoding="utf-8", errors="replace") as fh:
            doc = json.load(fh)
    except Exception:
        return None
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return None
    device_pids = {
        ev.get("pid")
        for ev in events
        if isinstance(ev, dict) and ev.get("ph") == "M"
        and ev.get("name") == "process_name"
        and _looks_device(str((ev.get("args") or {}).get("name", "")))
    }
    if not device_pids:
        return None
    total_us = 0.0
    by_name: dict[str, float] = {}
    n = 0
    for ev in events:
        if (isinstance(ev, dict) and ev.get("ph") == "X"
                and ev.get("pid") in device_pids):
            dur = float(ev.get("dur", 0.0))
            total_us += dur
            key = normalize_op_name(str(ev.get("name", "")))
            if key:
                by_name[key] = by_name.get(key, 0.0) + dur * 1e-6
            n += 1
    if n == 0:
        return None
    return DeviceOps(total_s=total_us * 1e-6, by_name=by_name,
                     n_events=n, source=str(source))


def _parse_device_time(root: Path) -> Optional[tuple[float, int, str]]:
    ops = _parse_device_ops(root)
    if ops is None:
        return None
    return ops.total_s, ops.n_events, ops.source


def profile_ops(sample_fn: Callable[[], object],
                log_dir: Optional[str | Path] = None,
                ) -> Optional[DeviceOps]:
    """Run ``sample_fn`` once under the profiler; parse *per-op* device
    time. Same degradation contract as :func:`profile_sample`: every
    failure path (no jax, no trace, no device track) returns ``None``."""
    try:
        import jax
    except Exception:
        return None
    tmp = None
    try:
        if log_dir is None:
            tmp = tempfile.mkdtemp(prefix="repro-devprof-")
            log_dir = tmp
        try:
            with jax.profiler.trace(str(log_dir),
                                    create_perfetto_trace=True):
                out = sample_fn()
                jax.block_until_ready(out)
        except Exception:
            return None
        return _parse_device_ops(Path(log_dir))
    finally:
        if tmp is not None:
            shutil.rmtree(tmp, ignore_errors=True)


def profile_sample(sample_fn: Callable[[], object],
                   log_dir: Optional[str | Path] = None,
                   ) -> Optional[DeviceTiming]:
    """Run ``sample_fn`` once under the jax profiler; parse device time.

    ``log_dir=None`` uses (and removes) a temporary directory; pass a
    path to keep the raw profile for inspection.
    """
    try:
        import jax
    except Exception:
        return None
    tmp = None
    try:
        if log_dir is None:
            tmp = tempfile.mkdtemp(prefix="repro-devprof-")
            log_dir = tmp
        t0 = time.perf_counter()
        try:
            with jax.profiler.trace(str(log_dir),
                                    create_perfetto_trace=True):
                out = sample_fn()
                # drain async dispatch so the host bracket closes after
                # the device work it is compared against (skew_s)
                jax.block_until_ready(out)
        except Exception:
            return None
        host_s = time.perf_counter() - t0
        parsed = _parse_device_time(Path(log_dir))
        if parsed is None:
            return None
        device_s, n, source = parsed
        return DeviceTiming(device_time_s=device_s, host_time_s=host_s,
                            skew_s=host_s - device_s, n_events=n,
                            source=source)
    finally:
        if tmp is not None:
            shutil.rmtree(tmp, ignore_errors=True)

"""Span-based trial tracing to an append-only JSONL event log.

The span tree is ``session → shape → trial → invocation → phase``;
instant events mark incumbent improvements, CI prunes, trial-cache hits
and executable-cache hits/dedups.  Parent attribution is what
``PhaseProfiler`` cannot do: the profiler folds every worker thread into
global buckets, while the recorder keeps a **per-thread span stack**
(trial spans opened on a pool thread nest correctly under each other)
plus a cross-thread **context stack** for spans whose children are
opened on *other* threads — the session span is pushed as context by the
scheduling thread, so a trial span opened on a worker thread with an
empty local stack still parents to it.

Records are one JSON object per line, written (and flushed) at span
*end*, so children always precede their parents in the file and a torn
tail line loses at most one record:

``{"type": "span", "id": 7, "parent": 1, "name": "trial", "cat":
"trial", "ts": 0.0123, "dur": 0.0041, "tid": 1234, "thread":
"ThreadPoolExecutor-0_1", "attrs": {...}}``

``ts``/``dur`` are seconds relative to recorder start on the monotonic
clock.  ``{"type": "instant", ...}`` carries ``ts`` but no duration;
``{"type": "meta", ...}`` carries free-form metadata (one is written at
install with the trace version, another typically at session end with
the metrics snapshot).

Installing the recorder (``with TraceRecorder(...)``) wires it into
``repro.core.profiling`` as the trace sink, which turns every existing
``phase()`` call site in the evaluator/samplers/exec-cache into a
dual-sink (bucket + span) with the same no-op fast path when nothing is
installed.
"""

from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Any, Callable, Optional

from .metrics import metrics

TRACE_VERSION = 1

__all__ = ["TRACE_VERSION", "TraceRecorder", "recorder"]

_INSTALL_LOCK = threading.Lock()
_ACTIVE: Optional["TraceRecorder"] = None


def recorder() -> Optional["TraceRecorder"]:
    """The installed recorder, or ``None`` when tracing is off."""
    return _ACTIVE


class _SpanHandle:
    """An open span; exiting the context manager completes it."""

    __slots__ = ("_rec", "id", "parent", "name", "cat", "attrs",
                 "_t0", "_tid", "_thread", "_context")

    def __init__(self, rec: "TraceRecorder", sid: int, parent: Optional[int],
                 name: str, cat: str, t0: float, attrs: dict,
                 context: bool, tid: int, thread: str) -> None:
        self._rec = rec
        self.id = sid
        self.parent = parent
        self.name = name
        self.cat = cat
        self.attrs = attrs
        self._t0 = t0
        self._tid = tid
        self._thread = thread
        self._context = context

    def set(self, **attrs: Any) -> None:
        """Attach attributes resolved mid-span (score, prune reason, ...)."""
        self.attrs.update(attrs)

    def __enter__(self) -> "_SpanHandle":
        return self

    def __exit__(self, *exc) -> bool:
        self._rec._end(self)
        return False


class TraceRecorder:
    """Collects spans/instants in memory and appends them to JSONL.

    ``path=None`` keeps the trace purely in memory (tests, ad-hoc use);
    otherwise every completed record is appended and flushed so a
    crashed session still leaves a readable prefix.  Install with
    ``with`` — only one recorder may be active per process.
    """

    def __init__(self, path: Optional[str | Path] = None, *,
                 session: Optional[str] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 meta: Optional[dict] = None) -> None:
        self.path = Path(path) if path is not None else None
        self.session = session
        self._clock = clock
        self._t0 = clock()
        self._lock = threading.Lock()
        self._n = 0
        self._events: list[dict] = []
        self._tls = threading.local()
        self._ctx: list[int] = []  # cross-thread parent defaults
        self._file = None
        if self.path is not None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._file = open(self.path, "a", encoding="utf-8")
        head = {"type": "meta", "trace_version": TRACE_VERSION}
        if session is not None:
            head["session"] = session
        if meta:
            head.update(meta)
        self._emit(head)

    # -- span API ---------------------------------------------------------

    def span(self, name: str, cat: str = "phase", *, context: bool = False,
             **attrs: Any) -> _SpanHandle:
        """Open a span parented to this thread's innermost open span.

        With an empty local stack the span parents to the top of the
        context stack instead (how worker-thread trials attach to the
        session).  ``context=True`` additionally pushes the new span
        onto the context stack until it ends.
        """
        t0 = self._clock()
        th = threading.current_thread()
        stack = self._stack()
        with self._lock:
            self._n += 1
            sid = self._n
            parent = stack[-1].id if stack else (
                self._ctx[-1] if self._ctx else None)
            if context:
                self._ctx.append(sid)
        h = _SpanHandle(self, sid, parent, name, cat, t0, dict(attrs),
                        context, th.ident or 0, th.name)
        stack.append(h)
        return h

    def instant(self, name: str, **attrs: Any) -> None:
        """A zero-duration marker parented like :meth:`span`."""
        ts = self._clock() - self._t0
        th = threading.current_thread()
        stack = self._stack()
        with self._lock:
            parent = stack[-1].id if stack else (
                self._ctx[-1] if self._ctx else None)
        rec = {"type": "instant", "name": name, "parent": parent,
               "ts": round(ts, 9), "tid": th.ident or 0, "thread": th.name}
        if attrs:
            rec["attrs"] = attrs
        self._emit(rec)

    def add_phase(self, name: str, seconds: float,
                  at: Optional[float] = None) -> None:
        """Record an already-measured phase interval as a completed span.

        ``at`` is the interval's *end* on the recorder's clock (defaults
        to now); samplers that already hold clock readings pass it so
        back-to-back phases (dispatch then sync) land adjacent rather
        than overlapping.
        """
        end = at if at is not None else self._clock()
        th = threading.current_thread()
        stack = self._stack()
        with self._lock:
            self._n += 1
            sid = self._n
            parent = stack[-1].id if stack else (
                self._ctx[-1] if self._ctx else None)
        self._emit({"type": "span", "id": sid, "parent": parent,
                    "name": name, "cat": "phase",
                    "ts": round(end - self._t0 - seconds, 9),
                    "dur": round(max(seconds, 0.0), 9),
                    "tid": th.ident or 0, "thread": th.name})

    def meta_event(self, **fields: Any) -> None:
        """Append a free-form metadata record (metrics snapshots etc.)."""
        self._emit({"type": "meta", **fields})

    def events(self) -> list[dict]:
        """Copy of every record emitted so far (meta + spans + instants)."""
        with self._lock:
            return list(self._events)

    # -- internals --------------------------------------------------------

    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _end(self, h: _SpanHandle) -> None:
        t1 = self._clock()
        stack = self._stack()
        if stack and stack[-1] is h:
            stack.pop()
        elif h in stack:  # pragma: no cover - misnested exit, stay sane
            stack.remove(h)
        if h._context:
            with self._lock:
                if h.id in self._ctx:
                    self._ctx.remove(h.id)
        rec = {"type": "span", "id": h.id, "parent": h.parent,
               "name": h.name, "cat": h.cat,
               "ts": round(h._t0 - self._t0, 9),
               "dur": round(max(t1 - h._t0, 0.0), 9),
               "tid": h._tid, "thread": h._thread}
        if h.attrs:
            rec["attrs"] = h.attrs
        self._emit(rec)

    def _emit(self, rec: dict) -> None:
        line = json.dumps(rec, default=str)
        with self._lock:
            self._events.append(rec)
            if self._file is not None:
                self._file.write(line + "\n")
                self._file.flush()
        metrics().inc("trace.events")

    def close(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None

    # -- install ----------------------------------------------------------

    def __enter__(self) -> "TraceRecorder":
        global _ACTIVE
        from repro.core import profiling  # runtime import; no cycle
        with _INSTALL_LOCK:
            if _ACTIVE is not None:
                raise RuntimeError("a TraceRecorder is already installed")
            _ACTIVE = self
            profiling.set_trace_sink(self)
        return self

    def __exit__(self, *exc) -> bool:
        global _ACTIVE
        from repro.core import profiling
        with _INSTALL_LOCK:
            if _ACTIVE is self:
                _ACTIVE = None
                profiling.set_trace_sink(None)
        self.close()
        return False

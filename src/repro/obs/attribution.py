"""Whole-model roofline attribution: per-op cost vs the empirical roofs.

The tuner's roofs (DGEMM ``F_p``, TRIAD ``B_a``) only pay off when real
workloads can be placed on them. This module takes one
:class:`~repro.models.workloads.ModelWorkload`, walks its optimized HLO
per instruction (:func:`repro.analysis.hlo.parse_hlo_ops`), joins each
op's FLOPs/bytes with its measured device time when the profiler yields
device tracks, classifies every op compute- vs memory-bound against the
roofs recovered from the trial cache, and reports per-op and
per-subsystem %-of-roof with an explicit unattributed-time remainder.

Two modes, mirroring :mod:`repro.obs.device_timing`:

- **measured** — ``jax.profiler.trace`` produced device tracks; each
  HLO op joins against its device busy time, ``%-of-roof`` compares
  achieved FLOP/s (or B/s for flop-free ops) against the attainable
  roof at the op's intensity, and the remainder is the device time no
  HLO op claimed (trace overhead, unmatched events).
- **static** — no device tracks (CPU backends emit none): per-op time
  is *modeled* as ``max(flops/F_p, bytes/B_a)`` — the roofline's own
  lower bound — subsystem shares come from the model, ``%-of-roof`` is
  100 by construction, and the remainder is exactly zero. Every op
  still carries a subsystem label and bound class, so the dashboard
  section renders identically on a laptop and on an accelerator.

Without roofs (empty trial cache) ops still get costs and intensities
but classify as ``unclassified`` — the report degrades, never raises.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.analysis.hlo import ModuleOps, parse_hlo_ops

__all__ = [
    "AttributedOp",
    "AttributionReport",
    "Roofs",
    "attribute",
    "attribution_from_static",
    "roofs_from_trials",
]


# ---------------------------------------------------------------------------
# Roofs recovered from the trial cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Roofs:
    """The empirical ceilings one attribution classifies against."""

    peak_flops: float                   # F_p, FLOP/s
    bandwidths: dict[str, float]        # subsystem -> B_a, bytes/s
    fingerprint: str = ""

    @property
    def default_subsystem(self) -> str:
        """The outermost (slowest) memory level — the conservative slope
        an op of unknown residency is classified against."""
        return min(self.bandwidths, key=self.bandwidths.get)

    def ridge(self, subsystem: Optional[str] = None) -> float:
        b = self.bandwidths[subsystem or self.default_subsystem]
        return self.peak_flops / b

    def attainable(self, intensity: float,
                   subsystem: Optional[str] = None) -> float:
        b = self.bandwidths[subsystem or self.default_subsystem]
        return min(b * intensity, self.peak_flops)

    def classify(self, intensity: float) -> tuple[str, str]:
        """(subsystem, bound) of one op by its arithmetic intensity."""
        sub = self.default_subsystem
        bound = "compute" if intensity >= self.ridge(sub) else "memory"
        return sub, bound

    def model_time(self, flops: float, bytes_accessed: float) -> float:
        """Roofline lower-bound time: max of compute and memory terms."""
        t_c = flops / self.peak_flops if self.peak_flops > 0 else 0.0
        b = self.bandwidths[self.default_subsystem]
        t_m = bytes_accessed / b if b > 0 else 0.0
        return max(t_c, t_m)

    def to_json(self) -> dict:
        return {"peak_flops": self.peak_flops,
                "bandwidths": dict(self.bandwidths),
                "fingerprint": self.fingerprint}


def roofs_from_trials(paths: Sequence[str],
                      fingerprint: Optional[str] = None) -> Optional[Roofs]:
    """Recover ``F_p``/``B_a`` from cached trials (the paper's end
    product, reassembled from disk).

    Prefers the report matching ``fingerprint`` (default: this host's
    :func:`~repro.core.cache.hardware_fingerprint`), falling back to the
    first reportable fingerprint; ``None`` when no cache path yields a
    complete report.
    """
    from repro.core.cache import load_trials
    from repro.core.report import build_reports

    trials = []
    for p in paths:
        try:
            trials.extend(load_trials(p))
        except (OSError, ValueError):
            continue
    if not trials:
        return None
    reports, _ = build_reports(trials)
    if not reports:
        return None
    if fingerprint is None:
        try:
            from repro.core.cache import hardware_fingerprint

            fingerprint = hardware_fingerprint()
        except Exception:
            fingerprint = None
    chosen = next((r for r in reports if r.fingerprint == fingerprint),
                  reports[0])
    return Roofs(
        peak_flops=chosen.model.machine.peak_flops,
        bandwidths=dict(chosen.model.machine.mem_bandwidths),
        fingerprint=chosen.fingerprint)


# ---------------------------------------------------------------------------
# Attribution records
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttributedOp:
    """One HLO op placed on the roofline."""

    name: str
    kind: str
    flops: float
    bytes_accessed: float
    intensity: float            # FLOP/byte (inf for flop-only ops)
    time_s: Optional[float]     # measured (or modeled, static mode)
    subsystem: str              # memory subsystem label | "unclassified"
    bound: str                  # "compute" | "memory" | "unclassified"
    pct_of_roof: Optional[float]
    modeled: bool               # False: cost model had no formula

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        if math.isinf(self.intensity):
            d["intensity"] = None  # JSON has no Infinity
        return d


@dataclasses.dataclass(frozen=True)
class AttributionReport:
    """Per-op and per-subsystem roofline placement of one workload."""

    workload: str
    mode: str                            # "measured" | "static"
    ops: tuple[AttributedOp, ...]
    total_flops: float
    total_bytes: float
    device_total_s: Optional[float]      # None in static mode
    attributed_s: float                  # sum of joined / modeled op time
    unattributed_s: float                # device_total - attributed (0 static)
    subsystem_seconds: dict[str, float]  # "compute" + memory subsystems
    roofs: Optional[Roofs]
    unhandled: dict[str, int]            # op kinds the cost model skipped
    fingerprint: str = ""

    @property
    def unattributed_frac(self) -> float:
        total = (self.device_total_s if self.device_total_s
                 else self.attributed_s)
        if not total:
            return 0.0
        return self.unattributed_s / total

    def top_ops(self, n: int = 20) -> tuple[AttributedOp, ...]:
        """Heaviest ops first: by time when we have it, else by FLOPs
        then bytes (static mode always has modeled time)."""
        def weight(op: AttributedOp):
            return (op.time_s if op.time_s is not None else 0.0,
                    op.flops, op.bytes_accessed)
        return tuple(sorted(self.ops, key=weight, reverse=True)[:n])

    def to_json(self) -> dict:
        return {
            "workload": self.workload,
            "mode": self.mode,
            "fingerprint": self.fingerprint,
            "total_flops": self.total_flops,
            "total_bytes": self.total_bytes,
            "device_total_s": self.device_total_s,
            "attributed_s": self.attributed_s,
            "unattributed_s": self.unattributed_s,
            "unattributed_frac": self.unattributed_frac,
            "subsystem_seconds": dict(self.subsystem_seconds),
            "roofs": self.roofs.to_json() if self.roofs else None,
            "unhandled": dict(self.unhandled),
            "ops": [op.to_json() for op in self.ops],
        }

    def to_markdown(self, max_ops: int = 20) -> str:
        """Self-contained markdown: per-op table + subsystem summary."""
        lines = [f"## Roofline attribution: `{self.workload}` "
                 f"({self.mode})", ""]
        if self.roofs is not None:
            bw = ", ".join(f"{k}={v:.3g} B/s"
                           for k, v in sorted(self.roofs.bandwidths.items()))
            lines.append(f"Roofs: F_p={self.roofs.peak_flops:.3g} FLOP/s; "
                         f"{bw} (`{self.roofs.fingerprint or 'n/a'}`)")
        else:
            lines.append("Roofs: none recovered — ops are unclassified.")
        lines.append("")
        header = ["op", "kind", "FLOPs", "bytes", "I (FLOP/B)",
                  "time", "subsystem", "bound", "% of roof"]
        rows = []
        for op in self.top_ops(max_ops):
            rows.append([
                f"`{op.name}`", op.kind, f"{op.flops:.4g}",
                f"{op.bytes_accessed:.4g}",
                "∞" if math.isinf(op.intensity) else f"{op.intensity:.3g}",
                (f"{op.time_s * 1e6:.3g}µs" if op.time_s is not None
                 else "—"),
                op.subsystem, op.bound,
                (f"{op.pct_of_roof:.1f}%" if op.pct_of_roof is not None
                 else "—"),
            ])
        lines.append("| " + " | ".join(header) + " |")
        lines.append("|" + "|".join("---" for _ in header) + "|")
        lines += ["| " + " | ".join(r) + " |" for r in rows]
        if len(self.ops) > max_ops:
            lines.append("")
            lines.append(f"({len(self.ops) - max_ops} further ops elided)")
        lines.append("")
        lines.append("### Subsystem shares")
        lines.append("")
        total = sum(self.subsystem_seconds.values()) + self.unattributed_s
        lines.append("| subsystem | time | share |")
        lines.append("|---|---|---|")
        for sub, secs in sorted(self.subsystem_seconds.items()):
            share = 100.0 * secs / total if total else 0.0
            lines.append(f"| {sub} | {secs * 1e6:.3g}µs | {share:.1f}% |")
        u_share = 100.0 * self.unattributed_s / total if total else 0.0
        lines.append(f"| *unattributed* | {self.unattributed_s * 1e6:.3g}µs "
                     f"| {u_share:.1f}% |")
        lines.append("")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Assembly
# ---------------------------------------------------------------------------


def _attr_op(op, time_s: Optional[float], roofs: Optional[Roofs],
             static: bool) -> AttributedOp:
    intensity = op.intensity
    if roofs is None:
        return AttributedOp(
            name=op.name, kind=op.kind, flops=op.flops,
            bytes_accessed=op.bytes_accessed, intensity=intensity,
            time_s=time_s, subsystem="unclassified", bound="unclassified",
            pct_of_roof=None, modeled=op.modeled)
    sub, bound = roofs.classify(intensity)
    pct: Optional[float] = None
    if static:
        # modeled time saturates the roof by construction: the static
        # fallback reports *where* time must go, not how well it is spent
        pct = 100.0
    elif time_s and time_s > 0:
        if op.flops > 0 and not math.isinf(intensity):
            roof = roofs.attainable(intensity, sub)
            pct = 100.0 * (op.flops / time_s) / roof if roof > 0 else 0.0
        elif op.flops > 0:
            pct = 100.0 * (op.flops / time_s) / roofs.peak_flops
        elif op.bytes_accessed > 0:
            b = roofs.bandwidths[sub]
            pct = 100.0 * (op.bytes_accessed / time_s) / b if b > 0 else 0.0
    return AttributedOp(
        name=op.name, kind=op.kind, flops=op.flops,
        bytes_accessed=op.bytes_accessed, intensity=intensity,
        time_s=time_s, subsystem=sub, bound=bound, pct_of_roof=pct,
        modeled=op.modeled)


def _subsystem_seconds(ops: Sequence[AttributedOp]) -> dict[str, float]:
    """Time bucketed by bound class: compute-bound ops under "compute",
    memory-bound ops under their subsystem, unclassified under its own
    key — the stacked-bar data of the dashboard section."""
    out: dict[str, float] = {}
    for op in ops:
        if op.time_s is None:
            continue
        key = "compute" if op.bound == "compute" else (
            op.subsystem if op.bound == "memory" else "unclassified")
        out[key] = out.get(key, 0.0) + op.time_s
    return out


def attribution_from_static(workload_name: str, module: ModuleOps,
                            roofs: Optional[Roofs],
                            fingerprint: str = "") -> AttributionReport:
    """Static HLO-only attribution (the off-GPU fallback): op time is the
    roofline model's own lower bound, the remainder is exactly zero."""
    attributed: list[AttributedOp] = []
    for op in module.ops:
        t = roofs.model_time(op.flops, op.bytes_accessed) if roofs else None
        attributed.append(_attr_op(op, t, roofs, static=True))
    total_t = sum(op.time_s or 0.0 for op in attributed)
    return AttributionReport(
        workload=workload_name, mode="static", ops=tuple(attributed),
        total_flops=module.flops, total_bytes=module.bytes_accessed,
        device_total_s=None, attributed_s=total_t, unattributed_s=0.0,
        subsystem_seconds=_subsystem_seconds(attributed), roofs=roofs,
        unhandled=dict(module.unhandled), fingerprint=fingerprint)


def _attribution_from_device(workload_name: str, module: ModuleOps,
                             device, roofs: Optional[Roofs],
                             fingerprint: str = "") -> AttributionReport:
    attributed: list[AttributedOp] = []
    joined = 0.0
    for op in module.ops:
        t = device.by_name.get(op.name)
        if t is not None:
            joined += t
        attributed.append(_attr_op(op, t, roofs, static=False))
    return AttributionReport(
        workload=workload_name, mode="measured", ops=tuple(attributed),
        total_flops=module.flops, total_bytes=module.bytes_accessed,
        device_total_s=device.total_s, attributed_s=joined,
        unattributed_s=max(device.total_s - joined, 0.0),
        subsystem_seconds=_subsystem_seconds(attributed), roofs=roofs,
        unhandled=dict(module.unhandled), fingerprint=fingerprint)


def attribute(workload, roofs: Optional[Roofs] = None, *,
              force_static: bool = False,
              log_dir: Optional[str] = None) -> AttributionReport:
    """Attribute one :class:`~repro.models.workloads.ModelWorkload`.

    Tries the measured path (one profiled invocation, like
    :func:`repro.obs.device_timing.profile_sample`) unless
    ``force_static``; degrades to static HLO-only attribution when the
    profiler yields no device tracks. Emits PR-9 trace instants so the
    Perfetto export carries op-level context.
    """
    from repro.core.profiling import trace_instant

    module = parse_hlo_ops(workload.hlo_text())
    fingerprint = ""
    try:
        from repro.core.cache import hardware_fingerprint

        fingerprint = hardware_fingerprint()
    except Exception:
        pass
    device = None
    if not force_static:
        from .device_timing import profile_ops

        compiled = workload.compiled()
        device = profile_ops(lambda: compiled(*workload.args),
                             log_dir=log_dir)
    if device is None:
        report = attribution_from_static(workload.name, module, roofs,
                                         fingerprint)
    else:
        report = _attribution_from_device(workload.name, module, device,
                                          roofs, fingerprint)
    trace_instant("attribution", workload=report.workload, mode=report.mode,
                  n_ops=len(report.ops), total_flops=report.total_flops,
                  total_bytes=report.total_bytes,
                  unattributed_frac=report.unattributed_frac)
    for op in report.top_ops(10):
        trace_instant("attribution_op", workload=report.workload,
                      op=op.name, kind=op.kind, flops=op.flops,
                      bytes=op.bytes_accessed, subsystem=op.subsystem,
                      bound=op.bound,
                      pct_of_roof=op.pct_of_roof)
    return report

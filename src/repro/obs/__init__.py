"""Observability: span tracing, metrics, Perfetto export, device timing.

The subsystem is deliberately import-light: no module here imports
``repro.core`` at module level, so core modules (cache, ledger) may
import :mod:`repro.obs.metrics` at the top of the file without creating
a cycle.  The :class:`TraceRecorder` reaches back into
``repro.core.profiling`` only at install time (``__enter__``) to wire
itself in as the trace sink behind the dual-sink ``phase()`` helpers.
"""

from .device_timing import DeviceTiming, device_timing_available, profile_sample
from .export import (load_events, to_chrome_trace, trial_summaries,
                     validate_chrome_trace, write_chrome_trace)
from .metrics import MetricsRegistry, metrics
from .trace import TRACE_VERSION, TraceRecorder, recorder

__all__ = [
    "DeviceTiming",
    "MetricsRegistry",
    "TRACE_VERSION",
    "TraceRecorder",
    "device_timing_available",
    "load_events",
    "metrics",
    "profile_sample",
    "recorder",
    "to_chrome_trace",
    "trial_summaries",
    "validate_chrome_trace",
    "write_chrome_trace",
]

"""Observability: span tracing, metrics, Perfetto export, device timing.

The subsystem is deliberately import-light: no module here imports
``repro.core`` at module level, so core modules (cache, ledger) may
import :mod:`repro.obs.metrics` at the top of the file without creating
a cycle.  The :class:`TraceRecorder` reaches back into
``repro.core.profiling`` only at install time (``__enter__``) to wire
itself in as the trace sink behind the dual-sink ``phase()`` helpers.
"""

from .attribution import (AttributedOp, AttributionReport, Roofs, attribute,
                          attribution_from_static, roofs_from_trials)
from .device_timing import (DeviceOps, DeviceTiming,
                            device_timing_available, profile_ops,
                            profile_sample)
from .export import (load_events, to_chrome_trace, trial_summaries,
                     validate_chrome_trace, write_chrome_trace)
from .metrics import MetricsRegistry, metrics
from .trace import TRACE_VERSION, TraceRecorder, recorder

__all__ = [
    "AttributedOp",
    "AttributionReport",
    "DeviceOps",
    "DeviceTiming",
    "MetricsRegistry",
    "Roofs",
    "TRACE_VERSION",
    "TraceRecorder",
    "attribute",
    "attribution_from_static",
    "device_timing_available",
    "load_events",
    "metrics",
    "profile_ops",
    "profile_sample",
    "recorder",
    "roofs_from_trials",
    "to_chrome_trace",
    "trial_summaries",
    "validate_chrome_trace",
    "write_chrome_trace",
]

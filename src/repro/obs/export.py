"""Trace exports: Chrome trace-event JSON and per-trial summary tables.

:func:`to_chrome_trace` emits the Trace Event Format that both
``chrome://tracing`` and https://ui.perfetto.dev load directly: one
``ph="X"`` complete event per span (``ts``/``dur`` in microseconds),
``ph="i"`` thread-scoped instants, and ``ph="M"`` process/thread name
metadata with ``pid=1`` for the session and ``tid`` = the worker
thread.  Events are sorted by begin time within the array so timestamps
are monotone per tid and enclosing spans precede their children —
:func:`validate_chrome_trace` checks exactly that plus proper nesting.

:func:`trial_summaries` folds a raw event list into one dict per trial
(config, score, prune/stop reason, sample count, per-phase seconds,
improvement marker, worker thread) — the compact table
``repro.history.render`` turns into the dashboard drill-down section.
"""

from __future__ import annotations

import json
from collections import defaultdict
from pathlib import Path
from typing import Iterable, Optional

__all__ = ["load_events", "to_chrome_trace", "trial_summaries",
           "validate_chrome_trace", "write_chrome_trace"]

# nesting/monotonicity tolerance: span bounds are rounded to nanoseconds
# on write, so disagreements below ~2us are representation noise
_EPS_US = 2.0


def load_events(path: str | Path) -> list[dict]:
    """Read a trace JSONL file, skipping torn/garbage lines."""
    events: list[dict] = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(rec, dict) and rec.get("type"):
                events.append(rec)
    return events


def _us(seconds: float) -> float:
    return round(seconds * 1e6, 3)


def to_chrome_trace(events: Iterable[dict], *, pid: int = 1) -> dict:
    """Convert recorder events to a Perfetto-loadable Chrome trace."""
    events = list(events)
    session = next((e.get("session") for e in events
                    if e.get("type") == "meta" and e.get("session")), None)
    out: list[dict] = [{
        "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
        "args": {"name": f"session:{session}" if session else "session"},
    }]
    thread_names: dict[int, str] = {}
    body: list[tuple[tuple, dict]] = []
    for e in events:
        kind = e.get("type")
        if kind not in ("span", "instant"):
            continue
        tid = int(e.get("tid", 0))
        if tid not in thread_names:
            thread_names[tid] = str(e.get("thread", tid))
        args = dict(e.get("attrs") or {})
        if kind == "span":
            args["span_id"] = e.get("id")
            if e.get("parent") is not None:
                args["parent"] = e.get("parent")
            ev = {"ph": "X", "name": str(e.get("name")),
                  "cat": str(e.get("cat", "phase")),
                  "ts": _us(float(e.get("ts", 0.0))),
                  "dur": _us(float(e.get("dur", 0.0))),
                  "pid": pid, "tid": tid, "args": args}
            # begin-time order, widest-first on ties: parents precede
            # children and per-tid timestamps come out monotone
            body.append(((ev["ts"], -ev["dur"]), ev))
        else:
            if e.get("parent") is not None:
                args["parent"] = e.get("parent")
            ev = {"ph": "i", "s": "t", "name": str(e.get("name")),
                  "ts": _us(float(e.get("ts", 0.0))),
                  "pid": pid, "tid": tid, "args": args}
            body.append(((ev["ts"], 0.0), ev))
    for tid, name in sorted(thread_names.items()):
        out.append({"ph": "M", "name": "thread_name", "pid": pid,
                    "tid": tid, "args": {"name": name}})
    out.extend(ev for _, ev in sorted(body, key=lambda item: item[0]))
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str | Path, events: Iterable[dict]) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(to_chrome_trace(events)) + "\n",
                    encoding="utf-8")
    return path


def validate_chrome_trace(doc: dict) -> list[str]:
    """Schema/shape problems in an exported trace ([] when clean).

    Checks: required keys per phase type, non-negative durations,
    monotone begin timestamps per tid in array order, and proper
    nesting of duration events within each tid (spans on one thread
    must contain or be disjoint from each other — never interleave).
    """
    problems: list[str] = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    last_ts: dict[int, float] = {}
    open_spans: dict[int, list[tuple[float, float, str]]] = defaultdict(list)
    for i, ev in enumerate(evs):
        ph = ev.get("ph")
        if ph == "M":
            continue
        if ph not in ("X", "i"):
            problems.append(f"event {i}: unexpected ph {ph!r}")
            continue
        missing = [k for k in ("name", "ts", "pid", "tid") if k not in ev]
        if ph == "X" and "dur" not in ev:
            missing.append("dur")
        if missing:
            problems.append(f"event {i}: missing {missing}")
            continue
        tid = ev["tid"]
        ts = float(ev["ts"])
        if ts < last_ts.get(tid, float("-inf")) - _EPS_US:
            problems.append(
                f"event {i} ({ev['name']}): ts {ts} not monotone on "
                f"tid {tid} (prev {last_ts[tid]})")
        last_ts[tid] = max(ts, last_ts.get(tid, ts))
        if ph != "X":
            continue
        dur = float(ev["dur"])
        if dur < 0:
            problems.append(f"event {i} ({ev['name']}): negative dur {dur}")
            continue
        stack = open_spans[tid]
        while stack and stack[-1][1] <= ts + _EPS_US:
            stack.pop()
        if stack and ts + dur > stack[-1][1] + _EPS_US:
            problems.append(
                f"event {i} ({ev['name']}): [{ts}, {ts + dur}] interleaves "
                f"with open span {stack[-1][2]!r} ending {stack[-1][1]} "
                f"on tid {tid}")
            continue
        stack.append((ts, ts + dur, str(ev["name"])))
    return problems


def trial_summaries(events: Iterable[dict]) -> list[dict]:
    """One compact dict per trial, in trial-index order.

    Fresh trials come from ``cat="trial"`` spans (phase seconds are
    summed over the span's descendants, improvement/prune markers from
    instants inside it); cache-served trials come from ``cache_hit``
    instants and carry ``cached=True`` with no timing breakdown.
    """
    events = list(events)
    spans = [e for e in events if e.get("type") == "span"]
    instants = [e for e in events if e.get("type") == "instant"]
    children: dict[Optional[int], list[dict]] = defaultdict(list)
    for s in spans:
        children[s.get("parent")].append(s)

    rows: list[dict] = []
    for t in spans:
        if t.get("cat") != "trial":
            continue
        attrs = t.get("attrs") or {}
        subtree = {t.get("id")}
        phases: dict[str, float] = {}
        invocations = 0
        frontier = [t]
        while frontier:
            node = frontier.pop()
            for c in children.get(node.get("id"), ()):
                subtree.add(c.get("id"))
                frontier.append(c)
                if c.get("cat") == "invocation":
                    invocations += 1
                elif c.get("cat") == "phase":
                    name = str(c.get("name"))
                    phases[name] = phases.get(name, 0.0) + float(
                        c.get("dur", 0.0))
        # instants attach by parent span (live backends emit them inside
        # the trial span) or by a "trial" attr (round-synchronized
        # backends all-reduce after the spans close)
        marks = [i for i in instants
                 if i.get("parent") in subtree
                 or (i.get("attrs") or {}).get("trial") == attrs.get("index")]
        rows.append({
            "index": attrs.get("index"),
            "config": attrs.get("config"),
            "score": attrs.get("score"),
            "pruned": bool(attrs.get("pruned")),
            "stop_reason": attrs.get("stop_reason"),
            "samples": attrs.get("samples"),
            "worker": attrs.get("worker"),
            "thread": t.get("thread"),
            "tid": t.get("tid"),
            "ts": float(t.get("ts", 0.0)),
            "dur_s": float(t.get("dur", 0.0)),
            "invocations": invocations,
            "phases": dict(sorted(phases.items())),
            "improved": any(i.get("name") == "incumbent_improved"
                            for i in marks),
            "cached": False,
        })
    for i in instants:
        if i.get("name") != "cache_hit":
            continue
        attrs = i.get("attrs") or {}
        rows.append({
            "index": attrs.get("index"),
            "config": attrs.get("config"),
            "score": attrs.get("score"),
            "pruned": bool(attrs.get("pruned")),
            "stop_reason": attrs.get("stop_reason"),
            "samples": attrs.get("samples"),
            "worker": None,
            "thread": i.get("thread"),
            "tid": i.get("tid"),
            "ts": float(i.get("ts", 0.0)),
            "dur_s": 0.0,
            "invocations": 0,
            "phases": {},
            "improved": False,
            "cached": True,
        })
    rows.sort(key=lambda r: (r["index"] is None,
                             r["index"] if r["index"] is not None else 0,
                             r["ts"]))
    return rows

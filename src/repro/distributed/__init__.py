"""Distribution layer: mesh-aware sharding rules and the distributed tuner."""

from .sharding import (SERVE_RULES, TRAIN_RULES, ShardingRules, logical_to_spec,
                       spec_tree)

__all__ = ["SERVE_RULES", "TRAIN_RULES", "ShardingRules", "logical_to_spec",
           "spec_tree"]

"""Logical-axis sharding rules -> PartitionSpecs, divisibility-aware.

Models annotate every parameter and activation dim with a *logical* axis name
("embed", "heads", "mlp", "vocab", ...). A :class:`ShardingRules` maps each
logical name to mesh axis names. ``logical_to_spec`` resolves the mapping
against a concrete mesh, *dropping* any mesh axis that does not evenly divide
the dimension (fallback = replication on that axis) — this is what lets one
rule set serve all ten architectures (36-head MiniCPM simply ends up with
replicated attention while 96-head Command-R gets full 16-way TP; see
DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import params as params_lib

MeshAxes = tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis names (in priority order)."""

    rules: Mapping[str, MeshAxes]
    name: str = "custom"

    def get(self, logical: str | None) -> MeshAxes:
        if logical is None:
            return ()
        return self.rules.get(logical, ())

    def replace(self, **updates: MeshAxes) -> "ShardingRules":
        merged = dict(self.rules)
        merged.update(updates)
        return ShardingRules(rules=merged, name=self.name + "+")


# Default rule sets. "pod" is pure data parallelism across pods; "data"
# carries DP + FSDP (ZeRO-3 weight sharding on the contraction dim);
# "model" carries TP (heads / mlp / vocab) and the decode-cache sequence
# split (flash-decoding-style split-K, resolved by GSPMD collectives).
TRAIN_RULES = ShardingRules(name="train", rules={
    # activations: batch over DP axes; the sequence dim of saved block
    # boundaries is sharded over "model" (Megatron-style sequence
    # parallelism) — without it the scan backward stashes an unsharded
    # (B_local, S, D) residual per layer and the 40-layer stack alone is
    # 10.7GB/device (33GB peak -> 5.1GB peak on granite train_4k; see
    # EXPERIMENTS.md §Perf)
    "batch": ("pod", "data"),
    "act_seq": ("model",),
    "act_embed": (),
    # weights
    "embed": ("data",),          # FSDP: contraction dim sharded over data
    "embed_r": (),               # replicated d_model (embedding table)
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": (),
    "head_dim": (),
    "mlp": ("model",),
    "experts": (),               # expert dim replicated; expert mlp TP'd
    "layers": (),
    "frames": (),
    "ssm_inner": ("model",),
    "ssm_state": (),
    "conv": (),
    # decode cache (unused in train)
    "cache_seq": ("model",),
    "cache_batch": ("data",),
})

SERVE_RULES = ShardingRules(name="serve", rules={
    "batch": ("data",),
    "act_seq": (),
    "act_embed": (),
    "embed": ("data",),          # 2D weight sharding for big checkpoints
    "embed_r": (),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": (),
    "head_dim": (),
    "mlp": ("model",),
    "experts": (),
    "layers": (),
    "frames": (),
    "ssm_inner": ("model",),
    "ssm_state": (),
    "conv": (),
    "cache_seq": ("model",),
    "cache_batch": ("data",),
})


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def logical_to_spec(logical: Sequence[str | None], shape: Sequence[int],
                    rules: ShardingRules, mesh: Mesh) -> P:
    """Resolve one tensor's logical axes to a PartitionSpec.

    Mesh axes that are absent from the mesh or do not divide the dim size are
    dropped (replication fallback). A mesh axis may be consumed by only one
    dim (first wins), matching GSPMD validity rules.
    """
    used: set[str] = set()
    entries: list[Any] = []
    for dim_size, name in zip(shape, logical):
        axes: list[str] = []
        divisor = 1
        for ax in rules.get(name):
            if ax in used or ax not in mesh.shape:
                continue
            nxt = divisor * _axis_size(mesh, ax)
            if dim_size % nxt == 0:
                axes.append(ax)
                used.add(ax)
                divisor = nxt
        if not axes:
            entries.append(None)
        elif len(axes) == 1:
            entries.append(axes[0])
        else:
            entries.append(tuple(axes))
    return P(*entries)


def spec_tree(defs: Any, rules: ShardingRules, mesh: Mesh) -> Any:
    """PartitionSpec tree for a ParamDef tree."""
    return params_lib._map_tree(
        lambda _, d: logical_to_spec(d.logical, d.shape, rules, mesh), defs)


def sharding_tree(defs: Any, rules: ShardingRules, mesh: Mesh) -> Any:
    """NamedSharding tree for a ParamDef tree."""
    return params_lib._map_tree(
        lambda _, d: NamedSharding(
            mesh, logical_to_spec(d.logical, d.shape, rules, mesh)), defs)


def activation_spec(rules: ShardingRules, mesh: Mesh,
                    logical: Sequence[str | None],
                    shape: Sequence[int]) -> P:
    """Spec for an activation/input tensor (same resolution path)."""
    return logical_to_spec(logical, shape, rules, mesh)

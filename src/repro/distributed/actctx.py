"""Activation-sharding context: logical constraints inside model code.

GSPMD's automatic propagation can settle on pathological layouts when the
graph gives it freedom (observed: shallow unrolled models placing the FSDP
weight sharding onto activations, replicating the batch — EXPERIMENTS.md
§Perf iteration 0). Models therefore annotate key activations with *logical*
axes via :func:`shard_act`; the step builders install a resolver that maps
logical axes -> NamedSharding for the active (mesh, rules). Outside any
context (unit tests, CPU smoke runs) ``shard_act`` is a no-op.

This module deliberately imports nothing from ``repro.models`` so the model
zoo can depend on it without cycles.
"""

from __future__ import annotations

import contextlib
from typing import Callable, Optional, Sequence

import jax

# resolver(logical_axes, shape) -> sharding or None
Resolver = Callable[[Sequence[Optional[str]], Sequence[int]], Optional[object]]

_RESOLVER: Optional[Resolver] = None


@contextlib.contextmanager
def activation_sharding(resolver: Resolver):
    """Install a resolver for the duration of a trace."""
    global _RESOLVER
    prev = _RESOLVER
    _RESOLVER = resolver
    try:
        yield
    finally:
        _RESOLVER = prev


def shard_act(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Constrain one activation to its logical layout (no-op w/o context)."""
    if _RESOLVER is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"logical {logical} vs shape {x.shape}")
    sharding = _RESOLVER(logical, x.shape)
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)

"""Distributed CI-pruned autotuning (beyond-paper; DESIGN.md §8.1).

The paper runs one node's benchmark search serially. At fleet scale two
parallelization axes open up, both enabled by the *exact* parallel merge of
Welford moments (Chan, Golub & LeVeque):

  1. **Search-space sharding** — workers take a strided shard of the
     (ordered) configuration list; after every round the incumbent best is
     all-reduced so stop-condition 4 prunes against the *global* best.
     On a real pod this is a scalar ``lax.pmax`` per round; here the
     scheduler is simulated with faithful per-worker wall-clock accounting
     (parallel time = max over workers).

  2. **Replicated evaluation** — several workers sample the *same*
     configuration concurrently and their (n, mean, M2) partials merge
     exactly, so the CI tightens ~sqrt(W) faster in wall-clock terms —
     useful for the high-variance configurations the paper's max-count cap
     would otherwise truncate.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from ..core import welford
from ..core.confidence import Interval, ci_mean
from ..core.evaluator import EvaluationSettings, Evaluator, InvocationFactory
from ..core.searchspace import Config, SearchSpace
from ..core.tuner import BenchmarkFactory, TrialRecord


@dataclasses.dataclass(frozen=True)
class DistributedTuningResult:
    best_config: Optional[Config]
    best_score: Optional[float]
    trials: tuple[TrialRecord, ...]
    total_samples: int
    serial_time_s: float           # sum of all trial times
    parallel_time_s: float         # max over workers (simulated wall clock)
    n_workers: int
    n_pruned: int

    @property
    def parallel_speedup(self) -> float:
        return self.serial_time_s / max(self.parallel_time_s, 1e-12)


def shard_configs(configs: list[Config], n_workers: int) -> list[list[Config]]:
    """Strided assignment: adjacent (similar-cost) configs spread across
    workers, balancing the size-correlated evaluation cost (paper Fig. 6)."""
    return [configs[w::n_workers] for w in range(n_workers)]


class DistributedTuner:
    """Search-space-sharded tuning with per-round incumbent all-reduce."""

    def __init__(self, space: SearchSpace, settings: EvaluationSettings,
                 n_workers: int = 4, order: str = "exhaustive",
                 seed: Optional[int] = None):
        self.space = space
        self.settings = settings
        self.n_workers = n_workers
        self.order = order
        self.seed = seed

    def tune(self, benchmark: BenchmarkFactory) -> DistributedTuningResult:
        evaluator = Evaluator(self.settings)
        direction = self.settings.direction
        shards = shard_configs(self.space.ordered(self.order, self.seed),
                               self.n_workers)
        worker_time = [0.0] * self.n_workers
        incumbent: Optional[float] = None
        best_cfg: Optional[Config] = None
        trials: list[TrialRecord] = []
        rounds = max(len(s) for s in shards)
        for r in range(rounds):
            # one synchronized round: each worker evaluates its r-th config
            # against the incumbent agreed at the end of the previous round
            round_results = []
            for w, shard in enumerate(shards):
                if r >= len(shard):
                    continue
                cfg = shard[r]
                t0 = time.perf_counter()
                res = evaluator.evaluate(benchmark(cfg), incumbent=incumbent)
                worker_time[w] += time.perf_counter() - t0
                trials.append(TrialRecord(config=cfg, result=res))
                round_results.append((cfg, res))
            # incumbent all-reduce (scalar pmax/pmin on a real mesh)
            for cfg, res in round_results:
                if not res.pruned and (incumbent is None or
                                       direction.better(res.score, incumbent)):
                    incumbent = res.score
                    best_cfg = cfg
        return DistributedTuningResult(
            best_config=best_cfg, best_score=incumbent,
            trials=tuple(trials),
            total_samples=sum(t.result.total_samples for t in trials),
            serial_time_s=sum(worker_time),
            parallel_time_s=max(worker_time) if worker_time else 0.0,
            n_workers=self.n_workers,
            n_pruned=sum(1 for t in trials if t.result.pruned))


def replicated_evaluate(make_invocation: InvocationFactory,
                        settings: EvaluationSettings, n_workers: int,
                        confidence: float = 0.99,
                        ) -> tuple[Interval, welford.WelfordState, float]:
    """Evaluate ONE configuration on ``n_workers`` concurrent workers and
    merge their sample streams exactly. Returns (CI of merged mean, merged
    state, simulated parallel wall-clock)."""
    evaluator = Evaluator(settings)
    partials = []
    wall = 0.0
    for _ in range(n_workers):
        t0 = time.perf_counter()
        res = evaluator.evaluate(make_invocation)
        wall = max(wall, time.perf_counter() - t0)
        for inv in res.invocations:
            # each invocation's full (n, mean, M2) — the merge is exact
            partials.append(welford.WelfordState(
                count=float(inv.count), mean=inv.mean, m2=inv.m2))
    merged = welford.tree_merge(partials)
    return ci_mean(merged, confidence), merged, wall

"""Distributed CI-pruned autotuning (beyond-paper; DESIGN.md §8.1).

The paper runs one node's benchmark search serially. At fleet scale two
parallelization axes open up, both enabled by the *exact* parallel merge of
Welford moments (Chan, Golub & LeVeque):

  1. **Search-space sharding** — workers take a strided shard of the
     (ordered) configuration list; after every round the incumbent best is
     all-reduced so stop-condition 4 prunes against the *global* best.
     On a real pod this is a scalar ``lax.pmax`` per round; here the
     scheduler is simulated with faithful per-worker wall-clock accounting
     (parallel time = max over workers).

  2. **Replicated evaluation** — several workers sample the *same*
     configuration concurrently and their (n, mean, M2) partials merge
     exactly, so the CI tightens ~sqrt(W) faster in wall-clock terms —
     useful for the high-variance configurations the paper's max-count cap
     would otherwise truncate.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

from ..core import welford
from ..core.confidence import Interval, ci_mean
from ..core.evaluator import EvaluationSettings, Evaluator, InvocationFactory
from ..core.executor import SimulatedShardedBackend, shard_configs  # noqa: F401 — re-export
from ..core.searchspace import Config, SearchSpace
from ..core.tuner import BenchmarkFactory, TrialRecord, Tuner


@dataclasses.dataclass(frozen=True)
class DistributedTuningResult:
    best_config: Optional[Config]
    best_score: Optional[float]
    trials: tuple[TrialRecord, ...]
    total_samples: int
    serial_time_s: float           # sum of all trial times
    parallel_time_s: float         # max over workers (simulated wall clock)
    n_workers: int
    n_pruned: int

    @property
    def parallel_speedup(self) -> float:
        return self.serial_time_s / max(self.parallel_time_s, 1e-12)


class DistributedTuner:
    """Search-space-sharded tuning with per-round incumbent all-reduce.

    Now a thin shell: the round scheduling, strided sharding and
    per-worker wall-clock accounting live in
    :class:`~repro.core.executor.SimulatedShardedBackend`, shared with the
    serial and thread-pool paths of :class:`~repro.core.tuner.Tuner`.
    """

    def __init__(self, space: SearchSpace, settings: EvaluationSettings,
                 n_workers: int = 4, order: str = "exhaustive",
                 seed: Optional[int] = None):
        self.space = space
        self.settings = settings
        self.n_workers = n_workers
        self.order = order
        self.seed = seed

    def tune(self, benchmark: BenchmarkFactory,
             cache=None) -> DistributedTuningResult:
        result = Tuner(self.space, self.settings, order=self.order,
                       seed=self.seed).tune(
            benchmark,
            backend=SimulatedShardedBackend(self.n_workers),
            cache=cache)
        return DistributedTuningResult(
            best_config=result.best_config, best_score=result.best_score,
            trials=result.trials,
            total_samples=result.total_samples,
            serial_time_s=result.serial_time_s,
            parallel_time_s=result.parallel_time_s,
            n_workers=self.n_workers,
            n_pruned=result.n_pruned)


def replicated_evaluate(make_invocation: InvocationFactory,
                        settings: EvaluationSettings, n_workers: int,
                        confidence: float = 0.99,
                        ) -> tuple[Interval, welford.WelfordState, float]:
    """Evaluate ONE configuration on ``n_workers`` concurrent workers and
    merge their sample streams exactly. Returns (CI of merged mean, merged
    state, simulated parallel wall-clock)."""
    evaluator = Evaluator(settings)
    partials = []
    wall = 0.0
    for _ in range(n_workers):
        t0 = time.perf_counter()
        res = evaluator.evaluate(make_invocation)
        wall = max(wall, time.perf_counter() - t0)
        for inv in res.invocations:
            # each invocation's full (n, mean, M2) — the merge is exact
            partials.append(welford.WelfordState(
                count=float(inv.count), mean=inv.mean, m2=inv.m2))
    merged = welford.tree_merge(partials)
    return ci_mean(merged, confidence), merged, wall

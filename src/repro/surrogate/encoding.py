"""Config → feature-vector encoding for model-guided search.

Surrogate models need numeric inputs; :class:`~repro.core.searchspace.Param`
domains are ordered but arbitrary (powers of two, the paper's 500-doubling
leading dimensions, or categorical flags). The encoding deliberately uses
the *level index* within each parameter's ordered domain, not the raw
value: the paper's spaces are geometric ladders (Sec. IV-A), so raw values
would compress the small end of every ladder into a corner of feature
space, while level indices spread the paper's 4×4×6 reduced DGEMM grid
uniformly. Parameters whose domain is non-numeric get a one-hot block
instead — there is no meaningful order-distance between ``"nmk"`` and
``"nkm"`` loop orders even though the domain tuple is ordered.

Features are scaled to [0, 1] per block, so distance-based surrogates
(:class:`~repro.surrogate.model.KNNSurrogate`) weigh every parameter
equally regardless of domain size.

**Shape features** (the sweep layer, :mod:`repro.sweep`): an encoder built
with a ``shape_space`` appends one block per shape parameter so a single
surrogate can learn the joint shape×config surface. Unlike config levels —
which are exact lookups raising ``KeyError`` off-domain — numeric shape
features are *continuous*: the value's position on the domain's log scale
(linear when the domain spans zero or negatives), clamped to [0, 1]. An
unseen shape between two tuned grid points lands between their features,
which is exactly what lets :class:`~repro.sweep.oracle.ConfigOracle`
interpolate "best config for a shape nobody tuned".
"""

from __future__ import annotations

import math
import numbers
from typing import Optional, Sequence

import numpy as np

from repro.core.searchspace import Config, Param, SearchSpace

__all__ = ["SpaceEncoder", "is_ordinal"]


def is_ordinal(param: Param) -> bool:
    """True iff every domain value is a real number (bools excluded):
    the level index is then a meaningful 1-D coordinate."""
    return all(isinstance(v, numbers.Real) and not isinstance(v, bool)
               for v in param.values)


def _numeric(v: object) -> bool:
    return isinstance(v, numbers.Real) and not isinstance(v, bool)


class _ShapeAxis:
    """Continuous [0, 1] coordinate for one numeric shape parameter.

    Geometric ladders (the common case: matrix dims, working-set bytes)
    get a log scale so the feature is linear in the *level*, matching how
    config levels encode; domains touching zero or negatives fall back to
    linear. Values outside [lo, hi] clamp to the boundary — an
    extrapolated shape is "at the edge of what was tuned", not an error.
    """

    def __init__(self, values: Sequence):
        lo, hi = min(values), max(values)
        self.lo, self.hi = float(lo), float(hi)
        self.log = self.lo > 0.0 and self.hi > self.lo

    def coord(self, v: object) -> float:
        if not _numeric(v):
            raise KeyError(f"non-numeric shape value {v!r}")
        v = float(v)
        if self.hi == self.lo:
            return 0.0
        if self.log:
            if v <= 0.0:
                return 0.0
            t = ((math.log(v) - math.log(self.lo))
                 / (math.log(self.hi) - math.log(self.lo)))
        else:
            t = (v - self.lo) / (self.hi - self.lo)
        return min(max(t, 0.0), 1.0)


class SpaceEncoder:
    """Maps :class:`SearchSpace` configurations to fixed-width float64
    feature vectors.

    Ordinal parameters contribute one feature: their level index
    normalized to [0, 1] (a single-value domain encodes as 0). Categorical
    parameters contribute one 0/1 feature per level. The encoding is a
    pure function of the space's declared params, so two encoders over
    the same space agree feature-for-feature.

    With a ``shape_space``, every vector additionally carries that space's
    shape features (see module docstring) and :meth:`encode` requires the
    ``shape`` argument. ``config_dim`` is the width of the config block
    alone; ``dim`` includes the shape block.
    """

    def __init__(self, space: SearchSpace,
                 shape_space: Optional[SearchSpace] = None):
        self.space = space
        self.shape_space = shape_space
        self._ordinal: dict[str, dict[object, float]] = {}
        self._onehot: dict[str, dict[object, int]] = {}
        names: list[str] = []
        offset = 0
        self._offsets: dict[str, int] = {}
        for p in space.params:
            self._offsets[p.name] = offset
            if is_ordinal(p):
                denom = max(len(p.values) - 1, 1)
                self._ordinal[p.name] = {v: i / denom
                                         for i, v in enumerate(p.values)}
                names.append(p.name)
                offset += 1
            else:
                self._onehot[p.name] = {v: i for i, v in enumerate(p.values)}
                names.extend(f"{p.name}={v}" for v in p.values)
                offset += len(p.values)
        self.config_dim = offset
        # shape block: continuous axes for numeric shape params, one-hot
        # for categorical ones (a categorical "shape" cannot interpolate,
        # but it can still condition the model)
        self._shape_axes: dict[str, _ShapeAxis] = {}
        self._shape_onehot: dict[str, dict[object, int]] = {}
        self._shape_offsets: dict[str, int] = {}
        if shape_space is not None:
            for p in shape_space.params:
                self._shape_offsets[p.name] = offset
                if is_ordinal(p):
                    self._shape_axes[p.name] = _ShapeAxis(p.values)
                    names.append(f"shape:{p.name}")
                    offset += 1
                else:
                    self._shape_onehot[p.name] = {v: i for i, v
                                                  in enumerate(p.values)}
                    names.extend(f"shape:{p.name}={v}" for v in p.values)
                    offset += len(p.values)
        self.feature_names: tuple[str, ...] = tuple(names)
        self.dim = offset

    def encode(self, config: Config,
               shape: Optional[Config] = None) -> np.ndarray:
        """One configuration as a (dim,) float64 vector. Raises
        ``KeyError`` for config values outside the declared domains —
        encode in-space configs only (project foreign seeds first).
        Numeric shape values may fall anywhere (unseen shapes clamp to
        the tuned range); categorical shape values must be in-domain."""
        if self.shape_space is not None and shape is None:
            raise TypeError("encoder built with a shape_space requires "
                            "encode(config, shape=...)")
        x = np.zeros(self.dim, dtype=np.float64)
        for p in self.space.params:
            v = config[p.name]
            base = self._offsets[p.name]
            levels = self._ordinal.get(p.name)
            if levels is not None:
                x[base] = levels[v]
            else:
                x[base + self._onehot[p.name][v]] = 1.0
        if self.shape_space is not None:
            for p in self.shape_space.params:
                v = shape[p.name]
                base = self._shape_offsets[p.name]
                axis = self._shape_axes.get(p.name)
                if axis is not None:
                    x[base] = axis.coord(v)
                else:
                    x[base + self._shape_onehot[p.name][v]] = 1.0
        return x

    def shape_features(self, shape: Config) -> np.ndarray:
        """Just the shape block of :meth:`encode` — the coordinate the
        oracle's nearest-tuned-shape fallback measures distance in."""
        if self.shape_space is None:
            return np.zeros(0, dtype=np.float64)
        x = np.zeros(self.dim - self.config_dim, dtype=np.float64)
        for p in self.shape_space.params:
            v = shape[p.name]
            base = self._shape_offsets[p.name] - self.config_dim
            axis = self._shape_axes.get(p.name)
            if axis is not None:
                x[base] = axis.coord(v)
            else:
                x[base + self._shape_onehot[p.name][v]] = 1.0
        return x

    def decode(self, x: np.ndarray) -> Config:
        """Nearest in-domain configuration for a feature vector's config
        block: ordinal features snap to the closest level, one-hot blocks
        take their argmax. Exact inverse of :meth:`encode` for encoded
        in-space configs (shape features, if any, are ignored)."""
        x = np.asarray(x, dtype=np.float64)
        cfg: Config = {}
        for p in self.space.params:
            base = self._offsets[p.name]
            if p.name in self._ordinal:
                denom = max(len(p.values) - 1, 1)
                i = int(round(float(x[base]) * denom))
                cfg[p.name] = p.values[min(max(i, 0), len(p.values) - 1)]
            else:
                block = x[base:base + len(p.values)]
                cfg[p.name] = p.values[int(np.argmax(block))]
        return cfg

    def encode_all(self, configs: Sequence[Config],
                   shape: Optional[Config] = None) -> np.ndarray:
        """Stack of :meth:`encode` rows, shape (len(configs), dim)."""
        if not configs:
            return np.zeros((0, self.dim), dtype=np.float64)
        return np.stack([self.encode(c, shape=shape) for c in configs])

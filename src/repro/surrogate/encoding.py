"""Config → feature-vector encoding for model-guided search.

Surrogate models need numeric inputs; :class:`~repro.core.searchspace.Param`
domains are ordered but arbitrary (powers of two, the paper's 500-doubling
leading dimensions, or categorical flags). The encoding deliberately uses
the *level index* within each parameter's ordered domain, not the raw
value: the paper's spaces are geometric ladders (Sec. IV-A), so raw values
would compress the small end of every ladder into a corner of feature
space, while level indices spread the paper's 4×4×6 reduced DGEMM grid
uniformly. Parameters whose domain is non-numeric get a one-hot block
instead — there is no meaningful order-distance between ``"nmk"`` and
``"nkm"`` loop orders even though the domain tuple is ordered.

Features are scaled to [0, 1] per block, so distance-based surrogates
(:class:`~repro.surrogate.model.KNNSurrogate`) weigh every parameter
equally regardless of domain size.
"""

from __future__ import annotations

import numbers
from typing import Sequence

import numpy as np

from repro.core.searchspace import Config, Param, SearchSpace

__all__ = ["SpaceEncoder", "is_ordinal"]


def is_ordinal(param: Param) -> bool:
    """True iff every domain value is a real number (bools excluded):
    the level index is then a meaningful 1-D coordinate."""
    return all(isinstance(v, numbers.Real) and not isinstance(v, bool)
               for v in param.values)


class SpaceEncoder:
    """Maps :class:`SearchSpace` configurations to fixed-width float64
    feature vectors.

    Ordinal parameters contribute one feature: their level index
    normalized to [0, 1] (a single-value domain encodes as 0). Categorical
    parameters contribute one 0/1 feature per level. The encoding is a
    pure function of the space's declared params, so two encoders over
    the same space agree feature-for-feature.
    """

    def __init__(self, space: SearchSpace):
        self.space = space
        self._ordinal: dict[str, dict[object, float]] = {}
        self._onehot: dict[str, dict[object, int]] = {}
        names: list[str] = []
        offset = 0
        self._offsets: dict[str, int] = {}
        for p in space.params:
            self._offsets[p.name] = offset
            if is_ordinal(p):
                denom = max(len(p.values) - 1, 1)
                self._ordinal[p.name] = {v: i / denom
                                         for i, v in enumerate(p.values)}
                names.append(p.name)
                offset += 1
            else:
                self._onehot[p.name] = {v: i for i, v in enumerate(p.values)}
                names.extend(f"{p.name}={v}" for v in p.values)
                offset += len(p.values)
        self.feature_names: tuple[str, ...] = tuple(names)
        self.dim = offset

    def encode(self, config: Config) -> np.ndarray:
        """One configuration as a (dim,) float64 vector. Raises
        ``KeyError`` for values outside the declared domains — encode
        in-space configs only (project foreign seeds first)."""
        x = np.zeros(self.dim, dtype=np.float64)
        for p in self.space.params:
            v = config[p.name]
            base = self._offsets[p.name]
            levels = self._ordinal.get(p.name)
            if levels is not None:
                x[base] = levels[v]
            else:
                x[base + self._onehot[p.name][v]] = 1.0
        return x

    def encode_all(self, configs: Sequence[Config]) -> np.ndarray:
        """Stack of :meth:`encode` rows, shape (len(configs), dim)."""
        if not configs:
            return np.zeros((0, self.dim), dtype=np.float64)
        return np.stack([self.encode(c) for c in configs])

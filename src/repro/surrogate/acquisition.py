"""Acquisition functions: where should the next trial be spent?

Given a surrogate's predictive (mean, std) per candidate, an acquisition
function scores how much a trial there is worth. Both implementations
reuse the CI machinery in :mod:`repro.core.confidence` so acquisition
respects the paper's noise model rather than inventing its own:

  * **UCB** uses the same normal quantile the paper's stop conditions use
    — ``kappa = normal_quantile(confidence)`` — so "optimistic" means
    exactly "the edge of the (one-sided) confidence band" at the
    confidence level the evaluation settings already declare.
  * **Expected Improvement** is computed against a *noise-adjusted*
    incumbent: :func:`noise_adjusted_best` pushes the reference to the
    incumbent's own CI bound facing the search direction
    (:func:`repro.core.confidence.ci_mean` over the incumbent trial's
    pooled Welford moments). A candidate must therefore promise
    improvement beyond the band the incumbent's score could wander in
    from measurement noise alone — the same reasoning behind the paper's
    stop condition 4 — and the default exploration margin ``xi`` is the
    settings' ``rel_margin`` (the paper's 1% CI-convergence threshold).

Scores are always "higher is better" regardless of the tuning direction;
minimization is handled by sign-flipping means internally.
"""

from __future__ import annotations

import math
from typing import Optional

import numpy as np

from repro.core.confidence import ci_mean, normal_quantile
from repro.core.stop_conditions import Direction
from repro.core.welford import WelfordState

__all__ = ["expected_improvement", "noise_adjusted_best", "normal_cdf",
           "normal_pdf", "upper_confidence_bound"]


def normal_pdf(z: np.ndarray) -> np.ndarray:
    return np.exp(-0.5 * np.square(z)) / math.sqrt(2.0 * math.pi)


_erf = np.vectorize(math.erf, otypes=[np.float64])


def normal_cdf(z: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + _erf(np.asarray(z) / math.sqrt(2.0)))


def _signed(mean: np.ndarray, direction: Direction) -> np.ndarray:
    """Fold direction into the mean: after this, bigger is better."""
    return np.asarray(mean, dtype=np.float64) \
        if direction is Direction.MAXIMIZE else -np.asarray(mean,
                                                            dtype=np.float64)


def noise_adjusted_best(state: WelfordState, confidence: float,
                        direction: Direction) -> float:
    """The incumbent reference EI should beat: the CI bound of the
    incumbent's own sample stream facing the search direction (upper for
    maximize, lower for minimize). With fewer than two samples the CI is
    unbounded, so the mean itself is returned."""
    interval = ci_mean(state, confidence)
    bound = interval.hi if direction is Direction.MAXIMIZE else interval.lo
    return float(bound) if math.isfinite(bound) else float(interval.mean)


def expected_improvement(mean: np.ndarray, std: np.ndarray, best: float,
                         direction: Direction = Direction.MAXIMIZE,
                         xi: float = 0.01) -> np.ndarray:
    """E[max(improvement over ``best``, 0)] under the surrogate's normal
    predictive distribution. ``xi`` is the relative exploration margin —
    pass the settings' ``rel_margin`` so "improvement" means the same
    thing as the paper's CI-convergence threshold."""
    mu = _signed(mean, direction)
    best_s = best if direction is Direction.MAXIMIZE else -best
    std = np.maximum(np.asarray(std, dtype=np.float64), 0.0)
    target = best_s + xi * abs(best_s)
    delta = mu - target
    out = np.maximum(delta, 0.0)
    pos = std > 0
    z = delta[pos] / std[pos]
    out[pos] = delta[pos] * normal_cdf(z) + std[pos] * normal_pdf(z)
    return out


def upper_confidence_bound(mean: np.ndarray, std: np.ndarray,
                           direction: Direction = Direction.MAXIMIZE,
                           confidence: float = 0.99,
                           kappa: Optional[float] = None) -> np.ndarray:
    """Optimism in the face of uncertainty at the paper's confidence
    level: mean + kappa·std (sign-folded), kappa the one-sided normal
    quantile of ``confidence`` unless given explicitly."""
    if kappa is None:
        kappa = normal_quantile(confidence)
    return _signed(mean, direction) \
        + kappa * np.maximum(np.asarray(std, dtype=np.float64), 0.0)

"""Model-guided search strategies on the ask/tell protocol.

The paper cuts search cost by *reducing the space* and *terminating
evaluations early*; every strategy the repo shipped before this module
still proposes configurations blindly. "From Roofline to Ruggedness"
shows GEMM landscapes are rugged enough that proposal order matters, and
the kernel-tuner benchmarking suite literature treats Bayesian/bandit
searchers as the baseline competitive tuners. These two strategies close
that gap — through the same :class:`~repro.core.tuner.Tuner` engine,
backends, cache, and transfer plumbing as every other strategy (the
engine needed no changes; that is what the ask/tell layer is for):

  * :class:`SurrogateStrategy` — fit a surrogate
    (:mod:`~repro.surrogate.model`) to observed scores, rank unevaluated
    configurations by acquisition (:mod:`~repro.surrogate.acquisition`),
    propose the top-k, update the model on every ``tell``. Warm-start
    seeds (``TrialCache.suggest_seeds`` → ``Tuner.tune(seeds=...)``)
    are evaluated first and become the model's first observations.
  * :class:`BanditStrategy` — Thompson-style sampling over
    parameter-level arms: each (param, value) pair keeps Welford moments
    of the scores of configurations containing it, and proposals compose
    a config by drawing one posterior sample per arm and taking each
    parameter's best draw. Never enumerates the space — the policy for
    cardinalities where even materializing the config list is off-budget.

Both are deterministic under a fixed seed (numpy ``default_rng``; no
wall-clock anywhere), so cached reruns and golden tests stay honest.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core import welford
from repro.core.cache import config_key
from repro.core.evaluator import EvalResult, EvaluationSettings
from repro.core.executor import Batch
from repro.core.searchspace import Config, SearchSpace
from repro.core.stop_conditions import Direction
from repro.core.strategy import SearchStrategy
from repro.core.welford import WelfordState

from .acquisition import (expected_improvement, noise_adjusted_best,
                          upper_confidence_bound)
from .encoding import SpaceEncoder
from .model import make_surrogate

__all__ = ["BanditStrategy", "SurrogateStrategy"]


def _pooled_state(result: EvalResult) -> WelfordState:
    """The trial's sample stream as one WelfordState (exact Chan merge of
    the stored per-invocation moments — same pooling the ledger uses)."""
    return welford.tree_merge([
        WelfordState(count=float(i.count), mean=i.mean, m2=i.m2)
        for i in result.invocations])


class SurrogateStrategy(SearchStrategy):
    """Surrogate-guided proposal order: ask = top-k acquisition over the
    unevaluated configurations, tell = incremental model update.

    ``budget`` caps proposals (``None`` — run until the space is
    exhausted: the model then only *orders* the sweep, which still pays
    off because a good incumbent found early tightens stop-condition-4
    pruning for everything after it). ``n_init`` seeds the model with a
    space-filling random sample before acquisition takes over (default:
    enough points to make the default surrogate identifiable, at least
    3). ``batch`` is the proposal width when the backend imposes no round
    structure (``ask(None)``); round-synchronized backends get their own
    round width. ``model`` picks the surrogate ("auto" | "ridge" |
    "knn"), ``acquisition`` the scoring rule ("ei" | "ucb") — EI measures
    improvement against the incumbent's own CI bound, UCB is optimism at
    the settings' confidence level (see :mod:`~repro.surrogate.acquisition`).
    """

    name = "surrogate"

    def __init__(self, budget: Optional[int] = None,
                 n_init: Optional[int] = None,
                 batch: Optional[int] = None,
                 model: str = "auto", acquisition: str = "ei",
                 seed: Optional[int] = None):
        if budget is not None and budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        if n_init is not None and n_init < 1:
            raise ValueError(f"n_init must be >= 1, got {n_init}")
        if batch is not None and batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        if acquisition not in ("ei", "ucb"):
            raise ValueError(f"unknown acquisition {acquisition!r} "
                             "(ei | ucb)")
        self.budget = budget
        self.n_init = n_init
        self.batch = batch
        self.model = model
        self.acquisition = acquisition
        self.seed = seed

    # -- subclass hooks (the sweep layer re-targets these) -------------------
    def _make_encoder(self, space: SearchSpace) -> SpaceEncoder:
        """The feature encoder ``reset`` installs. Subclasses may return
        an encoder over a *wider* feature space (e.g. joint shape×config,
        :class:`~repro.sweep.strategy.SweepStrategy`) as long as
        :meth:`_encode` agrees with it."""
        return SpaceEncoder(space)

    def _encode(self, config: Config) -> np.ndarray:
        """Feature vector of one config under the installed encoder."""
        return self._encoder.encode(config)

    def _prior_observations(self):
        """(x, y) pairs fed to the surrogate at reset, before any trial of
        this run — empty by default. Subclasses yield transfer knowledge
        here (cached trials of sibling shapes under the same hardware
        fingerprint); a warmed model skips the random-exploration phase
        and shrinks the default initial design to a local anchor."""
        return ()

    def reset(self, space: SearchSpace, settings: EvaluationSettings,
              seeds: Sequence[Config] = ()) -> None:
        self._direction: Direction = settings.direction
        self._confidence = settings.confidence
        self._xi = settings.rel_margin
        self._encoder = self._make_encoder(space)
        self._configs = space.ordered("exhaustive")
        self._X = (np.stack([self._encode(c) for c in self._configs])
                   if self._configs
                   else np.zeros((0, self._encoder.dim), dtype=np.float64))
        self._index = {config_key(c): i for i, c in enumerate(self._configs)}
        self._surrogate = make_surrogate(self.model, self._encoder.dim,
                                         len(self._configs))
        priors = list(self._prior_observations())
        if priors:
            self._surrogate.observe_many(
                np.stack([x for x, _ in priors]), [y for _, y in priors])
        self._n_priors = len(priors)
        self._rng = np.random.default_rng(
            self.seed if self.seed is not None else 0)
        self._unproposed = set(range(len(self._configs)))
        self._proposed = 0
        self._best: Optional[tuple[float, WelfordState]] = None
        self._done = not self._configs

        # initial design: seeds first (deduplicated), then a random
        # space-filling sample
        seed_idx: list[int] = []
        seen: set[int] = set()
        for cfg in seeds:
            i = self._index.get(config_key(cfg))
            if i is not None and i not in seen:
                seen.add(i)
                seed_idx.append(i)
        if self.n_init is not None:
            want = self.n_init
        elif self._n_priors:
            # the priors already identify the model: two fresh anchor
            # points re-ground it in this run's own measurements and the
            # acquisition takes over
            want = 2
        else:
            want = max(3, 2 * self._encoder.dim + 1)
        pool = sorted(self._unproposed - seen)
        fill = max(0, want - len(seed_idx))
        if fill and pool:
            picks = self._rng.choice(len(pool), size=min(fill, len(pool)),
                                     replace=False)
            seed_idx.extend(pool[int(i)] for i in sorted(picks))
        self._init_queue = seed_idx

    def _budget_left(self) -> Optional[int]:
        return None if self.budget is None else self.budget - self._proposed

    def _take(self, idx: list[int]) -> Optional[Batch]:
        if not idx:
            return None
        self._unproposed.difference_update(idx)
        self._proposed += len(idx)
        return Batch(tuple(self._configs[i] for i in idx))

    def _width(self, n: Optional[int]) -> int:
        width = n if n else (self.batch or 1)
        left = self._budget_left()
        if left is not None:
            width = min(width, left)
        return min(width, len(self._unproposed))

    def ask(self, n: Optional[int]) -> Optional[Batch]:
        if self._done:
            return None
        left = self._budget_left()
        if (left is not None and left <= 0) or not self._unproposed:
            self._done = True
            return None
        k = self._width(n)
        if k < 1:
            self._done = True
            return None
        if self._init_queue:
            take = [i for i in self._init_queue[:k] if i in self._unproposed]
            del self._init_queue[:k]
            if take:
                return self._take(take)
            # every queued init config was already proposed — fall through
        if self._surrogate.n_observed == 0:
            # nothing to model yet (e.g. every outcome so far was pruned):
            # keep exploring at random rather than ranking on the prior
            pool = sorted(self._unproposed)
            picks = self._rng.choice(len(pool), size=min(k, len(pool)),
                                     replace=False)
            return self._take([pool[int(i)] for i in sorted(picks)])
        pool = sorted(self._unproposed)
        mean, std = self._surrogate.predict(self._X[pool])
        if self.acquisition == "ucb":
            scores = upper_confidence_bound(mean, std, self._direction,
                                            confidence=self._confidence)
        else:
            best = self._best_reference(float(np.max(mean))
                                        if self._direction is
                                        Direction.MAXIMIZE
                                        else float(np.min(mean)))
            scores = expected_improvement(mean, std, best, self._direction,
                                          xi=self._xi)
        order = np.lexsort((np.arange(len(pool)), -scores))
        return self._take([pool[int(i)] for i in order[:k]])

    def _best_reference(self, fallback: float) -> float:
        """EI's incumbent reference: the best observed trial's
        noise-adjusted CI bound; the surrogate's own best mean before any
        unpruned outcome exists."""
        if self._best is None:
            return fallback
        score, state = self._best
        if state.count >= 2:
            return noise_adjusted_best(state, self._confidence,
                                       self._direction)
        return score

    def tell(self, config: Config, result: EvalResult) -> None:
        i = self._index.get(config_key(config))
        if i is not None:
            self._unproposed.discard(i)   # cache-served outside our asks
        # Pruned trials feed the model too: a truncated stream's mean is an
        # unbiased (merely noisier) estimate, and under the paper's stop
        # condition 4 *most* trials are pruned — discarding them would
        # starve the surrogate. They are only barred from selection: a
        # truncated estimate never becomes the incumbent reference.
        x = self._X[i] if i is not None else self._encode(config)
        self._surrogate.observe(x, result.score)
        if result.pruned:
            return
        if self._best is None or self._direction.better(result.score,
                                                        self._best[0]):
            self._best = (result.score, _pooled_state(result))


class BanditStrategy(SearchStrategy):
    """Thompson-style sampling over parameter-level arms, for spaces too
    large to enumerate.

    Every (param, value) pair is an arm carrying Welford moments of the
    scores of configurations that used it. A proposal draws one posterior
    sample per arm — Normal(mean, s/√n) for played arms, an optimistic
    wide draw around the global mean for unplayed ones — and composes the
    configuration from each parameter's best draw, so information from
    every trial generalizes across the whole axis (the additive-effects
    assumption; cheap, and wrong in exactly the ways
    :class:`SurrogateStrategy`'s quadratic cross terms are not — pick per
    space size). Nothing here enumerates or materializes the space:
    memory is O(Σ|domain|), proposals are rejection-sampled against the
    constraints and the visited set.
    """

    name = "bandit"

    #: consecutive failed proposal draws before the strategy concludes the
    #: unvisited feasible space is (effectively) exhausted
    MAX_ATTEMPTS = 128

    def __init__(self, budget: Optional[int] = None,
                 batch: Optional[int] = None,
                 seed: Optional[int] = None):
        if budget is not None and budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        if batch is not None and batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.budget = budget
        self.batch = batch
        self.seed = seed

    def reset(self, space: SearchSpace, settings: EvaluationSettings,
              seeds: Sequence[Config] = ()) -> None:
        self._space = space
        self._direction = settings.direction
        self._rng = np.random.default_rng(
            self.seed if self.seed is not None else 0)
        self._arms: dict[tuple[str, object], WelfordState] = {}
        self._global = welford.init()
        self._visited: set[str] = set()
        self._proposed = 0
        self._done = False
        self._pending: list[Config] = []
        pending_keys: set[str] = set()
        for cfg in seeds:
            key = config_key(cfg)
            if key not in pending_keys:
                pending_keys.add(key)
                self._pending.append(cfg)

    def _budget_left(self) -> Optional[int]:
        return None if self.budget is None else self.budget - self._proposed

    def _draw_value(self, param, value) -> float:
        arm = self._arms.get((param.name, value))
        g_n = float(self._global.count)
        g_mean = float(self._global.mean) if g_n else 0.0
        g_std = float(self._global.std) if g_n >= 2 else 1.0
        g_std = g_std if g_std > 0 else 1.0
        if arm is None or arm.count < 1:
            # unplayed arm: optimistic wide draw around the global mean
            return g_mean + 2.0 * g_std * float(self._rng.standard_normal())
        n = float(arm.count)
        s = float(arm.std) if n >= 2 else g_std
        s = s if s > 0 else g_std
        return float(arm.mean) + (s / np.sqrt(n)) \
            * float(self._rng.standard_normal())

    def _compose(self) -> Optional[Config]:
        """One Thompson proposal; None when MAX_ATTEMPTS consecutive
        draws failed to produce a fresh feasible configuration."""
        maximize = self._direction is Direction.MAXIMIZE
        for attempt in range(self.MAX_ATTEMPTS):
            cfg: Config = {}
            for p in self._space.params:
                if attempt < self.MAX_ATTEMPTS // 2:
                    draws = [(self._draw_value(p, v), v) for v in p.values]
                    choose = max if maximize else min
                    pick = choose(draws, key=lambda dv: dv[0])[1]
                else:
                    # pure random tail: escape a constraint-locked or
                    # fully-visited Thompson mode
                    pick = p.values[int(self._rng.integers(len(p.values)))]
                cfg[p.name] = pick
            key = config_key(cfg)
            if key in self._visited or not self._space.satisfies(cfg):
                continue
            self._visited.add(key)   # reserve: proposed counts as visited
            return cfg
        return None

    def ask(self, n: Optional[int]) -> Optional[Batch]:
        if self._done:
            return None
        width = n if n else (self.batch or 1)
        left = self._budget_left()
        if left is not None:
            if left <= 0:
                self._done = True
                return None
            width = min(width, left)
        out: list[Config] = []
        while self._pending and len(out) < width:
            cfg = self._pending.pop(0)
            key = config_key(cfg)
            if key in self._visited:
                continue
            self._visited.add(key)
            out.append(cfg)
        while len(out) < width:
            cfg = self._compose()
            if cfg is None:
                break
            out.append(cfg)
        if not out:
            self._done = True
            return None
        self._proposed += len(out)
        return Batch(tuple(out))

    def tell(self, config: Config, result: EvalResult) -> None:
        self._visited.add(config_key(config))
        # pruned scores update the arms too (unbiased truncated estimates;
        # see SurrogateStrategy.tell) — they just never become incumbents
        y = float(result.score)
        self._global = welford.update(self._global, y)
        for p in self._space.params:
            v = config.get(p.name)
            arm = self._arms.get((p.name, v), welford.init())
            self._arms[(p.name, v)] = welford.update(arm, y)

"""Pure-numpy surrogate models with predictive uncertainty.

Two surrogates, one protocol (``observe`` / ``predict`` / ``n_observed``),
no dependencies beyond numpy:

  * :class:`BayesianRidgeSurrogate` — Bayesian linear regression on a
    degree-2 polynomial expansion of the encoded features, maintained
    *incrementally*: ``observe`` folds one (x, y) pair into the Gram
    sufficient statistics (Φᵀ Φ, Φᵀ y) in O(D²), and the posterior is
    solved lazily when ``predict`` is next called. Targets are
    standardized internally against the running Welford moments of the
    observed scores, so the prior/noise scales are unitless and one
    default works for GFLOP/s and GB/s objectives alike. The predictive
    variance ``σ²_noise + φᵀ S φ`` grows away from observed data — the
    uncertainty the acquisition functions spend.
  * :class:`KNNSurrogate` — distance-weighted k-nearest-neighbor
    regression. The fallback for tiny spaces, where a quadratic fit has
    more coefficients than the space has configurations: prediction is
    the inverse-distance-weighted mean of the k nearest observations, and
    the predictive std combines the neighbors' weighted spread with a
    term growing in the distance to the nearest neighbor (far from all
    data ⇒ uncertain), floored by the observed score spread so
    exploration never collapses prematurely.

:func:`make_surrogate` picks between them: ridge when the space is large
enough to support the quadratic fit, k-NN below that.
"""

from __future__ import annotations

import numpy as np

from repro.core import welford

__all__ = ["BayesianRidgeSurrogate", "KNNSurrogate", "Surrogate",
           "make_surrogate", "poly_dim"]


def _poly_features(X: np.ndarray) -> np.ndarray:
    """Degree-2 polynomial expansion: [1, x_i, x_i·x_j (i ≤ j)]."""
    X = np.atleast_2d(np.asarray(X, dtype=np.float64))
    n, d = X.shape
    cols = [np.ones((n, 1)), X]
    for i in range(d):
        cols.append(X[:, i:i + 1] * X[:, i:])
    return np.concatenate(cols, axis=1)


def poly_dim(dim: int) -> int:
    """Feature count of the degree-2 expansion over ``dim`` inputs."""
    return 1 + dim + dim * (dim + 1) // 2


class Surrogate:
    """The model protocol :class:`~repro.surrogate.strategy.SurrogateStrategy`
    drives: feed outcomes with ``observe``, rank candidates with
    ``predict``."""

    name: str = "base"

    @property
    def n_observed(self) -> int:
        raise NotImplementedError

    def observe(self, x: np.ndarray, y: float) -> None:
        raise NotImplementedError

    def observe_many(self, X: np.ndarray, y) -> None:
        """Fold a batch of (x, y) pairs into the model — how prior
        observations (cached trials of sibling shapes, see
        :class:`~repro.sweep.strategy.SweepStrategy`) warm a fresh
        surrogate before its first ask."""
        X = np.atleast_2d(np.asarray(X, dtype=np.float64))
        for x, yi in zip(X, y):
            self.observe(x, float(yi))

    def predict(self, X: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(mean, std) per row of ``X``, in original target units."""
        raise NotImplementedError


class BayesianRidgeSurrogate(Surrogate):
    """Incremental Bayesian ridge regression on polynomial features.

    Posterior over weights w with prior N(0, α⁻¹I) and Gaussian noise
    precision β: S = (αI + β ΦᵀΦ)⁻¹, m = β S Φᵀt. Sufficient statistics
    accumulate per observation; the solve is deferred and cached until
    the next ``observe`` invalidates it. Standardization of targets is
    affine, so the standardized Gram vector Φᵀt is recovered exactly from
    the raw accumulators (Φᵀy, Σφ) and the running target moments — no
    replay of past observations is ever needed.
    """

    name = "ridge"

    def __init__(self, dim: int, alpha: float = 1e-2, noise: float = 1e-2):
        if alpha <= 0 or noise <= 0:
            raise ValueError("alpha and noise must be positive")
        self.dim = dim
        self.alpha = alpha
        self.noise = noise                   # σ²_noise in standardized units
        d = poly_dim(dim)
        self._gram = np.zeros((d, d))        # Φᵀ Φ
        self._phi_y = np.zeros(d)            # Φᵀ y  (raw targets)
        self._phi_sum = np.zeros(d)          # Σ φ   (for standardization)
        self._y_state = welford.init()
        self._posterior: tuple[np.ndarray, np.ndarray] | None = None

    @property
    def n_observed(self) -> int:
        return int(self._y_state.count)

    def observe(self, x: np.ndarray, y: float) -> None:
        phi = _poly_features(x)[0]
        self._gram += np.outer(phi, phi)
        self._phi_y += phi * float(y)
        self._phi_sum += phi
        self._y_state = welford.update(self._y_state, float(y))
        self._posterior = None

    def _y_scale(self) -> tuple[float, float]:
        mu = float(self._y_state.mean) if self.n_observed else 0.0
        sigma = float(self._y_state.std) if self.n_observed >= 2 else 0.0
        return mu, (sigma if sigma > 0 else 1.0)

    def _solve(self) -> tuple[np.ndarray, np.ndarray]:
        if self._posterior is None:
            mu, sigma = self._y_scale()
            phi_t = (self._phi_y - mu * self._phi_sum) / sigma
            beta = 1.0 / self.noise
            d = self._gram.shape[0]
            cov = np.linalg.inv(self.alpha * np.eye(d) + beta * self._gram)
            self._posterior = (beta * cov @ phi_t, cov)
        return self._posterior

    def predict(self, X: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        phi = _poly_features(X)
        mu, sigma = self._y_scale()
        if self.n_observed == 0:
            n = phi.shape[0]
            prior_var = self.noise + np.einsum(
                "ij,ij->i", phi, phi / self.alpha)
            return np.full(n, mu), sigma * np.sqrt(prior_var)
        mean_w, cov = self._solve()
        mean = phi @ mean_w
        var = self.noise + np.einsum("ij,jk,ik->i", phi, cov, phi)
        return mu + sigma * mean, sigma * np.sqrt(np.maximum(var, 0.0))


class KNNSurrogate(Surrogate):
    """Distance-weighted k-NN regression — the tiny-space fallback."""

    name = "knn"

    def __init__(self, dim: int, k: int = 3, eps: float = 1e-9):
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        self.dim = dim
        self.k = k
        self.eps = eps
        self._X: list[np.ndarray] = []
        self._y: list[float] = []

    @property
    def n_observed(self) -> int:
        return len(self._y)

    def observe(self, x: np.ndarray, y: float) -> None:
        self._X.append(np.asarray(x, dtype=np.float64))
        self._y.append(float(y))

    def predict(self, X: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        X = np.atleast_2d(np.asarray(X, dtype=np.float64))
        n = X.shape[0]
        if not self._y:
            return np.zeros(n), np.ones(n)
        obs_x = np.stack(self._X)
        obs_y = np.asarray(self._y)
        spread = float(obs_y.std()) if len(self._y) >= 2 else 1.0
        spread = spread if spread > 0 else 1.0
        dist = np.sqrt(((X[:, None, :] - obs_x[None, :, :]) ** 2).sum(-1))
        k = min(self.k, len(self._y))
        idx = np.argsort(dist, axis=1)[:, :k]
        nd = np.take_along_axis(dist, idx, axis=1)
        ny = obs_y[idx]
        w = 1.0 / (nd + self.eps)
        w /= w.sum(axis=1, keepdims=True)
        mean = (w * ny).sum(axis=1)
        var = (w * (ny - mean[:, None]) ** 2).sum(axis=1)
        # distance-to-nearest term: far from every observation ⇒ uncertain,
        # scaled by the observed spread so units follow the objective
        d_near = nd[:, 0]
        std = np.sqrt(var + (spread * d_near) ** 2)
        return mean, np.maximum(std, 0.05 * spread)


#: below this cardinality the quadratic fit is typically underdetermined
#: relative to what the space can ever show it — k-NN explores better there
TINY_SPACE = 24


def make_surrogate(kind: str, dim: int, cardinality: int) -> Surrogate:
    """Build the surrogate ``kind`` ("ridge", "knn", or "auto") for a
    space with ``dim`` encoded features and ``cardinality`` configs."""
    if kind == "ridge":
        return BayesianRidgeSurrogate(dim)
    if kind == "knn":
        return KNNSurrogate(dim)
    if kind == "auto":
        if cardinality < max(TINY_SPACE, poly_dim(dim)):
            return KNNSurrogate(dim)
        return BayesianRidgeSurrogate(dim)
    raise ValueError(f"unknown surrogate kind {kind!r} "
                     "(ridge | knn | auto)")

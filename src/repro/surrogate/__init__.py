"""Model-guided search: surrogate + acquisition strategies on the
ask/tell layer.

The paper's techniques cut the *cost per configuration* (CI-convergence,
incumbent pruning) and the *space itself* (constraint reduction); this
package cuts the *number of configurations worth visiting* by learning
the landscape as the search runs. It plugs into the existing
:class:`~repro.core.strategy.SearchStrategy` protocol — the
:class:`~repro.core.tuner.Tuner` engine, execution backends, trial cache,
run ledger, and transfer-seed plumbing all work unchanged.

Layers (see ``docs/strategies.md`` § Model-guided search):

  * :mod:`~repro.surrogate.encoding` — configs → numeric feature vectors
    (ordinal level indices, one-hot categoricals);
  * :mod:`~repro.surrogate.model` — pure-numpy surrogates with predictive
    uncertainty (incremental Bayesian ridge on polynomial features, k-NN
    fallback for tiny spaces);
  * :mod:`~repro.surrogate.acquisition` — Expected Improvement and UCB,
    built on the CI machinery in :mod:`repro.core.confidence` so
    acquisition respects the paper's noise model;
  * :mod:`~repro.surrogate.strategy` — :class:`SurrogateStrategy`
    (batched top-k acquisition) and :class:`BanditStrategy`
    (parameter-level Thompson sampling for very large spaces).
"""

from .acquisition import (expected_improvement, noise_adjusted_best,
                          upper_confidence_bound)
from .encoding import SpaceEncoder, is_ordinal
from .model import (BayesianRidgeSurrogate, KNNSurrogate, Surrogate,
                    make_surrogate, poly_dim)
from .strategy import BanditStrategy, SurrogateStrategy

__all__ = [
    "BanditStrategy", "BayesianRidgeSurrogate", "KNNSurrogate",
    "SpaceEncoder", "Surrogate", "SurrogateStrategy",
    "expected_improvement", "is_ordinal", "make_surrogate",
    "noise_adjusted_best", "poly_dim", "upper_confidence_bound",
]

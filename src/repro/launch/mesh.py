"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).

Mesh layout (TPU v5e pods of 256 chips):
  single pod : (data=16, model=16)
  multi-pod  : (pod=2, data=16, model=16)  — "pod" is pure DP; the gradient
               all-reduce over "pod" is the only traffic that crosses the
               inter-pod links.
"""

from __future__ import annotations

import jax

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over however many devices this host actually has (tests,
    the CPU training example)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    return make_mesh((n // model, model), ("data", "model"))

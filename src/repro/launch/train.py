"""End-to-end training driver (host mesh; the multi-pod path swaps the mesh
constructor only).

Fault tolerance in the loop:
  * checkpoint every ``--ckpt-every`` steps via the atomic manager;
  * on start, resume from the newest complete checkpoint (params, opt
    state, step counter) — the data pipeline is a pure function of the
    step so the token stream resumes exactly;
  * per-step wall-time watchdog flags stragglers (CI-based detection uses
    the same Welford machinery as the paper's stop conditions).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b \
      --smoke --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from .. import configs
from ..checkpoint import CheckpointManager
from ..core import welford
from ..core.confidence import ci_mean
from ..data import DataConfig, SyntheticLM
from ..distributed import sharding as sh
from ..models import params as params_lib
from ..models.config import WorkloadShape
from ..models.transformer import StepConfig
from ..optim import adamw_init
from ..train.steps import build_train_step
from .mesh import make_host_mesh


def train(arch: str, steps: int = 100, batch: int = 8, seq: int = 256,
          smoke: bool = True, ckpt_dir: str | None = None,
          ckpt_every: int = 50, peak_lr: float = 3e-3,
          log_every: int = 10, straggler_factor: float = 3.0) -> dict:
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    shape = WorkloadShape("custom", seq, batch, "train")
    mesh = make_host_mesh()
    rules = sh.TRAIN_RULES
    step_cfg = StepConfig(remat=True, loss_chunk=min(128, seq))
    bundle = build_train_step(cfg, shape, mesh, rules, step_cfg,
                              peak_lr=peak_lr, total_steps=steps)
    step_fn = bundle.jitted()

    defs = __import__("repro.models.api", fromlist=["param_defs"]).param_defs(cfg)
    from ..optim import opt_state_defs
    manager = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    params = opt_state = None
    if manager is not None:
        restored = manager.restore_latest()
        if restored is not None:
            state, manifest = restored
            params, opt_state = state["params"], state["opt"]
            start_step = manifest["step"]
            print(f"[train] resumed from step {start_step}")
    if params is None:
        params = params_lib.materialize(jax.random.key(0), defs)
        opt_state = adamw_init(defs)

    pipeline = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size), batch, seq)
    losses = []
    # straggler watchdog: CI over observed step times (the paper's Welford)
    times = welford.init()
    for step in range(start_step, steps):
        batch_data = pipeline.batch_at(step)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch_data,
                                             np.int32(step))
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if times.count >= 5:
            interval = ci_mean(times, confidence=0.99)
            if dt > straggler_factor * max(interval.hi, 1e-9):
                print(f"[train] straggler step {step}: {dt:.3f}s vs "
                      f"CI hi {interval.hi:.3f}s")
        if step > 0:  # skip compile step in the stats
            times = welford.update(times, dt)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0:
            print(f"[train] step {step:5d} loss={losses[-1]:.4f} "
                  f"|g|={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
        if manager is not None and (step + 1) % ckpt_every == 0:
            manager.save(step + 1, {"params": params, "opt": opt_state})
    if manager is not None:
        manager.save(steps, {"params": params, "opt": opt_state})
    return {"losses": losses, "final_loss": losses[-1],
            "mean_step_s": float(times.mean) if times.count else None}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--peak-lr", type=float, default=3e-3)
    args = ap.parse_args()
    result = train(args.arch, steps=args.steps, batch=args.batch,
                   seq=args.seq, smoke=args.smoke, ckpt_dir=args.ckpt_dir,
                   ckpt_every=args.ckpt_every, peak_lr=args.peak_lr)
    print(f"[train] done: first loss {result['losses'][0]:.4f} -> "
          f"final {result['final_loss']:.4f}")


if __name__ == "__main__":
    main()

"""Batched serving driver: prefill a prompt batch, then greedy decode.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma_2b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..models import api
from ..models import params as params_lib
from ..models.config import WorkloadShape
from ..models.transformer import StepConfig
from ..train.steps import build_decode_step, build_prefill_step
from .mesh import make_host_mesh


def serve(arch: str, batch: int = 4, prompt_len: int = 32, gen: int = 16,
          smoke: bool = True, seed: int = 0) -> dict:
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    mesh = make_host_mesh()
    step_cfg = StepConfig(remat=False, loss_chunk=min(128, prompt_len))
    prefill_shape = WorkloadShape("serve_prefill", prompt_len, batch,
                                  "prefill")
    # decode cells allocate prompt+gen cache slots
    decode_shape = WorkloadShape("serve_decode", prompt_len + gen, batch,
                                 "decode")

    params = params_lib.materialize(jax.random.key(seed),
                                    api.param_defs(cfg))
    key = jax.random.key(seed + 1)
    batch_data = {"tokens": jax.random.randint(key, (batch, prompt_len), 0,
                                               cfg.vocab_size)}
    if cfg.family == "encdec":
        batch_data["frames"] = jax.random.normal(
            jax.random.fold_in(key, 1), (batch, cfg.n_frames, cfg.d_enc),
            cfg.jdtype)
    if cfg.family == "vlm":
        batch_data["image_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (batch, cfg.n_image_tokens, cfg.d_model), cfg.jdtype)

    prefill = build_prefill_step(cfg, prefill_shape, mesh,
                                 step_cfg=step_cfg).jitted()
    t0 = time.perf_counter()
    logits, cache = prefill(params, batch_data)
    # sync BOTH outputs: the KV cache is consumed by decode below, so a
    # logits-only sync would stop the prefill clock while cache writes
    # are still in flight (lint MS206)
    jax.block_until_ready((logits, cache))
    t_prefill = time.perf_counter() - t0
    cache = api.extend_cache(cache, gen)

    decode = build_decode_step(cfg, decode_shape, mesh,
                               step_cfg=step_cfg)
    # jit directly (cache shapes here come from the live prefill)
    decode_fn = jax.jit(decode.fn)

    tokens = jnp.argmax(logits[:, -1:, :cfg.vocab_size], axis=-1).astype(
        jnp.int32)
    generated = [tokens]
    t0 = time.perf_counter()
    for t in range(gen - 1):
        step_batch = dict(batch_data)
        step_batch["tokens"] = tokens
        logits, cache = decode_fn(params, step_batch, cache,
                                  jnp.int32(prompt_len + t))
        tokens = jnp.argmax(logits[:, :, :cfg.vocab_size],
                            axis=-1).astype(jnp.int32)
        generated.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t0
    out = jnp.concatenate(generated, axis=1)
    return {
        "tokens": np.asarray(out),
        "prefill_s": t_prefill,
        "decode_s_per_token": t_decode / max(gen - 1, 1),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    result = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                   gen=args.gen, smoke=args.smoke)
    print(f"[serve] generated shape {result['tokens'].shape} "
          f"prefill={result['prefill_s']*1e3:.0f}ms "
          f"decode={result['decode_s_per_token']*1e3:.1f}ms/token")
    print(result["tokens"][:2, :12])


if __name__ == "__main__":
    main()

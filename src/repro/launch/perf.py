import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing driver.

Re-runs one dry-run cell under explicit knob overrides (sharding rules,
remat policy, microbatches, loss chunk, ...) and prints the roofline-term
deltas vs the baseline — the measure step of the paper's
hypothesis -> change -> measure -> validate loop, with the dry-run cost
model as the measurement.

``--tune`` mode closes the loop with the paper's own machinery: the core
Tuner searches a small knob space using the dominant roofline term as the
(deterministic) objective, exactly the "autotune the benchmarking/execution
parameters" pattern, applied to the framework itself.

  PYTHONPATH=src python -m repro.launch.perf --arch mamba2_130m \
      --shape train_4k --tune
"""

import argparse
import json

from .. import configs
from ..core import Direction, EvaluationSettings, Tuner, grid
from ..models.config import SHAPES
from ..models.transformer import StepConfig
from .dryrun import run_cell


def term(record: dict, name: str) -> float:
    return record.get(f"{name}_ms", float("inf"))


def objective(record: dict) -> float:
    """Perfect-overlap step-time lower bound (max of the three terms)."""
    return max(record["compute_ms"], record["memory_ms"],
               record["collective_ms"])


def show(tag: str, r: dict) -> None:
    if r["status"] != "ok":
        print(f"[{tag}] {r['status']}: {r.get('error', '')}")
        return
    print(f"[{tag}] compute={r['compute_ms']}ms memory={r['memory_ms']}ms "
          f"collective={r['collective_ms']}ms -> {r['dominant']} "
          f"| useful={r['useful_flops_ratio']} mfu_bound={r['mfu_bound']} "
          f"peak={r['peak_gb']}GB")


# pure 256-way data parallelism + ZeRO: the right layout for sub-2B models
# on a 256-chip pod (TP collectives vanish; only grad sync + FSDP gathers)
DP_ONLY = {"batch": ("pod", "data", "model"), "heads": (), "mlp": (),
           "vocab": (), "ssm_inner": (), "act_seq": ()}

PRESETS = {"dp-only": DP_ONLY}


def run_once(arch, shape, mesh, step_kw=None, rules_override=None,
             cfg_override=None, verbose=False):
    step_cfg = StepConfig(**step_kw) if step_kw else None
    return run_cell(arch, shape, mesh, step_cfg=step_cfg,
                    rules_override=rules_override,
                    cfg_override=cfg_override, verbose=verbose)


def tune_knobs(arch: str, shape: str, mesh: str, out_path: str | None):
    """CI-machinery-driven knob search on the cost-model objective."""
    cfg = configs.get(arch)
    knobs = {"microbatches": (1, 2)}
    cfg_knobs = {}
    if cfg.family in ("ssm", "hybrid"):
        cfg_knobs["ssm_chunk"] = (128, 512)
    else:
        knobs["loss_chunk"] = (256, 1024)
    space = grid(**knobs, **cfg_knobs)
    settings = EvaluationSettings(max_invocations=1, max_iterations=1,
                                  direction=Direction.MINIMIZE,
                                  use_inner_prune=True)
    records = {}

    def benchmark(knob_cfg):
        step_kw = {k: v for k, v in knob_cfg.items() if k in knobs}
        cfg_kw = {k: v for k, v in knob_cfg.items() if k in cfg_knobs}

        def factory():
            def sample():
                r = run_once(arch, shape, mesh, step_kw=step_kw,
                             cfg_override=cfg_kw or None)
                records[tuple(sorted(knob_cfg.items()))] = r
                return objective(r) if r["status"] == "ok" else 1e12
            return sample
        return factory

    result = Tuner(space, settings).tune(benchmark)
    print(f"\n[tune] best knobs: {result.best_config} -> "
          f"{result.best_score:.1f}ms lower bound "
          f"({len(result.trials)} compiles)")
    if out_path:
        with open(out_path, "a") as f:
            for k, r in records.items():
                f.write(json.dumps({"knobs": dict(k), **r}) + "\n")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tune", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--loss-chunk", type=int, default=None)
    ap.add_argument("--remat-policy", default=None)
    ap.add_argument("--grad-bf16", action="store_true")
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence-parallel activations")
    ap.add_argument("--preset", default=None, choices=list(PRESETS),
                    help="sharding-rule preset (e.g. dp-only)")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig override, e.g. --set moe_group_size=128")
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-baseline", action="store_true",
                    help="skip recompiling the baseline (chained variants)")
    args = ap.parse_args()

    if args.tune:
        tune_knobs(args.arch, args.shape, args.mesh, args.out)
        return

    baseline = None
    if not args.no_baseline:
        baseline = run_once(args.arch, args.shape, args.mesh)
        show("baseline", baseline)
    step_kw = {}
    if args.microbatches is not None:
        step_kw["microbatches"] = args.microbatches
    if args.loss_chunk is not None:
        step_kw["loss_chunk"] = args.loss_chunk
    if args.remat_policy is not None:
        step_kw["remat_policy"] = args.remat_policy
    if args.grad_bf16:
        step_kw["grad_bf16"] = True
    rules_override = {"act_seq": ()} if args.no_sp else None
    if args.preset:
        rules_override = dict(PRESETS[args.preset])
    cfg_override = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        cfg_override[k] = int(v) if v.lstrip("-").isdigit() else v
    if step_kw or rules_override or cfg_override:
        varied = run_once(args.arch, args.shape, args.mesh,
                          step_kw=step_kw or None,
                          rules_override=rules_override,
                          cfg_override=cfg_override or None)
        show("variant ", varied)
        if baseline and varied["status"] == "ok" and baseline["status"] == "ok":
            print(f"[delta] lower bound {objective(baseline):.1f}ms -> "
                  f"{objective(varied):.1f}ms")
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps({"variant": {**step_kw, **cfg_override,
                                                "no_sp": args.no_sp,
                                                "preset": args.preset},
                                    **varied}) + "\n")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first statements in this module: jax locks
the device count at first backend init, and the production meshes need 512
placeholder host devices (deliverable e).

Two-pass analysis per cell (see DESIGN.md §7):
  1. FULL pass — the production config, layers scanned: proves the sharded
     program lowers + compiles, and gives the true per-device memory
     footprint (``memory_analysis``). XLA's ``cost_analysis`` counts a scan
     body ONCE, so this pass cannot give FLOPs.
  2. COST pass — the same model at depth 1 and 2 "layer units" with every
     compute scan fully unrolled (``layers.unroll_scans``): cost_analysis
     and the collective-bytes HLO parse are exact there; per-unit deltas
     extrapolate linearly to the full depth (layers are shape-identical).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite_3_2b
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.jsonl
"""

import argparse
import dataclasses
import json
import time
import traceback

from .. import configs
from ..analysis.hlo import parse_collectives
from ..analysis.terms import RooflineTerms, model_flops
from ..distributed import sharding as sh
from ..models import layers as layers_lib
from ..models.config import SHAPES, ModelConfig, cell_is_applicable
from ..models.transformer import StepConfig
from ..train.steps import build_step
from .mesh import make_production_mesh

MESHES = {"single": dict(multi_pod=False), "multi": dict(multi_pod=True)}


def layer_unit(cfg: ModelConfig) -> int:
    """Smallest layer count that preserves the arch's repeating structure."""
    if cfg.family == "vlm":
        return cfg.cross_attn_every
    if cfg.family == "hybrid" and cfg.attn_every:
        return cfg.attn_every
    return 1


def scaled_config(cfg: ModelConfig, units: int) -> ModelConfig:
    unit = layer_unit(cfg)
    changes = {"n_layers": unit * units}
    if cfg.family == "encdec":
        changes["n_enc_layers"] = units
    return dataclasses.replace(cfg, **changes)


def _compile_cell(cfg, shape, mesh, rules, step_cfg):
    with mesh:
        bundle = build_step(cfg, shape, mesh, rules, step_cfg)
        return bundle.lower().compile()


def _costs(compiled, chips):
    ca = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text(), chips)
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            coll.total_bytes, coll)


def run_cell(arch: str, shape_name: str, mesh_name: str,
             step_cfg: StepConfig | None = None,
             rules_override: dict | None = None,
             cfg_override: dict | None = None,
             analyze: bool = True, verbose: bool = True) -> dict:
    """Lower + compile one cell; returns the record dict."""
    from ..train.steps import default_step_cfg
    cfg = configs.get(arch)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    shape = SHAPES[shape_name]
    if step_cfg is None:
        step_cfg = default_step_cfg(cfg, shape)
    if not cell_is_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped",
                "reason": "full-attention arch; long_500k requires "
                          "sub-quadratic attention (DESIGN.md §4)"}
    mesh = make_production_mesh(**MESHES[mesh_name])
    chips = int(mesh.devices.size)
    rules = sh.TRAIN_RULES if shape.kind == "train" else sh.SERVE_RULES
    if rules_override:
        rules = rules.replace(**rules_override)
    t0 = time.perf_counter()
    try:
        # ---- pass 1: full config, scanned (compile + memory proof) ----
        compiled = _compile_cell(cfg, shape, mesh, rules, step_cfg)
        t_full = time.perf_counter() - t0
        ma = compiled.memory_analysis()
        peak_bytes = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                      + ma.temp_size_in_bytes)

        record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                  "status": "ok", "chips": chips,
                  "compile_s": round(t_full, 1),
                  "args_gb": round(ma.argument_size_in_bytes / 1e9, 3),
                  "temp_gb": round(ma.temp_size_in_bytes / 1e9, 3),
                  "out_gb": round(ma.output_size_in_bytes / 1e9, 3),
                  "peak_gb": round(peak_bytes / 1e9, 3)}

        if analyze:
            # ---- pass 2: unrolled small-depth cost extrapolation ----
            unit = layer_unit(cfg)
            total_units = cfg.n_layers // unit
            with layers_lib.unroll_scans():
                c1 = _compile_cell(scaled_config(cfg, 1), shape, mesh, rules,
                                   step_cfg)
                f1, b1, cb1, _ = _costs(c1, chips)
                c2 = _compile_cell(scaled_config(cfg, 2), shape, mesh, rules,
                                   step_cfg)
                f2, b2, cb2, coll2 = _costs(c2, chips)
            flops = f1 + (f2 - f1) * (total_units - 1)
            bytes_ = b1 + (b2 - b1) * (total_units - 1)
            coll_bytes = cb1 + (cb2 - cb1) * (total_units - 1)
            terms = RooflineTerms(
                arch=cfg.name, shape=shape_name, mesh=mesh_name,
                flops_per_dev=flops, bytes_per_dev=bytes_,
                coll_bytes_per_dev=coll_bytes,
                coll_summary=coll2.summary(),
                peak_bytes_per_dev=peak_bytes,
                model_flops_total=model_flops(cfg, shape), chips=chips)
            record.update(terms.row())
            record.update({
                "flops_per_dev": flops, "bytes_per_dev": bytes_,
                "coll_bytes_per_dev": coll_bytes,
                "model_flops_total": terms.model_flops_total,
            })
            if verbose:
                print(f"[{arch} × {shape_name} × {mesh_name}] OK "
                      f"compile={t_full:.0f}s peak={record['peak_gb']}GB/dev")
                print(f"  roofline: compute={terms.compute_s*1e3:.2f}ms "
                      f"memory={terms.memory_s*1e3:.2f}ms "
                      f"collective={terms.collective_s*1e3:.2f}ms "
                      f"-> {terms.dominant}-bound "
                      f"useful={terms.useful_flops_ratio:.2f} "
                      f"mfu_bound={terms.mfu_bound:.3f}")
                print(f"  collectives(2-unit model): {terms.coll_summary}")
        elif verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] OK "
                  f"compile={t_full:.0f}s peak={record['peak_gb']}GB/dev")
        return record
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] FAIL: {e}")
            traceback.print_exc()
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "error", "error": f"{type(e).__name__}: {e}"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None,
                    help="arch id(s); default: all")
    ap.add_argument("--shape", action="append", default=None,
                    choices=list(SHAPES), help="shape(s); default: all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-analysis", action="store_true",
                    help="compile-only (skip the cost-extrapolation pass)")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    archs = args.arch or configs.ARCH_IDS
    shapes = args.shape or list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_name in meshes:
                rec = run_cell(arch, shape_name, mesh_name,
                               analyze=not args.no_analysis)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
                n_ok += rec["status"] == "ok"
                n_fail += rec["status"] == "error"
                n_skip += rec["status"] == "skipped"
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Render dry-run JSONL records into the EXPERIMENTS.md tables.

  PYTHONPATH=src python -m repro.launch.report results/*.jsonl
"""

from __future__ import annotations

import argparse
import json

ARCH_ORDER = ["command-r-plus-104b", "granite-3-2b", "minicpm-2b", "gemma-2b",
              "whisper-base", "granite-moe-1b-a400m", "mixtral-8x22b",
              "llama-3.2-vision-11b", "mamba2-130m", "zamba2-2.7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _canon(arch: str) -> str:
    arch = arch.replace("_", "-").replace("llama-3-2", "llama-3.2") \
        .replace("zamba2-2-7b", "zamba2-2.7b")
    return arch


def load(paths: list[str]) -> list[dict]:
    records = []
    for p in paths:
        with open(p) as f:
            records += [json.loads(line) for line in f]
    # normalize arch ids, dedupe on (arch, shape, mesh), keep last
    seen = {}
    for r in records:
        r = {**r, "arch": _canon(r["arch"])}
        seen[(r["arch"], r["shape"], r["mesh"])] = r
    return list(seen.values())


def _key(r):
    arch = _canon(r["arch"])
    a = ARCH_ORDER.index(arch) if arch in ARCH_ORDER else 99
    s = SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER else 99
    return (a, s, r["mesh"])


def dryrun_table(records: list[dict]) -> str:
    rows = ["| arch | shape | mesh | status | compile_s | peak GB/dev | "
            "fits v5e(16G) |",
            "|---|---|---|---|---|---|---|"]
    for r in sorted(records, key=_key):
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"SKIP (full-attn, long ctx) | - | - | - |")
            continue
        fits = "yes" if r.get("peak_gb", 1e9) + r.get("args_gb", 0) <= 16 \
            else "**no**"
        rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                    f"{r['status']} | {r.get('compile_s', '-')} | "
                    f"{r.get('peak_gb', '-')} | {fits} |")
    return "\n".join(rows)


def roofline_table(records: list[dict], mesh: str = "single") -> str:
    rows = ["| arch | shape | compute ms | memory ms | coll ms | dominant | "
            "useful | MFU-bound | top collectives |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(records, key=_key):
        if r["mesh"] != mesh or r["status"] != "ok" or "dominant" not in r:
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_ms']} | "
            f"{r['memory_ms']} | {r['collective_ms']} | {r['dominant']} | "
            f"{r['useful_flops_ratio']} | {r['mfu_bound']} | "
            f"{r.get('collectives', '')[:60]} |")
    return "\n".join(rows)


def summary(records: list[dict]) -> str:
    ok = [r for r in records if r["status"] == "ok"]
    skip = [r for r in records if r["status"] == "skipped"]
    err = [r for r in records if r["status"] == "error"]
    lines = [f"cells: {len(ok)} ok, {len(skip)} skipped (documented), "
             f"{len(err)} failed"]
    if err:
        for r in err:
            lines.append(f"  FAILED {r['arch']} {r['shape']} {r['mesh']}: "
                         f"{r.get('error', '')[:100]}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+")
    args = ap.parse_args()
    records = load(args.paths)
    print("### Dry-run summary\n")
    print(summary(records))
    print("\n### Dry-run table (both meshes)\n")
    print(dryrun_table(records))
    print("\n### Roofline table (single pod, 256 chips)\n")
    print(roofline_table(records, "single"))
    print("\n### Roofline table (multi-pod, 512 chips)\n")
    print(roofline_table(records, "multi"))


if __name__ == "__main__":
    main()

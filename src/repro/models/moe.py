"""Mixture-of-Experts layer: grouped einsum dispatch (GShard-style).

Token-choice top-k routing with a capacity limit, expressed as dense einsums
so it shards cleanly under GSPMD: expert FFN weights are TP-sharded on the
"mlp" dim, token groups ride the batch ("pod","data") axes, and the dispatch/
combine tensors stay bounded by the *group size* — dispatch elements are
``tokens * group_size * top_k * capacity_factor`` independent of E
(DESIGN.md §5). Group size is per-arch (granite-moe's tiny d_ff needs small
groups to keep dispatch FLOPs a small fraction of expert FLOPs).

Dropped-token semantics: tokens over capacity fall through on the residual
stream (standard GShard behavior).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.actctx import shard_act
from .config import ModelConfig
from .params import ParamDef


def moe_defs(cfg: ModelConfig, layers: int | None = None) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    lead = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()

    def w(shape, logical, **kw):
        return ParamDef(shape=lead + shape, logical=lax_ + logical,
                        dtype=cfg.jdtype, **kw)

    return {
        "router": w((D, E), ("embed_r", "experts"), scale=0.02),
        "w_gate": w((E, D, F), ("experts", "embed", "mlp")),
        "w_up": w((E, D, F), ("experts", "embed", "mlp")),
        "w_down": w((E, F, D), ("experts", "mlp", "embed")),
    }


def capacity(cfg: ModelConfig, group_size: int) -> int:
    c = int(group_size * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, c + (-c) % 8)  # lane-friendly multiple of 8


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig,
              drop: bool = True) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).

    ``drop=False`` (inference): capacity covers every routed token so the
    result is independent of which other tokens share the group — required
    for prefill/decode consistency (training keeps the capacity limit)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    Sg = min(cfg.moe_group_size, S)
    if (B * S) % Sg:
        Sg = S  # odd lengths (tests): one group per batch row
    G = (B * S) // Sg
    if drop:
        C = capacity(cfg, Sg)
    else:
        c = Sg * cfg.top_k
        C = max(8, c + (-c) % 8)
    xg = x.reshape(G, Sg, D)

    # --- routing (f32 for a stable softmax/top-k) ---
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    gate_vals, gate_idx = jax.lax.top_k(logits, K)          # (G, Sg, K)
    probs = jax.nn.softmax(gate_vals, axis=-1)              # (G, Sg, K)
    eoh = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)    # (G, Sg, K, E)

    # --- position within expert, s-major then k-major priority ---
    flat = eoh.reshape(G, Sg * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                   # 0-based slots
    pos = pos.reshape(G, Sg, K, E)
    in_cap = (pos < C).astype(jnp.float32)
    slot = jnp.einsum("gske,gske->gsk", pos, eoh)           # chosen slot id
    slot_oh = jax.nn.one_hot(slot.astype(jnp.int32), C,
                             dtype=jnp.float32)             # (G, Sg, K, C)

    # combine[g,s,e,c] = prob of (token s -> expert e at slot c), 0 if dropped
    kept = eoh * in_cap                                     # (G, Sg, K, E)
    combine = jnp.einsum("gske,gskc,gsk->gsec", kept, slot_oh, probs)
    dispatch = (combine > 0.0).astype(x.dtype)              # (G, Sg, E, C)

    # --- expert FFN over capacity-packed tokens ---
    ein = shard_act(jnp.einsum("gsec,gsd->gecd", dispatch, xg),
                    ("batch", None, None, "act_embed"))     # (G, E, C, D)
    h_g = jax.nn.silu(shard_act(
        jnp.einsum("gecd,edf->gecf", ein, p["w_gate"]),
        ("batch", None, None, "mlp")))
    h_u = shard_act(jnp.einsum("gecd,edf->gecf", ein, p["w_up"]),
                    ("batch", None, None, "mlp"))
    out_e = shard_act(jnp.einsum("gecf,efd->gecd", h_g * h_u, p["w_down"]),
                      ("batch", None, None, "act_embed"))

    # --- weighted un-dispatch ---
    y = jnp.einsum("gecd,gsec->gsd", out_e,
                   combine.astype(out_e.dtype))
    return shard_act(y.reshape(B, S, D),
                     ("batch", "act_seq", "act_embed"))


def aux_load_balance_loss(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (fraction * probability)."""
    B, S, D = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                 # (B, S, E)
    _, idx = jax.lax.top_k(logits, cfg.top_k)
    frac = jnp.mean(jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32),
                    axis=(0, 1, 2))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    return cfg.n_experts * jnp.sum(frac * mean_prob)

"""Shared model layers (functional, param-tree based).

Conventions:
  * weights store contraction dims first: ``wq (D, H, Dh)``, ``wo (H, Dh, D)``;
  * every ParamDef carries logical axis names consumed by
    ``repro.distributed.sharding`` (TP on "heads"/"mlp"/"vocab", FSDP on
    "embed");
  * attention exposes a full-sequence path (train/prefill; flash kernel or
    jnp reference) and a one-token decode path over a position-tagged KV
    cache (supports both linear and rolling/sliding-window caches).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.actctx import shard_act
from ..kernels.flash_attention import flash_attention
from .config import ModelConfig
from .params import ParamDef

# ---------------------------------------------------------------------------
# Scan-unroll context: ``cost_analysis`` counts a lax.scan body ONCE, so the
# roofline analysis lowers a small-depth model with every compute scan fully
# unrolled and extrapolates per-layer costs (launch/dryrun.py). All compute
# scans in the model zoo go through ``xscan`` so one flag flips them all.
# ---------------------------------------------------------------------------

_UNROLL_SCANS = False


class unroll_scans:
    """Context manager: trace with all model scans fully unrolled."""

    def __enter__(self):
        global _UNROLL_SCANS
        self._prev = _UNROLL_SCANS
        _UNROLL_SCANS = True

    def __exit__(self, *exc):
        global _UNROLL_SCANS
        _UNROLL_SCANS = self._prev


def xscan(body, init, xs, length=None):
    return jax.lax.scan(body, init, xs, length=length,
                        unroll=True if _UNROLL_SCANS else 1)

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_defs(cfg: ModelConfig, d: int | None = None) -> ParamDef:
    return ParamDef(shape=(d or cfg.d_model,), logical=("embed_r",),
                    init="ones", dtype=cfg.jdtype)


def apply_norm(scale: jax.Array, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x32 = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        x32 = x32 - jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    x32 = x32 * jax.lax.rsqrt(var + cfg.norm_eps)
    return (x32 * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def _rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, S, Dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    freqs = _rope_freqs(dh, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    if angles.ndim == 2:                                 # (S, Dh/2)
        angles = angles[None, None]                      # (1, 1, S, Dh/2)
    else:                                                # (B, S, Dh/2)
        angles = angles[:, None]                         # (B, 1, S, Dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Whisper-style sinusoidal table (computed, not learned)."""
    return sinusoidal_at(jnp.arange(n, dtype=jnp.float32), d)


def sinusoidal_at(positions: jax.Array, d: int) -> jax.Array:
    """Sinusoidal encoding for an arbitrary positions array -> (..., d)."""
    pos = positions.astype(jnp.float32)[..., None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    angle = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention_defs(cfg: ModelConfig, layers: int | None = None,
                   kv_from: int | None = None) -> dict:
    """Param tree for one (stack of) attention layer(s).

    ``layers``: if given, stack with a leading "layers" axis for lax.scan.
    ``kv_from``: width of the kv source (cross-attention); default d_model.
    """
    D, H, Hk, Dh = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_)
    Dkv = kv_from or D
    lead = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()

    def w(shape, logical):
        return ParamDef(shape=lead + shape, logical=lax_ + logical,
                        dtype=cfg.jdtype)

    return {
        "wq": w((D, H, Dh), ("embed", "heads", "head_dim")),
        "wk": w((Dkv, Hk, Dh), ("embed", "kv_heads", "head_dim")),
        "wv": w((Dkv, Hk, Dh), ("embed", "kv_heads", "head_dim")),
        "wo": w((H, Dh, D), ("heads", "head_dim", "embed")),
    }


ACT_BSD = ("batch", "act_seq", "act_embed")
ACT_QHEADS = ("batch", "heads", "act_seq", "head_dim")
ACT_KVHEADS = ("batch", "kv_heads", "act_seq", "head_dim")


def _qkv(p: dict, x: jax.Array, kv_x: jax.Array):
    q = shard_act(jnp.einsum("bsd,dhk->bhsk", x, p["wq"]), ACT_QHEADS)
    k = shard_act(jnp.einsum("bsd,dhk->bhsk", kv_x, p["wk"]), ACT_KVHEADS)
    v = shard_act(jnp.einsum("bsd,dhk->bhsk", kv_x, p["wv"]), ACT_KVHEADS)
    return q, k, v


def attention_full(p: dict, x: jax.Array, cfg: ModelConfig, *,
                   kv_x: Optional[jax.Array] = None, causal: bool = True,
                   rope: bool = True, window: Optional[int] = None,
                   use_flash: bool = False, block_q: int = 512,
                   block_k: int = 512) -> jax.Array:
    """Full-sequence attention (train / prefill). x: (B, S, D)."""
    kv_src = x if kv_x is None else kv_x
    q, k, v = _qkv(p, x, kv_src)
    if rope:
        pos_q = jnp.arange(x.shape[1])
        pos_k = jnp.arange(kv_src.shape[1])
        q = apply_rope(q, pos_q, cfg.rope_theta)
        k = apply_rope(k, pos_k, cfg.rope_theta)
    if use_flash and kv_x is None:
        out = flash_attention(q, k, v, causal=causal, window=window,
                              bq=block_q, bk=block_k, interpret=True)
    else:
        out = _attend(q, k, v, causal=causal and kv_x is None, window=window)
    return shard_act(jnp.einsum("bhsk,hkd->bsd", out, p["wo"]), ACT_BSD)


def _expand_kv(k: jax.Array, h: int) -> jax.Array:
    """GQA: replicate kv heads up to the q-head count so every tensor in the
    attention math carries the TP-sharded "heads" dim (kv_heads rarely
    divides the model axis; q heads usually do — DESIGN.md §5)."""
    hkv = k.shape[1]
    if hkv == h:
        return k
    return shard_act(jnp.repeat(k, h // hkv, axis=1), ACT_QHEADS)


# Sequences at or above this length use the q-chunked online path so the
# (S, S) score matrix never materializes (the XLA analog of the Pallas
# flash kernel's VMEM tiling; on TPU the kernel path replaces this).
_CHUNK_THRESHOLD = 2048
_Q_CHUNK = 1024


def _attend(q, k, v, *, causal: bool, window: Optional[int]) -> jax.Array:
    """jnp attention with GQA head expansion; q-chunked for long sequences."""
    b, h, sq, dh = q.shape
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    if sq >= _CHUNK_THRESHOLD and sq % _Q_CHUNK == 0:
        return _attend_chunked(q, k, v, causal=causal, window=window)
    return _attend_direct(q, k, v, causal=causal, window=window)


def _attend_direct(q, k, v, *, causal: bool, window: Optional[int],
                   q_offset=None) -> jax.Array:
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(dh)
    if causal or window is not None:
        if q_offset is None:
            q_pos = jnp.arange(sq)[:, None] + (sk - sq)  # align ends
        else:
            q_pos = jnp.arange(sq)[:, None] + q_offset
        k_pos = jnp.arange(sk)[None, :]
        mask = jnp.ones((sq, sk), dtype=bool)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return shard_act(out.astype(q.dtype), ACT_QHEADS)


def _attend_chunked(q, k, v, *, causal: bool,
                    window: Optional[int]) -> jax.Array:
    """Scan over query chunks: live score slab is (B, H, qc, S) instead of
    (B, H, S, S). The chunk body is checkpointed so the backward pass
    re-derives its probs instead of stashing them per chunk."""
    b, h, sq, dh = q.shape
    qc = _Q_CHUNK
    nq = sq // qc
    q_chunks = jnp.moveaxis(q.reshape(b, h, nq, qc, dh), 2, 0)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(_, inp):
        q_c, i = inp
        out = _attend_direct(q_c, k, v, causal=causal, window=window,
                             q_offset=i * qc)
        return None, out

    _, ys = xscan(body, None, (q_chunks, jnp.arange(nq)))
    return jnp.moveaxis(ys, 0, 2).reshape(b, h, sq, dh)


# ---- decode path ----------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KVCacheSpec:
    """Layout of one layer-stack's KV cache: (L, B, Hkv, S, Dh) k/v plus a
    position tag per slot (supports rolling window caches)."""

    layers: int
    batch: int
    kv_heads: int
    length: int
    head_dim: int
    dtype: object

    def shape_tree(self) -> dict:
        kv = jax.ShapeDtypeStruct(
            (self.layers, self.batch, self.kv_heads, self.length,
             self.head_dim), self.dtype)
        pos = jax.ShapeDtypeStruct((self.layers, self.batch, self.length),
                                   jnp.int32)
        return {"k": kv, "v": kv, "pos": pos}

    def init_tree(self) -> dict:
        shapes = self.shape_tree()
        return {
            "k": jnp.zeros(shapes["k"].shape, self.dtype),
            "v": jnp.zeros(shapes["v"].shape, self.dtype),
            "pos": jnp.full(shapes["pos"].shape, -1, jnp.int32),
        }

    @property
    def logical(self) -> dict:
        kv = ("layers", "cache_batch", "kv_heads", "cache_seq", "head_dim")
        return {"k": kv, "v": kv, "pos": ("layers", "cache_batch", "cache_seq")}


def attention_decode(p: dict, x: jax.Array, layer_cache: dict,
                     pos: jax.Array, cfg: ModelConfig, *,
                     rope: bool = True,
                     window: Optional[int] = None) -> tuple[jax.Array, dict]:
    """One-token decode. x: (B, 1, D); layer_cache holds this layer's
    {k, v, pos} slices (B, Hkv, S, Dh) / (B, S). Returns (y, new_cache)."""
    q, k_new, v_new = _qkv(p, x, x)                      # (B, *, 1, Dh)
    if rope:
        pos_b = jnp.full((x.shape[0], 1), pos, jnp.int32)
        q = apply_rope(q, pos_b, cfg.rope_theta)
        k_new = apply_rope(k_new, pos_b, cfg.rope_theta)
    length = layer_cache["k"].shape[2]
    # Linear cache (length == max seq): slot == pos. Rolling/window cache
    # (length == window): slot wraps; staleness is handled by the pos tags.
    slot = jnp.asarray(pos % length, jnp.int32)
    k = jax.lax.dynamic_update_slice_in_dim(layer_cache["k"],
                                            k_new.astype(layer_cache["k"].dtype),
                                            slot, axis=2)
    v = jax.lax.dynamic_update_slice_in_dim(layer_cache["v"],
                                            v_new.astype(layer_cache["v"].dtype),
                                            slot, axis=2)
    pos_tags = jax.lax.dynamic_update_slice_in_dim(
        layer_cache["pos"],
        jnp.full((x.shape[0], 1), pos, jnp.int32), slot, axis=1)

    b, h, _, dh = q.shape
    k_exp = _expand_kv(k, h)
    v_exp = _expand_kv(v, h)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k_exp.astype(jnp.float32)) / math.sqrt(dh)
    valid = (pos_tags >= 0) & (pos_tags <= pos)
    if window is not None:
        valid &= (pos - pos_tags) < window
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v_exp.astype(jnp.float32))
    out = out.astype(x.dtype)
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"])
    return y, {"k": k, "v": v, "pos": pos_tags}


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / plain)
# ---------------------------------------------------------------------------


def mlp_defs(cfg: ModelConfig, layers: int | None = None) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    lead = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()

    def w(shape, logical):
        return ParamDef(shape=lead + shape, logical=lax_ + logical,
                        dtype=cfg.jdtype)

    if cfg.mlp_type == "plain":
        return {"w_up": w((D, F), ("embed", "mlp")),
                "w_down": w((F, D), ("mlp", "embed"))}
    return {"w_gate": w((D, F), ("embed", "mlp")),
            "w_up": w((D, F), ("embed", "mlp")),
            "w_down": w((F, D), ("mlp", "embed"))}


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


def apply_mlp(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    mlp_hidden = ("batch", "act_seq", "mlp")
    if cfg.mlp_type == "plain":
        h = _act(shard_act(jnp.einsum("bsd,df->bsf", x, p["w_up"]),
                           mlp_hidden), cfg.act)
        return shard_act(jnp.einsum("bsf,fd->bsd", h, p["w_down"]), ACT_BSD)
    g = _act(shard_act(jnp.einsum("bsd,df->bsf", x, p["w_gate"]),
                       mlp_hidden), cfg.act)
    u = shard_act(jnp.einsum("bsd,df->bsf", x, p["w_up"]), mlp_hidden)
    return shard_act(jnp.einsum("bsf,fd->bsd", g * u, p["w_down"]), ACT_BSD)


# ---------------------------------------------------------------------------
# Embedding + LM head + loss
# ---------------------------------------------------------------------------


def embedding_defs(cfg: ModelConfig) -> dict:
    # The table is 2D-sharded (vocab -> model TP, d_model -> data FSDP):
    # vocab-only sharding left a full-size f32 gradient all-reduce + table
    # all-gather in the HLO (12.6GB each for command-r; §Perf iteration 2).
    out = {"table": ParamDef(shape=(cfg.vocab_padded, cfg.d_model),
                             logical=("vocab", "embed"), init="embed",
                             scale=0.02, dtype=cfg.jdtype)}
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamDef(shape=(cfg.d_model, cfg.vocab_padded),
                                  logical=("embed", "vocab"),
                                  dtype=cfg.jdtype)
    return out


def embed_tokens(p: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    # One-hot-free lookup; GSPMD partitions the gather over the vocab-sharded
    # table via mask + all-reduce (verified in the dry-run HLO).
    return shard_act(jnp.take(p["table"], tokens, axis=0), ACT_BSD)


def logits_fn(p: dict, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", h, p["table"])
    return jnp.einsum("bsd,dv->bsv", h, p["lm_head"])


def cross_entropy_loss(p_embed: dict, h: jax.Array, targets: jax.Array,
                       cfg: ModelConfig, *, chunk: int = 512,
                       mask: jax.Array | None = None) -> jax.Array:
    """CE over (B, S) targets, chunked over the sequence so the
    (B, chunk, V) logits slab bounds activation memory (a hillclimbing
    lever; see §Perf). ``mask`` (B, S) in {0,1} weights positions."""
    b, s, _ = h.shape
    chunk = min(chunk, s)
    n_chunks = s // chunk
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    h_c = h[:, :n_chunks * chunk].reshape(b, n_chunks, chunk, -1)
    t_c = targets[:, :n_chunks * chunk].reshape(b, n_chunks, chunk)
    m_c = mask[:, :n_chunks * chunk].reshape(b, n_chunks, chunk)
    h_c = jnp.moveaxis(h_c, 1, 0)
    t_c = jnp.moveaxis(t_c, 1, 0)
    m_c = jnp.moveaxis(m_c, 1, 0)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(acc, xs):
        hc, tc, mc = xs                              # (B, chunk, D), (B, chunk)
        logits = shard_act(logits_fn(p_embed, hc, cfg),
                           ("batch", None, "vocab")).astype(jnp.float32)
        # mask padded vocab rows with an elementwise iota compare — an
        # .at[vocab_size:].set() would cross shard boundaries of the
        # vocab-sharded dim and force a full-logits all-gather (38.9GB for
        # granite train_4k; see EXPERIMENTS.md §Perf iteration 1)
        if cfg.vocab_padded != cfg.vocab_size:
            col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
            logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((lse - gold) * mc.astype(jnp.float32)), None

    total, _ = xscan(body, jnp.zeros((), jnp.float32), (h_c, t_c, m_c))
    return total / jnp.maximum(jnp.sum(mask), 1.0)


def next_token_targets(tokens: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Keep S intact (chunking/sharding divisibility): targets are tokens
    rolled left; the final position is masked out of the loss."""
    b, s = tokens.shape
    targets = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones((b, s), jnp.float32).at[:, -1].set(0.0)
    return targets, mask

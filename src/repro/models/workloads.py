"""Whole-model workloads as jittable, costable callables.

The tuner's benchmarks (DGEMM, TRIAD) reproduce the paper; this module
turns the *models* already in the repo into the same shape of object: a
named, deterministic, jit-compatible callable with concrete example
arguments. That one handle feeds three consumers:

- ``benchmarks/common.py`` registers train/decode steps as audited,
  tunable benchmarks (the flash-attention tile sizes in
  :class:`~repro.models.transformer.StepConfig` are the search space);
- ``repro.obs.attribution`` lowers the callable, walks its optimized
  HLO per-op, and places every op on the empirical roofline;
- tests/CI smoke the whole path on CPU with the tiny default config.

Everything here is CPU-safe: the default config is a 2-layer toy model,
inputs come from a fixed PRNG key, and nothing allocates until
:func:`build_workload` is called.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

from .config import ModelConfig, WorkloadShape
from .transformer import StepConfig

__all__ = [
    "ModelWorkload",
    "TINY_CONFIG",
    "WORKLOAD_NAMES",
    "build_workload",
    "workload_static_cost",
]

# Small enough to compile in seconds on CPU, big enough that dot ops
# dominate the HLO (the attribution tables should not be all-reshape).
TINY_CONFIG = ModelConfig(
    name="tiny-dense",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    norm="rmsnorm",
    dtype="float32",
)

_TINY_BATCH = 2
_TINY_SEQ = 64

WORKLOAD_NAMES = ("train_step", "prefill_step", "decode_step", "dgemm")


@dataclasses.dataclass(frozen=True)
class ModelWorkload:
    """One named workload: a pure jittable ``fn`` plus concrete ``args``.

    ``fn(*args)`` is what gets timed, lowered, and attributed; ``args``
    are real device arrays (deterministic — fixed PRNG key) so repeated
    builds of the same workload hash to the same executable.
    """

    name: str
    kind: str                    # train | prefill | decode | kernel
    fn: Callable
    args: tuple
    cfg: Optional[ModelConfig]   # None for raw-kernel workloads (dgemm)
    step: Optional[StepConfig]
    shape: Optional[WorkloadShape]
    declared_flops: Optional[float] = None  # analytic, when one exists

    def jit(self):
        import jax

        return jax.jit(self.fn)

    def compiled(self):
        """Lower + compile once (AOT); callers reuse for text and cost."""
        return self.jit().lower(*self.args).compile()

    def hlo_text(self) -> str:
        return self.compiled().as_text()


def _tiny_shape(kind: str, batch: int, seq: int) -> WorkloadShape:
    return WorkloadShape(name=f"tiny_{kind}", seq_len=seq,
                         global_batch=batch, kind=kind)


@functools.lru_cache(maxsize=None)
def _materialized(cfg: ModelConfig):
    import jax

    from . import api
    from .params import materialize

    return materialize(jax.random.PRNGKey(0), api.param_defs(cfg))


def _tokens(batch: int, seq: int, vocab: int):
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(1)
    return jax.random.randint(key, (batch, seq), 0, vocab, jnp.int32)


def _model_batch(cfg: ModelConfig, shape: WorkloadShape) -> dict:
    """Concrete input batch matching ``config.input_specs``."""
    import jax
    import jax.numpy as jnp

    seq = shape.seq_len if shape.kind != "decode" else 1
    batch: dict = {"tokens": _tokens(shape.global_batch, seq,
                                     cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros(
            (shape.global_batch, cfg.n_frames, cfg.d_enc), cfg.jdtype)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (shape.global_batch, cfg.n_image_tokens, cfg.d_model),
            cfg.jdtype)
    return batch


def _build_train(cfg: ModelConfig, step: StepConfig,
                 batch_size: int, seq: int) -> ModelWorkload:
    import jax

    from . import api

    shape = _tiny_shape("train", batch_size, seq)
    params = _materialized(cfg)
    batch = _model_batch(cfg, shape)

    def train_step(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss_fn(p, batch, cfg, step))(params)
        return loss, grads

    return ModelWorkload(name="train_step", kind="train", fn=train_step,
                         args=(params, batch), cfg=cfg, step=step,
                         shape=shape)


def _build_prefill(cfg: ModelConfig, step: StepConfig,
                   batch_size: int, seq: int) -> ModelWorkload:
    from . import api

    shape = _tiny_shape("prefill", batch_size, seq)
    params = _materialized(cfg)
    batch = _model_batch(cfg, shape)

    def prefill_step(params, batch):
        return api.prefill_fn(params, batch, cfg, step)

    return ModelWorkload(name="prefill_step", kind="prefill",
                         fn=prefill_step, args=(params, batch), cfg=cfg,
                         step=step, shape=shape)


def _build_decode(cfg: ModelConfig, step: StepConfig,
                  batch_size: int, seq: int) -> ModelWorkload:
    import jax.numpy as jnp

    from . import api

    shape = _tiny_shape("decode", batch_size, seq)
    params = _materialized(cfg)
    batch = _model_batch(cfg, shape)
    cache = api.cache_init(cfg, shape)
    pos = jnp.int32(0)

    def decode_step(params, batch, cache, pos):
        return api.decode_fn(params, batch, cache, pos, cfg, step)

    return ModelWorkload(name="decode_step", kind="decode", fn=decode_step,
                         args=(params, batch, cache, pos), cfg=cfg,
                         step=step, shape=shape)


def _build_dgemm(m: int, n: int, k: int) -> ModelWorkload:
    """Square-ish DGEMM with an exact analytic FLOP count (2·m·n·k) —
    the calibration workload for attribution math (tests pin the
    attributed FLOPs to this declaration within 1%)."""
    import jax
    import jax.numpy as jnp

    a = jax.random.normal(jax.random.PRNGKey(3), (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(4), (k, n), jnp.float32)

    def dgemm(a, b):
        return jnp.dot(a, b)

    return ModelWorkload(name="dgemm", kind="kernel", fn=dgemm,
                         args=(a, b), cfg=None, step=None, shape=None,
                         declared_flops=2.0 * m * n * k)


def build_workload(name: str, arch: Optional[str] = None, *,
                   step: Optional[StepConfig] = None,
                   batch_size: int = _TINY_BATCH, seq_len: int = _TINY_SEQ,
                   m: int = 128, n: int = 128, k: int = 128,
                   ) -> ModelWorkload:
    """Build one named workload with concrete inputs.

    ``arch`` selects a smoke-scale architecture from ``repro.configs``
    (e.g. ``"mixtral_8x22b"`` → its SMOKE config); the default is the
    in-module :data:`TINY_CONFIG` dense toy. ``step`` carries the
    execution knobs — including the Pallas flash-attention tile sizes —
    so a tuner can rebuild the same workload under different configs.
    """
    if name not in WORKLOAD_NAMES:
        raise ValueError(
            f"unknown workload {name!r}; expected one of {WORKLOAD_NAMES}")
    if name == "dgemm":
        return _build_dgemm(m, n, k)
    if arch is None:
        cfg = TINY_CONFIG
    else:
        from repro.configs import get_smoke

        cfg = get_smoke(arch)
    step = step or StepConfig(remat=False)
    builder = {"train_step": _build_train, "prefill_step": _build_prefill,
               "decode_step": _build_decode}[name]
    return builder(cfg, step, batch_size, seq_len)


def workload_static_cost(workload: ModelWorkload):
    """Compiler-reported cost of one workload call (shared audit helper).

    This is the *same* number the benchmark registration declares as its
    work term and the GFLOP/s conversion divides by, so the workload
    audit (MS101) checks the shared formula against the trace rather
    than an analytic approximation that drifts on tiny models.
    """
    from repro.lint.workload import trace_cost

    return trace_cost(workload.fn, workload.args)

"""Unified model API: one entry point per step kind, dispatched by family.

    param_defs(cfg)                      -> ParamDef tree
    loss_fn(params, batch, cfg, step)    -> scalar loss            (train)
    prefill_fn(params, batch, cfg, step) -> (logits, cache)        (prefill)
    decode_fn(params, batch, cache, pos, cfg, step) -> (logits, cache)
    cache_shapes(cfg, shape)             -> ShapeDtypeStruct tree
    cache_logical(cfg)                   -> logical-axes tree (sharding)

All functions are pure and jit/pjit-compatible; ``batch`` is a dict of
arrays matching ``config.input_specs``.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from . import encdec, hybrid, layers, transformer
from .config import ModelConfig, WorkloadShape, cache_len
from .transformer import StepConfig


def param_defs(cfg: ModelConfig) -> dict:
    if cfg.family in ("dense", "moe"):
        return transformer.lm_defs(cfg)
    if cfg.family == "vlm":
        return transformer.vlm_defs(cfg)
    if cfg.family == "encdec":
        return encdec.encdec_defs(cfg)
    if cfg.family == "ssm":
        return hybrid.ssm_lm_defs(cfg)
    if cfg.family == "hybrid":
        return hybrid.hybrid_lm_defs(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


def loss_fn(params: dict, batch: dict, cfg: ModelConfig,
            step: StepConfig) -> jax.Array:
    if cfg.family == "encdec":
        return encdec.loss_fn(params, batch, cfg, step)
    if cfg.family in ("ssm", "hybrid"):
        tokens = batch["tokens"]
        h = hybrid.hidden(params, tokens, cfg, step)
        targets, mask = layers.next_token_targets(tokens)
        return layers.cross_entropy_loss(params["embed"], h, targets, cfg,
                                         chunk=step.loss_chunk, mask=mask)
    return transformer.lm_loss(params, batch, cfg, step)


def prefill_fn(params: dict, batch: dict, cfg: ModelConfig,
               step: StepConfig) -> tuple[jax.Array, dict]:
    import dataclasses
    step = dataclasses.replace(step, inference=True)
    if cfg.family == "encdec":
        return encdec.prefill(params, batch, cfg, step)
    if cfg.family in ("ssm", "hybrid"):
        return hybrid.prefill(params, batch, cfg, step)
    if cfg.family == "vlm":
        return transformer.vlm_prefill(params, batch, cfg, step)
    return transformer.lm_prefill(params, batch, cfg, step)


def decode_fn(params: dict, batch: dict, cache: dict, pos: jax.Array,
              cfg: ModelConfig, step: StepConfig) -> tuple[jax.Array, dict]:
    import dataclasses
    step = dataclasses.replace(step, inference=True)
    tokens = batch["tokens"]
    if cfg.family == "encdec":
        return encdec.decode(params, tokens, cache, pos, cfg, step)
    if cfg.family in ("ssm", "hybrid"):
        return hybrid.decode(params, tokens, cache, pos, cfg, step)
    return transformer.lm_decode(params, tokens, cache, pos, cfg, step,
                                 image_embeds=batch.get("image_embeds"))


def cache_shapes(cfg: ModelConfig, shape: WorkloadShape) -> dict:
    """ShapeDtypeStruct tree for the decode cache of one workload cell."""
    B = shape.global_batch
    length = cache_len(cfg, shape)
    if cfg.family == "encdec":
        return encdec.cache_shapes(cfg, B, length)
    if cfg.family in ("ssm", "hybrid"):
        return hybrid.cache_shapes(cfg, B, length)
    if cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        n_self = cfg.n_layers - n_cross
        return {"attn": transformer.kv_cache_spec(
            cfg, B, length, layers=n_self).shape_tree()}
    return {"attn": transformer.kv_cache_spec(cfg, B, length).shape_tree()}


def cache_logical(cfg: ModelConfig) -> dict:
    kv_logical = layers.KVCacheSpec(1, 1, 1, 1, 1, jnp.bfloat16).logical
    if cfg.family == "encdec":
        return encdec.cache_logical(cfg)
    if cfg.family in ("ssm", "hybrid"):
        return hybrid.cache_logical(cfg)
    return {"attn": kv_logical}


def extend_cache(cache: dict, extra: int) -> dict:
    """Grow every attention KV cache by ``extra`` slots (prefill allocates
    prompt-length caches; serving needs room for generated tokens)."""

    def walk(node):
        if isinstance(node, dict):
            if set(node) == {"k", "v", "pos"}:
                pad_kv = [(0, 0)] * node["k"].ndim
                pad_kv[-2] = (0, extra)
                pad_pos = [(0, 0)] * node["pos"].ndim
                pad_pos[-1] = (0, extra)
                return {
                    "k": jnp.pad(node["k"], pad_kv),
                    "v": jnp.pad(node["v"], pad_kv),
                    "pos": jnp.pad(node["pos"], pad_pos, constant_values=-1),
                }
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(cache)


def cache_init(cfg: ModelConfig, shape: WorkloadShape) -> dict:
    """Zero-initialized cache (smoke tests / real serving)."""
    shapes = cache_shapes(cfg, shape)

    def init_leaf(s: jax.ShapeDtypeStruct, path_is_pos: bool):
        if path_is_pos:
            return jnp.full(s.shape, -1, jnp.int32)
        return jnp.zeros(s.shape, s.dtype)

    def walk(node, key=""):
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        return init_leaf(node, key == "pos")

    return walk(shapes)

"""Model zoo: the ten assigned architectures across six families."""

from .config import (DECODE_32K, LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K,
                     ModelConfig, WorkloadShape, cache_len,
                     cell_is_applicable, input_specs)
from .transformer import StepConfig

__all__ = ["DECODE_32K", "LONG_500K", "PREFILL_32K", "SHAPES", "TRAIN_4K",
           "ModelConfig", "StepConfig", "WorkloadShape", "cache_len",
           "cell_is_applicable", "input_specs"]

"""Decoder-only LM assembly: dense GQA, MoE, and VLM (cross-attn) variants.

Layers are scanned (stacked params, leading "layers" axis) so the HLO stays
O(1) in depth — essential for compiling 66 dry-run cells quickly and for
remat-per-layer memory behavior. The VLM variant interleaves via a nested
scan: outer over (self-block-group + cross-block) groups, inner over the
self blocks of the group.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as moe_lib
from .config import ModelConfig
from .params import ParamDef


@dataclasses.dataclass(frozen=True)
class StepConfig:
    """Execution knobs (all are autotuner search dimensions)."""

    use_flash: bool = False       # Pallas kernel (TPU) vs jnp reference (CPU)
    flash_block_q: int = 512      # Pallas flash-attention q tile
    flash_block_k: int = 512      # Pallas flash-attention k tile
    remat: bool = True
    remat_policy: str = "nothing_saveable"
    loss_chunk: int = 512
    microbatches: int = 1
    inference: bool = False       # prefill/decode: drop-free MoE routing
    grad_bf16: bool = False       # cast grads before sync (halves traffic)

    def policy(self):
        if not self.remat:
            return None
        return getattr(jax.checkpoint_policies, self.remat_policy)


def _stacked_norm(cfg: ModelConfig, layers: int) -> ParamDef:
    return ParamDef(shape=(layers, cfg.d_model), logical=("layers", "embed_r"),
                    init="ones", dtype=cfg.jdtype)


def _block_defs(cfg: ModelConfig, layers: int) -> dict:
    d: dict = {"ln1": _stacked_norm(cfg, layers),
               "attn": L.attention_defs(cfg, layers=layers),
               "ln2": _stacked_norm(cfg, layers)}
    if cfg.n_experts:
        d["moe"] = moe_lib.moe_defs(cfg, layers=layers)
    else:
        d["mlp"] = L.mlp_defs(cfg, layers=layers)
    return d


def lm_defs(cfg: ModelConfig) -> dict:
    """Dense / MoE decoder-only parameter tree."""
    out = {"embed": L.embedding_defs(cfg),
           "layers": _block_defs(cfg, cfg.n_layers),
           "ln_f": L.norm_defs(cfg)}
    return out


def vlm_defs(cfg: ModelConfig) -> dict:
    """Self blocks + every-Nth cross-attention blocks (Llama-3.2-Vision)."""
    n_cross = cfg.n_layers // cfg.cross_attn_every
    n_self = cfg.n_layers - n_cross
    return {
        "embed": L.embedding_defs(cfg),
        "self_layers": _block_defs(cfg, n_self),
        "cross_layers": {
            "ln1": _stacked_norm(cfg, n_cross),
            "attn": L.attention_defs(cfg, layers=n_cross),
            "ln2": _stacked_norm(cfg, n_cross),
            "mlp": L.mlp_defs(cfg, layers=n_cross),
        },
        "ln_f": L.norm_defs(cfg),
    }


# ---------------------------------------------------------------------------
# Block bodies
# ---------------------------------------------------------------------------


def _ffn(lp: dict, x: jax.Array, cfg: ModelConfig,
         step: StepConfig = StepConfig()) -> jax.Array:
    if cfg.n_experts:
        return moe_lib.apply_moe(lp["moe"], x, cfg, drop=not step.inference)
    return L.apply_mlp(lp["mlp"], x, cfg)


def _self_block(h: jax.Array, lp: dict, cfg: ModelConfig,
                step: StepConfig, *, collect_kv: bool = False):
    a_in = L.apply_norm(lp["ln1"], h, cfg)
    if collect_kv:
        # prefill: also emit this layer's roped K/V for the decode cache
        kv_src = a_in
        q = jnp.einsum("bsd,dhk->bhsk", a_in, lp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bhsk", kv_src, lp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bhsk", kv_src, lp["attn"]["wv"])
        pos = jnp.arange(h.shape[1])
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
        out = L._attend(q, k, v, causal=True, window=cfg.window)
        a = jnp.einsum("bhsk,hkd->bsd", out, lp["attn"]["wo"])
        kv = (k, v)
    else:
        a = L.attention_full(lp["attn"], a_in, cfg, causal=True,
                             window=cfg.window, use_flash=step.use_flash,
                             block_q=step.flash_block_q,
                             block_k=step.flash_block_k)
        kv = None
    h = h + a
    h = h + _ffn(lp, L.apply_norm(lp["ln2"], h, cfg), cfg, step)
    return (h, kv) if collect_kv else h


def _cross_block(h: jax.Array, lp: dict, ctx: jax.Array, cfg: ModelConfig,
                 step: StepConfig) -> jax.Array:
    a_in = L.apply_norm(lp["ln1"], h, cfg)
    a = L.attention_full(lp["attn"], a_in, cfg, kv_x=ctx, causal=False,
                         rope=False, use_flash=False)
    h = h + a
    h = h + L.apply_mlp(lp["mlp"], L.apply_norm(lp["ln2"], h, cfg), cfg)
    return h


def _maybe_remat(fn, step: StepConfig):
    if not step.remat:
        return fn
    return jax.checkpoint(fn, policy=step.policy())


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def lm_hidden(params: dict, tokens: jax.Array, cfg: ModelConfig,
              step: StepConfig,
              image_embeds: Optional[jax.Array] = None) -> jax.Array:
    """Token ids -> final hidden states (B, S, D)."""
    h = L.embed_tokens(params["embed"], tokens, cfg)
    if cfg.family == "vlm":
        h = _vlm_scan(params, h, image_embeds, cfg, step)
    else:
        body = _maybe_remat(
            lambda carry, lp: (_self_block(carry, lp, cfg, step), None), step)
        h, _ = L.xscan(body, h, params["layers"])
    return L.apply_norm(params["ln_f"], h, cfg)


def _vlm_scan(params: dict, h: jax.Array, image_embeds: jax.Array,
              cfg: ModelConfig, step: StepConfig) -> jax.Array:
    n_cross = cfg.n_layers // cfg.cross_attn_every
    per_group = cfg.cross_attn_every - 1
    grouped_self = jax.tree.map(
        lambda a: a.reshape(n_cross, per_group, *a.shape[1:]),
        params["self_layers"])

    self_body = _maybe_remat(
        lambda carry, lp: (_self_block(carry, lp, cfg, step), None), step)
    cross_body = _maybe_remat(
        lambda carry, lp: _cross_block(carry, lp, image_embeds, cfg, step),
        step)

    def group_body(carry, xs):
        self_lp, cross_lp = xs
        carry, _ = L.xscan(self_body, carry, self_lp)
        carry = cross_body(carry, cross_lp)
        return carry, None

    h, _ = L.xscan(group_body, h, (grouped_self, params["cross_layers"]))
    return h


def lm_loss(params: dict, batch: dict, cfg: ModelConfig,
            step: StepConfig) -> jax.Array:
    tokens = batch["tokens"]
    h = lm_hidden(params, tokens, cfg, step,
                  image_embeds=batch.get("image_embeds"))
    targets, mask = L.next_token_targets(tokens)
    loss = L.cross_entropy_loss(params["embed"], h, targets, cfg,
                                chunk=step.loss_chunk, mask=mask)
    if cfg.n_experts:
        # router load-balance aux loss on the first layer's activations is a
        # cheap proxy (full per-layer aux would need scan outputs); weight is
        # standard 0.01.
        h0 = L.embed_tokens(params["embed"], tokens, cfg)
        router0 = jax.tree.map(lambda a: a[0], params["layers"]["moe"])
        loss = loss + 0.01 * moe_lib.aux_load_balance_loss(router0, h0, cfg)
    return loss


# ---------------------------------------------------------------------------
# Prefill & decode
# ---------------------------------------------------------------------------


def kv_cache_spec(cfg: ModelConfig, batch: int, length: int,
                  layers: Optional[int] = None) -> L.KVCacheSpec:
    return L.KVCacheSpec(layers=layers or cfg.n_layers, batch=batch,
                         kv_heads=cfg.n_kv_heads, length=length,
                         head_dim=cfg.head_dim_, dtype=cfg.jdtype)


def lm_prefill(params: dict, batch: dict, cfg: ModelConfig,
               step: StepConfig) -> tuple[jax.Array, dict]:
    """Full-sequence forward that also materializes the decode cache.
    Returns (last-position logits, cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = L.embed_tokens(params["embed"], tokens, cfg)

    def body(carry, lp):
        carry, kv = _self_block(carry, lp, cfg, step, collect_kv=True)
        return carry, kv

    body = jax.checkpoint(body, policy=step.policy()) if step.remat else body
    h, (ks, vs) = L.xscan(body, h, params["layers"])
    h = L.apply_norm(params["ln_f"], h, cfg)
    logits = L.logits_fn(params["embed"], h[:, -1:], cfg)
    pos_tags = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                (cfg.n_layers, B, S))
    cache = {"attn": {"k": ks, "v": vs, "pos": pos_tags}}
    return logits, cache


def vlm_prefill(params: dict, batch: dict, cfg: ModelConfig,
                step: StepConfig) -> tuple[jax.Array, dict]:
    """VLM prefill: self-layer KV collected through the nested scan."""
    tokens, image_embeds = batch["tokens"], batch["image_embeds"]
    B, S = tokens.shape
    n_cross = cfg.n_layers // cfg.cross_attn_every
    per_group = cfg.cross_attn_every - 1
    grouped_self = jax.tree.map(
        lambda a: a.reshape(n_cross, per_group, *a.shape[1:]),
        params["self_layers"])
    h = L.embed_tokens(params["embed"], tokens, cfg)

    def self_body(carry, lp):
        carry, kv = _self_block(carry, lp, cfg, step, collect_kv=True)
        return carry, kv

    def group_body(carry, xs):
        self_lp, cross_lp = xs
        carry, kvs = L.xscan(self_body, carry, self_lp)
        carry = _cross_block(carry, cross_lp, image_embeds, cfg, step)
        return carry, kvs

    h, (ks, vs) = L.xscan(group_body, h,
                               (grouped_self, params["cross_layers"]))
    n_self = n_cross * per_group
    ks = ks.reshape(n_self, *ks.shape[2:])
    vs = vs.reshape(n_self, *vs.shape[2:])
    h = L.apply_norm(params["ln_f"], h, cfg)
    logits = L.logits_fn(params["embed"], h[:, -1:], cfg)
    pos_tags = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                (n_self, B, S))
    return logits, {"attn": {"k": ks, "v": vs, "pos": pos_tags}}


def lm_decode(params: dict, tokens: jax.Array, cache: dict, pos: jax.Array,
              cfg: ModelConfig, step: StepConfig,
              image_embeds: Optional[jax.Array] = None,
              ) -> tuple[jax.Array, dict]:
    """One-token decode. tokens: (B, 1). Returns (logits, new cache)."""
    h = L.embed_tokens(params["embed"], tokens, cfg)
    if cfg.family == "vlm":
        h, new_attn = _vlm_decode_scan(params, h, cache, pos, cfg,
                                       image_embeds, step)
    else:
        def body(carry, xs):
            lp, lc = xs
            a_in = L.apply_norm(lp["ln1"], carry, cfg)
            a, new_lc = L.attention_decode(lp["attn"], a_in, lc, pos, cfg,
                                           window=cfg.window)
            carry = carry + a
            carry = carry + _ffn(lp, L.apply_norm(lp["ln2"], carry, cfg),
                                 cfg, step)
            return carry, new_lc

        h, new_attn = L.xscan(body, h, (params["layers"],
                                             cache["attn"]))
    h = L.apply_norm(params["ln_f"], h, cfg)
    logits = L.logits_fn(params["embed"], h, cfg)
    return logits, {**cache, "attn": new_attn}


def _vlm_decode_scan(params: dict, h: jax.Array, cache: dict, pos: jax.Array,
                     cfg: ModelConfig, image_embeds: jax.Array,
                     step: StepConfig = StepConfig(remat=False)):
    n_cross = cfg.n_layers // cfg.cross_attn_every
    per_group = cfg.cross_attn_every - 1
    grouped_self = jax.tree.map(
        lambda a: a.reshape(n_cross, per_group, *a.shape[1:]),
        params["self_layers"])
    grouped_cache = jax.tree.map(
        lambda a: a.reshape(n_cross, per_group, *a.shape[1:]), cache["attn"])
    step = dataclasses.replace(step, remat=False)

    def self_body(carry, xs):
        lp, lc = xs
        a_in = L.apply_norm(lp["ln1"], carry, cfg)
        a, new_lc = L.attention_decode(lp["attn"], a_in, lc, pos, cfg)
        carry = carry + a
        carry = carry + _ffn(lp, L.apply_norm(lp["ln2"], carry, cfg),
                             cfg, step)
        return carry, new_lc

    def group_body(carry, xs):
        self_lp, self_cache, cross_lp = xs
        carry, new_cache = L.xscan(self_body, carry,
                                        (self_lp, self_cache))
        carry = _cross_block(carry, cross_lp, image_embeds, cfg, step)
        return carry, new_cache

    h, new_cache = L.xscan(
        group_body, h, (grouped_self, grouped_cache, params["cross_layers"]))
    new_attn = jax.tree.map(
        lambda a: a.reshape(n_cross * per_group, *a.shape[2:]), new_cache)
    return h, new_attn

"""Encoder-decoder audio LM (Whisper-style backbone).

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, n_frames, d_enc) directly. Positional
information is sinusoidal (computed, not learned) on both sides — the real
Whisper uses learned decoder positions; we use sinusoidal so decode-shape
cells (32k decoder positions, far past Whisper's 448) stay well-defined
(DESIGN.md §4). Attention is MHA without RoPE, as in the original.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ModelConfig
from .params import ParamDef
from .transformer import StepConfig, _maybe_remat


def _stacked_norm(cfg: ModelConfig, layers: int) -> ParamDef:
    return ParamDef(shape=(layers, cfg.d_model), logical=("layers", "embed_r"),
                    init="ones", dtype=cfg.jdtype)


def encdec_defs(cfg: ModelConfig) -> dict:
    ne, nd = cfg.n_enc_layers, cfg.n_layers
    return {
        "embed": L.embedding_defs(cfg),
        "enc_layers": {
            "ln1": _stacked_norm(cfg, ne),
            "attn": L.attention_defs(cfg, layers=ne),
            "ln2": _stacked_norm(cfg, ne),
            "mlp": L.mlp_defs(cfg, layers=ne),
        },
        "enc_ln_f": L.norm_defs(cfg),
        "dec_layers": {
            "ln1": _stacked_norm(cfg, nd),
            "attn": L.attention_defs(cfg, layers=nd),
            "lnx": _stacked_norm(cfg, nd),
            "xattn": L.attention_defs(cfg, layers=nd, kv_from=cfg.d_enc),
            "ln2": _stacked_norm(cfg, nd),
            "mlp": L.mlp_defs(cfg, layers=nd),
        },
        "ln_f": L.norm_defs(cfg),
    }


def encode(params: dict, frames: jax.Array, cfg: ModelConfig,
           step: StepConfig) -> jax.Array:
    h = frames + L.sinusoidal_positions(frames.shape[1],
                                        cfg.d_enc).astype(frames.dtype)

    def body(c, lp):
        a_in = L.apply_norm(lp["ln1"], c, cfg)
        c = c + L.attention_full(lp["attn"], a_in, cfg, causal=False,
                                 rope=False)
        c = c + L.apply_mlp(lp["mlp"], L.apply_norm(lp["ln2"], c, cfg), cfg)
        return c, None

    body = _maybe_remat(body, step)
    h, _ = L.xscan(body, h, params["enc_layers"])
    return L.apply_norm(params["enc_ln_f"], h, cfg)


def _dec_block(c: jax.Array, lp: dict, enc_out: jax.Array, cfg: ModelConfig,
               step: StepConfig, *, collect_kv: bool = False):
    a_in = L.apply_norm(lp["ln1"], c, cfg)
    if collect_kv:
        q = jnp.einsum("bsd,dhk->bhsk", a_in, lp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bhsk", a_in, lp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bhsk", a_in, lp["attn"]["wv"])
        out = L._attend(q, k, v, causal=True, window=None)
        c = c + jnp.einsum("bhsk,hkd->bsd", out, lp["attn"]["wo"])
        kv = (k, v)
    else:
        c = c + L.attention_full(lp["attn"], a_in, cfg, causal=True,
                                 rope=False, use_flash=step.use_flash,
                                 block_q=step.flash_block_q,
                                 block_k=step.flash_block_k)
        kv = None
    x_in = L.apply_norm(lp["lnx"], c, cfg)
    c = c + L.attention_full(lp["xattn"], x_in, cfg, kv_x=enc_out,
                             causal=False, rope=False)
    c = c + L.apply_mlp(lp["mlp"], L.apply_norm(lp["ln2"], c, cfg), cfg)
    return (c, kv) if collect_kv else c


def decoder_hidden(params: dict, tokens: jax.Array, enc_out: jax.Array,
                   cfg: ModelConfig, step: StepConfig) -> jax.Array:
    h = L.embed_tokens(params["embed"], tokens, cfg)
    h = h + L.sinusoidal_positions(tokens.shape[1],
                                   cfg.d_model).astype(h.dtype)
    body = _maybe_remat(
        lambda c, lp: (_dec_block(c, lp, enc_out, cfg, step), None), step)
    h, _ = L.xscan(body, h, params["dec_layers"])
    return L.apply_norm(params["ln_f"], h, cfg)


def loss_fn(params: dict, batch: dict, cfg: ModelConfig,
            step: StepConfig) -> jax.Array:
    enc_out = encode(params, batch["frames"], cfg, step)
    tokens = batch["tokens"]
    h = decoder_hidden(params, tokens, enc_out, cfg, step)
    targets, mask = L.next_token_targets(tokens)
    return L.cross_entropy_loss(params["embed"], h, targets, cfg,
                                chunk=step.loss_chunk, mask=mask)


# ---------------------------------------------------------------------------
# Prefill & decode
# ---------------------------------------------------------------------------


def cache_shapes(cfg: ModelConfig, batch: int, cache_length: int) -> dict:
    from .transformer import kv_cache_spec
    self_cache = kv_cache_spec(cfg, batch, cache_length).shape_tree()
    cross = jax.ShapeDtypeStruct(
        (cfg.n_layers, batch, cfg.n_kv_heads, cfg.n_frames, cfg.head_dim_),
        cfg.jdtype)
    return {"attn": self_cache, "cross_k": cross, "cross_v": cross}


def cache_logical(cfg: ModelConfig) -> dict:
    kv = ("layers", "cache_batch", "kv_heads", "frames", "head_dim")
    return {"attn": L.KVCacheSpec(1, 1, 1, 1, 1, jnp.bfloat16).logical,
            "cross_k": kv, "cross_v": kv}


def prefill(params: dict, batch: dict, cfg: ModelConfig,
            step: StepConfig) -> tuple[jax.Array, dict]:
    enc_out = encode(params, batch["frames"], cfg, step)
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = L.embed_tokens(params["embed"], tokens, cfg)
    h = h + L.sinusoidal_positions(S, cfg.d_model).astype(h.dtype)

    def body(c, lp):
        c, kv = _dec_block(c, lp, enc_out, cfg, step, collect_kv=True)
        cross_k = jnp.einsum("bsd,dhk->bhsk", enc_out, lp["xattn"]["wk"])
        cross_v = jnp.einsum("bsd,dhk->bhsk", enc_out, lp["xattn"]["wv"])
        return c, (kv, cross_k, cross_v)

    h, (kvs, cross_ks, cross_vs) = L.xscan(body, h,
                                                params["dec_layers"])
    h = L.apply_norm(params["ln_f"], h, cfg)
    logits = L.logits_fn(params["embed"], h[:, -1:], cfg)
    ks, vs = kvs
    pos_tags = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                (cfg.n_layers, B, S))
    cache = {"attn": {"k": ks, "v": vs, "pos": pos_tags},
             "cross_k": cross_ks, "cross_v": cross_vs}
    return logits, cache


def decode(params: dict, tokens: jax.Array, cache: dict, pos: jax.Array,
           cfg: ModelConfig, step: StepConfig) -> tuple[jax.Array, dict]:
    h = L.embed_tokens(params["embed"], tokens, cfg)
    h = h + L.sinusoidal_at(jnp.asarray(pos, jnp.float32),
                            cfg.d_model)[None, None].astype(h.dtype)

    def body(c, xs):
        lp, lc, ck, cv = xs
        a_in = L.apply_norm(lp["ln1"], c, cfg)
        a, new_lc = L.attention_decode(lp["attn"], a_in, lc, pos, cfg,
                                       rope=False)
        c = c + a
        # cross attention over the precomputed encoder K/V
        x_in = L.apply_norm(lp["lnx"], c, cfg)
        q = jnp.einsum("bsd,dhk->bhsk", x_in, lp["xattn"]["wq"])
        out = L._attend(q, ck, cv, causal=False, window=None)
        c = c + jnp.einsum("bhsk,hkd->bsd", out, lp["xattn"]["wo"])
        c = c + L.apply_mlp(lp["mlp"], L.apply_norm(lp["ln2"], c, cfg), cfg)
        return c, new_lc

    h, new_attn = L.xscan(
        body, h, (params["dec_layers"], cache["attn"], cache["cross_k"],
                  cache["cross_v"]))
    h = L.apply_norm(params["ln_f"], h, cfg)
    logits = L.logits_fn(params["embed"], h, cfg)
    return logits, {**cache, "attn": new_attn}

"""Model and workload configuration.

``ModelConfig`` covers all six architecture families in the assigned pool
(dense / moe / enc-dec audio / vlm / ssm / hybrid). Workload shapes are the
four assigned input-shape cells; ``input_specs`` produces the
ShapeDtypeStruct stand-ins the dry-run lowers against.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


def _round_up(x: int, mult: int) -> int:
    return x + (-x) % mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | encdec | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    mlp_type: str = "glu"           # glu | plain | none
    act: str = "silu"               # silu (SwiGLU) | gelu (GeGLU / plain)
    norm: str = "rmsnorm"           # rmsnorm | layernorm (no bias)
    tie_embeddings: bool = True
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 1024      # tokens per dispatch group (DESIGN §5)
    # --- attention variants ---
    window: Optional[int] = None    # sliding-window attention (Mixtral)
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    n_frames: int = 1500            # stub conv-frontend output length
    d_enc: int = 0                  # encoder width (= d_model for whisper)
    # --- vlm (llama-3.2-vision) ---
    cross_attn_every: int = 0       # every Nth layer is cross-attention
    n_image_tokens: int = 0         # stub patch-embedding count
    # --- ssm / hybrid (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    attn_every: int = 0             # zamba2: shared attn block interval
    # --- training ---
    lr_schedule: str = "cosine"     # cosine | wsd (MiniCPM)

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded to 256 so the vocab dim shards over any mesh axis
        (logits for rows >= vocab_size are masked in the loss)."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def supports_long_context(self) -> bool:
        """True iff decode state is sub-quadratic in context (SSM/hybrid or
        sliding-window attention). Pure full-attention archs skip long_500k."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (whisper: dec side)

    def n_params(self) -> int:
        from . import api  # local import to avoid cycle
        from .params import n_params as _np
        return _np(api.param_defs(self))

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k of n_experts count)."""
        if self.n_experts == 0:
            return self.n_params()
        total = self.n_params()
        expert_p = 3 * self.d_model * self.d_ff * self.n_experts * self.n_layers
        active_p = 3 * self.d_model * self.d_ff * self.top_k * self.n_layers
        return total - expert_p + active_p


@dataclasses.dataclass(frozen=True)
class WorkloadShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = WorkloadShape("train_4k", 4096, 256, "train")
PREFILL_32K = WorkloadShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = WorkloadShape("decode_32k", 32768, 128, "decode")
LONG_500K = WorkloadShape("long_500k", 524288, 1, "decode")

SHAPES: dict[str, WorkloadShape] = {s.name: s for s in
                                    (TRAIN_4K, PREFILL_32K, DECODE_32K,
                                     LONG_500K)}


def cell_is_applicable(cfg: ModelConfig, shape: WorkloadShape) -> bool:
    """long_500k only runs for sub-quadratic archs (assignment rule)."""
    if shape.name == "long_500k":
        return cfg.supports_long_context
    return True


def cache_len(cfg: ModelConfig, shape: WorkloadShape) -> int:
    """KV-cache length for a decode cell: sliding-window archs cap the cache
    at the window (that is the point of SWA)."""
    if cfg.window is not None:
        return min(shape.seq_len, cfg.window)
    return shape.seq_len


# ---------------------------------------------------------------------------
# Input stand-ins for lowering (no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: WorkloadShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   {tokens (B,S) i32, [frames|image_embeds]}
    prefill: {tokens (B,S) i32, [frames|image_embeds]}
    decode:  {tokens (B,1) i32, cache pytree, [frames|image_embeds]}
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    d = cfg.jdtype
    out: dict = {}
    if shape.kind in ("train", "prefill"):
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct((B, cfg.n_frames, cfg.d_enc), d)
    if cfg.family == "vlm":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.d_model), d)
    return out

"""Parameter-definition framework: one source of truth for shapes, logical
sharding axes, and initializers.

Every model declares its parameters as a nested tree of :class:`ParamDef`.
From that single tree we derive:
  * ``materialize``  — real arrays (smoke tests, the 100M training example);
  * ``shape_tree``   — ``jax.ShapeDtypeStruct`` stand-ins (the multi-pod
    dry-run lowers against these; nothing is ever allocated);
  * ``spec_tree``    — ``PartitionSpec`` per leaf, resolved from logical axis
    names via :class:`repro.distributed.sharding.ShardingRules` with
    divisibility-aware fallback (an axis that does not divide by its mesh
    axis size is replicated instead — see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

Tree = Any  # nested dict of ParamDef / arrays / ShapeDtypeStruct / specs


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declaration of one parameter tensor."""

    shape: tuple[int, ...]
    logical: tuple[str | None, ...]     # logical axis name per dim
    init: str = "normal"                # normal | zeros | ones | embed
    scale: float | None = None          # stddev override (default: fan-in)
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        if len(self.shape) != len(self.logical):
            raise ValueError(f"shape {self.shape} vs logical {self.logical}")


def _map_tree(fn: Callable[[str, ParamDef], Any], tree: Tree,
              path: str = "") -> Tree:
    if isinstance(tree, ParamDef):
        return fn(path, tree)
    if isinstance(tree, Mapping):
        return {k: _map_tree(fn, v, f"{path}/{k}") for k, v in tree.items()}
    raise TypeError(f"unexpected node at {path!r}: {type(tree)}")


def _fan_in(defn: ParamDef) -> float:
    # For >=2D weights treat all but the last dim as fan-in (our weights are
    # stored (in_dims..., out_dims...) with contraction dims leading).
    if len(defn.shape) < 2:
        return 1.0
    fan = 1.0
    for d in defn.shape[:-1]:
        fan *= d
    return max(fan, 1.0)


def _leaf_key(root: jax.Array, path: str) -> jax.Array:
    digest = int.from_bytes(
        hashlib.sha256(path.encode()).digest()[:4], "little")
    return jax.random.fold_in(root, digest)


def materialize(key: jax.Array, defs: Tree) -> Tree:
    """Initialize real parameter arrays from the definition tree."""

    def init_leaf(path: str, d: ParamDef) -> jax.Array:
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        scale = d.scale if d.scale is not None else _fan_in(d) ** -0.5
        if d.init == "embed":
            scale = d.scale if d.scale is not None else 1.0
        x = jax.random.normal(_leaf_key(key, path), d.shape, jnp.float32)
        return (x * scale).astype(d.dtype)

    return _map_tree(init_leaf, defs)


def shape_tree(defs: Tree) -> Tree:
    """ShapeDtypeStruct stand-ins (for .lower() without allocation)."""
    return _map_tree(lambda _, d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs)


def logical_tree(defs: Tree) -> Tree:
    """The logical-axes tree, same structure as the params."""
    return _map_tree(lambda _, d: d.logical, defs)


def n_params(defs: Tree) -> int:
    total = 0

    def count(_, d: ParamDef):
        nonlocal total
        size = 1
        for s in d.shape:
            size *= s
        total += size
        return None

    _map_tree(count, defs)
    return total

"""SSM (Mamba2) and hybrid (Zamba2-style) LM assemblies.

``ssm`` family: a pure stack of pre-norm Mamba2 blocks (mamba2-130m).
``hybrid`` family: Mamba2 backbone with ONE shared attention+MLP block
applied after every ``cfg.attn_every`` Mamba layers (Zamba2's shared block;
we apply the single shared block at each interval — the per-use LoRA deltas
of the real model are omitted, see DESIGN.md §4). The shared block's params
are closed over in the outer scan so gradients accumulate across all uses.

Decode carries per-layer SSM/conv states plus one KV cache *per shared-block
use* (same params, distinct caches).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from . import layers as L
from . import ssd
from .config import ModelConfig
from .params import ParamDef
from .transformer import StepConfig, _maybe_remat, kv_cache_spec


def _stacked_norm(cfg: ModelConfig, layers: int) -> ParamDef:
    return ParamDef(shape=(layers, cfg.d_model), logical=("layers", "embed_r"),
                    init="ones", dtype=cfg.jdtype)


def ssm_lm_defs(cfg: ModelConfig) -> dict:
    return {
        "embed": L.embedding_defs(cfg),
        "layers": {"ln": _stacked_norm(cfg, cfg.n_layers),
                   "ssd": ssd.ssd_defs(cfg, layers=cfg.n_layers)},
        "ln_f": L.norm_defs(cfg),
    }


def hybrid_lm_defs(cfg: ModelConfig) -> dict:
    return {
        "embed": L.embedding_defs(cfg),
        "layers": {"ln": _stacked_norm(cfg, cfg.n_layers),
                   "ssd": ssd.ssd_defs(cfg, layers=cfg.n_layers)},
        "shared": {
            "ln1": L.norm_defs(cfg),
            "attn": L.attention_defs(cfg),
            "ln2": L.norm_defs(cfg),
            "mlp": L.mlp_defs(cfg),
        },
        "ln_f": L.norm_defs(cfg),
    }


def n_shared_uses(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every if cfg.attn_every else 0


# ---------------------------------------------------------------------------
# Forward (train) and prefill
# ---------------------------------------------------------------------------


def _mamba_block(h: jax.Array, lp: dict, cfg: ModelConfig, *,
                 collect_state: bool = False):
    x_in = L.apply_norm(lp["ln"], h, cfg)
    if collect_state:
        y, state = ssd.ssd_forward(lp["ssd"], x_in, cfg, return_state=True)
        return h + y, state
    return h + ssd.ssd_forward(lp["ssd"], x_in, cfg), None


def _shared_block(h: jax.Array, sp: dict, cfg: ModelConfig, step: StepConfig,
                  *, collect_kv: bool = False):
    a_in = L.apply_norm(sp["ln1"], h, cfg)
    if collect_kv:
        q = jnp.einsum("bsd,dhk->bhsk", a_in, sp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bhsk", a_in, sp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bhsk", a_in, sp["attn"]["wv"])
        pos = jnp.arange(h.shape[1])
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
        out = L._attend(q, k, v, causal=True, window=cfg.window)
        a = jnp.einsum("bhsk,hkd->bsd", out, sp["attn"]["wo"])
        kv = (k, v)
    else:
        a = L.attention_full(sp["attn"], a_in, cfg, causal=True,
                             window=cfg.window, use_flash=step.use_flash,
                             block_q=step.flash_block_q,
                             block_k=step.flash_block_k)
        kv = None
    h = h + a
    h = h + L.apply_mlp(sp["mlp"], L.apply_norm(sp["ln2"], h, cfg), cfg)
    return (h, kv) if collect_kv else h


def hidden(params: dict, tokens: jax.Array, cfg: ModelConfig,
           step: StepConfig) -> jax.Array:
    h = L.embed_tokens(params["embed"], tokens, cfg)
    if cfg.family == "ssm" or not cfg.attn_every:
        body = _maybe_remat(
            lambda c, lp: (_mamba_block(c, lp, cfg)[0], None), step)
        h, _ = L.xscan(body, h, params["layers"])
    else:
        uses = n_shared_uses(cfg)
        grouped = jax.tree.map(
            lambda a: a.reshape(uses, cfg.attn_every, *a.shape[1:]),
            params["layers"])
        inner = _maybe_remat(
            lambda c, lp: (_mamba_block(c, lp, cfg)[0], None), step)
        shared = _maybe_remat(
            lambda c: _shared_block(c, params["shared"], cfg, step), step)

        def group_body(c, lp):
            c, _ = L.xscan(inner, c, lp)
            return shared(c), None

        h, _ = L.xscan(group_body, h, grouped)
    return L.apply_norm(params["ln_f"], h, cfg)


def prefill(params: dict, batch: dict, cfg: ModelConfig,
            step: StepConfig) -> tuple[jax.Array, dict]:
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = L.embed_tokens(params["embed"], tokens, cfg)
    if cfg.family == "ssm" or not cfg.attn_every:
        def body(c, lp):
            c, state = _mamba_block(c, lp, cfg, collect_state=True)
            return c, state

        h, states = L.xscan(body, h, params["layers"])
        cache = {"ssm": states["ssm"], "conv": states["conv"]}
    else:
        uses = n_shared_uses(cfg)
        grouped = jax.tree.map(
            lambda a: a.reshape(uses, cfg.attn_every, *a.shape[1:]),
            params["layers"])

        def inner(c, lp):
            c, state = _mamba_block(c, lp, cfg, collect_state=True)
            return c, state

        def group_body(c, lp):
            c, states = L.xscan(inner, c, lp)
            c, kv = _shared_block(c, params["shared"], cfg, step,
                                  collect_kv=True)
            return c, (states, kv)

        h, (states, kvs) = L.xscan(group_body, h, grouped)
        ssm_states = jax.tree.map(
            lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), states)
        ks, vs = kvs
        pos_tags = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                    (uses, B, S))
        cache = {"ssm": ssm_states["ssm"], "conv": ssm_states["conv"],
                 "attn": {"k": ks, "v": vs, "pos": pos_tags}}
    h = L.apply_norm(params["ln_f"], h, cfg)
    logits = L.logits_fn(params["embed"], h[:, -1:], cfg)
    return logits, cache


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def cache_shapes(cfg: ModelConfig, batch: int, cache_length: int) -> dict:
    shapes = ssd.ssm_cache_shapes(cfg, cfg.n_layers, batch)
    out = {"ssm": shapes["ssm"], "conv": shapes["conv"]}
    if cfg.family == "hybrid" and cfg.attn_every:
        out["attn"] = kv_cache_spec(cfg, batch, cache_length,
                                    layers=n_shared_uses(cfg)).shape_tree()
    return out


def cache_logical(cfg: ModelConfig) -> dict:
    out = dict(ssd.ssm_cache_logical())
    if cfg.family == "hybrid" and cfg.attn_every:
        out["attn"] = L.KVCacheSpec(1, 1, 1, 1, 1, jnp.bfloat16).logical
    return out


def decode(params: dict, tokens: jax.Array, cache: dict, pos: jax.Array,
           cfg: ModelConfig, step: StepConfig) -> tuple[jax.Array, dict]:
    h = L.embed_tokens(params["embed"], tokens, cfg)

    def mamba_body(c, xs):
        lp, lc = xs
        x_in = L.apply_norm(lp["ln"], c, cfg)
        y, new_lc = ssd.ssd_decode(lp["ssd"], x_in, lc, cfg)
        return c + y, new_lc

    if cfg.family == "ssm" or not cfg.attn_every:
        h, new_states = L.xscan(
            mamba_body, h, (params["layers"],
                            {"ssm": cache["ssm"], "conv": cache["conv"]}))
        new_cache = {**cache, **new_states}
    else:
        uses = n_shared_uses(cfg)
        grouped_lp = jax.tree.map(
            lambda a: a.reshape(uses, cfg.attn_every, *a.shape[1:]),
            params["layers"])
        grouped_state = jax.tree.map(
            lambda a: a.reshape(uses, cfg.attn_every, *a.shape[1:]),
            {"ssm": cache["ssm"], "conv": cache["conv"]})

        def group_body(c, xs):
            lp, st, attn_c = xs
            c, new_st = L.xscan(mamba_body, c, (lp, st))
            a_in = L.apply_norm(params["shared"]["ln1"], c, cfg)
            a, new_attn = L.attention_decode(params["shared"]["attn"], a_in,
                                             attn_c, pos, cfg,
                                             window=cfg.window)
            c = c + a
            c = c + L.apply_mlp(params["shared"]["mlp"],
                                L.apply_norm(params["shared"]["ln2"], c, cfg),
                                cfg)
            return c, (new_st, new_attn)

        h, (new_states, new_attn) = L.xscan(
            group_body, h, (grouped_lp, grouped_state, cache["attn"]))
        flat_states = jax.tree.map(
            lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), new_states)
        new_cache = {"ssm": flat_states["ssm"], "conv": flat_states["conv"],
                     "attn": new_attn}
    h = L.apply_norm(params["ln_f"], h, cfg)
    logits = L.logits_fn(params["embed"], h, cfg)
    return logits, new_cache

"""Mamba2 SSD (state-space duality) layer — chunked scan, pure JAX.

Follows Dao & Gu (arXiv:2405.21060): within a chunk the recurrence is
evaluated as a masked attention-like quadratic form (MXU-friendly); across
chunks a (B, H, P, N) state is carried by ``lax.scan``. The chunk length is
``cfg.ssm_chunk`` — a tunable exposed to the autotuner (it trades VMEM-
resident (Q, Q) score tiles against scan sequentiality, exactly the kind of
knob the paper's CI-pruned search is for).

Projections are split per component (z/x/B/C/dt) rather than one fused
in_proj so the TP sharding of ``d_inner`` ("ssm_inner" -> model axis) never
crosses component boundaries (DESIGN.md §5). Decode carries
(ssm_state (B,H,P,N) f32, conv_state (B,W-1,dim)) — O(1) in context length,
which is why the SSM/hybrid archs run the long_500k cell.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..distributed.actctx import shard_act
from .config import ModelConfig
from .layers import xscan
from .params import ParamDef


def ssd_defs(cfg: ModelConfig, layers: int | None = None) -> dict:
    D, Din, N, H, W = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                       cfg.ssm_heads, cfg.conv_width)
    lead = (layers,) if layers else ()
    lax_ = ("layers",) if layers else ()

    def w(shape, logical, **kw):
        return ParamDef(shape=lead + shape, logical=lax_ + logical,
                        dtype=cfg.jdtype, **kw)

    def small(shape, **kw):
        return ParamDef(shape=lead + shape,
                        logical=lax_ + (None,) * len(shape),
                        dtype=jnp.float32, **kw)

    return {
        "in_z": w((D, Din), ("embed", "ssm_inner")),
        "in_x": w((D, Din), ("embed", "ssm_inner")),
        "in_b": w((D, N), ("embed", "ssm_state")),
        "in_c": w((D, N), ("embed", "ssm_state")),
        "in_dt": w((D, H), ("embed", "heads")),
        "conv_x": w((W, Din), ("conv", "ssm_inner"), scale=0.5),
        "conv_b": w((W, N), ("conv", "ssm_state"), scale=0.5),
        "conv_c": w((W, N), ("conv", "ssm_state"), scale=0.5),
        "dt_bias": small((H,), init="zeros"),
        "a_log": small((H,), init="ones"),
        "d_skip": small((H,), init="ones"),
        "norm": w((Din,), (None,), init="ones"),
        "out_proj": w((Din, D), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv, width W (small): x (B, S, C), w (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):
        out = out + pad[:, i:i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def _project(p: dict, u: jax.Array, cfg: ModelConfig):
    """Shared front half of train/decode: projections + conv + dt/A."""
    inner = ("batch", "act_seq", "ssm_inner")[:u.ndim - 1] + ("ssm_inner",) \
        if u.ndim == 3 else ("batch", "ssm_inner")
    z = shard_act(jnp.einsum("...d,di->...i", u, p["in_z"]), inner)
    x = shard_act(jnp.einsum("...d,di->...i", u, p["in_x"]), inner)
    b = jnp.einsum("...d,dn->...n", u, p["in_b"])
    c = jnp.einsum("...d,dn->...n", u, p["in_c"])
    dt = jnp.einsum("...d,dh->...h", u, p["in_dt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["a_log"])                              # (H,), negative
    return z, x, b, c, dt, A


def ssd_forward(p: dict, u: jax.Array, cfg: ModelConfig,
                h0: jax.Array | None = None, return_state: bool = False):
    """Full-sequence SSD. u: (B, S, D) -> (B, S, D).

    ``return_state=True`` additionally returns the decode cache
    {ssm (B,H,P,N) f32, conv (B,W-1,Din+2N)} after the last position
    (prefill path)."""
    import math
    B, S, D = u.shape
    H, P, N, Q = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                  min(cfg.ssm_chunk, S))
    if S % Q:
        Q = math.gcd(S, Q)  # odd test lengths: largest common chunk
    z, x, b, c, dt, A = _project(p, u, cfg)
    if return_state:
        W = cfg.conv_width
        conv_tail = jnp.concatenate([x, b, c], axis=-1)[:, S - (W - 1):, :]
    x = jax.nn.silu(_causal_conv(x, p["conv_x"]))
    b = jax.nn.silu(_causal_conv(b, p["conv_b"]))
    c = jax.nn.silu(_causal_conv(c, p["conv_c"]))

    nc = S // Q
    xh = x.reshape(B, nc, Q, H, P).astype(jnp.float32)
    bh = b.reshape(B, nc, Q, N).astype(jnp.float32)
    ch = c.reshape(B, nc, Q, N).astype(jnp.float32)
    dth = dt.reshape(B, nc, Q, H)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_body(h, inputs):
        xc, bc, cc, dtc = inputs                # (B,Q,H,P) (B,Q,N) (B,Q,N) (B,Q,H)
        a = dtc * A                              # (B,Q,H), <= 0
        cum = jnp.cumsum(a, axis=1)              # (B,Q,H)
        total = cum[:, -1]                       # (B,H)
        # intra-chunk quadratic form
        cum_t = jnp.moveaxis(cum, 1, 2)          # (B,H,Q)
        diff = cum_t[:, :, :, None] - cum_t[:, :, None, :]   # (B,H,Q,Q)
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.where(mask, jnp.exp(diff), 0.0)
        scores = jnp.einsum("bin,bjn->bij", cc, bc)          # (B,Q,Q)
        xdt = xc * dtc[..., None]                            # (B,Q,H,P)
        y_intra = jnp.einsum("bij,bhij,bjhp->bihp", scores, L, xdt)
        # inter-chunk contribution from carried state
        y_inter = jnp.einsum("bin,bhpn->bihp", cc, h) * \
            jnp.exp(cum)[..., None]                          # (B,Q,H,1)
        # state update
        sd = jnp.exp(total[:, None, :] - cum)                # (B,Q,H)
        s_c = jnp.einsum("bjn,bjhp->bhpn", bc, xdt * sd[..., None])
        h_new = jnp.exp(total)[:, :, None, None] * h + s_c
        return h_new, y_intra + y_inter

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)
    inputs = (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(bh, 1, 0),
              jnp.moveaxis(ch, 1, 0), jnp.moveaxis(dth, 1, 0))
    h_final, ys = xscan(chunk_body, h0, inputs)       # (nc,B,Q,H,P)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    y = y + p["d_skip"][:, None] * x.reshape(B, S, H, P).astype(jnp.float32)
    y = y.reshape(B, S, cfg.d_inner)
    # gated RMSNorm (y * silu(z), normalized)
    g = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm"].astype(jnp.float32)
    out = jnp.einsum("bsi,id->bsd", g.astype(u.dtype), p["out_proj"])
    if return_state:
        return out, {"ssm": h_final,
                     "conv": conv_tail.astype(cfg.jdtype)}
    return out


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def ssm_cache_shapes(cfg: ModelConfig, layers: int, batch: int) -> dict:
    H, P, N, W = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                  cfg.conv_width)
    dim = cfg.d_inner + 2 * N
    return {
        "ssm": jax.ShapeDtypeStruct((layers, batch, H, P, N), jnp.float32),
        "conv": jax.ShapeDtypeStruct((layers, batch, W - 1, dim), cfg.jdtype),
    }


def ssm_cache_logical() -> dict:
    return {"ssm": ("layers", "cache_batch", "heads", None, None),
            "conv": ("layers", "cache_batch", None, "ssm_inner")}


def ssm_cache_init(cfg: ModelConfig, layers: int, batch: int) -> dict:
    shapes = ssm_cache_shapes(cfg, layers, batch)
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in shapes.items()}


def ssd_decode(p: dict, u: jax.Array, cache: dict,
               cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """One-token decode. u: (B, 1, D); cache: {ssm (B,H,P,N) f32,
    conv (B,W-1,Din+2N)}. Returns (y (B,1,D), new_cache)."""
    B = u.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, x, b, c, dt, A = _project(p, u[:, 0], cfg)        # (B, ·)
    # conv over the rolling window of raw (pre-activation) projections
    xbc = jnp.concatenate([x, b, c], axis=-1)
    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)
    w_full = jnp.concatenate([p["conv_x"], p["conv_b"], p["conv_c"]], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          w_full.astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out)
    x = conv_out[:, :cfg.d_inner]
    b = conv_out[:, cfg.d_inner:cfg.d_inner + N]
    c = conv_out[:, cfg.d_inner + N:]
    xh = x.reshape(B, H, P)
    decay = jnp.exp(dt * A)                               # (B, H)
    h = cache["ssm"] * decay[..., None, None] + jnp.einsum(
        "bn,bhp->bhpn", b, xh * dt[..., None])
    y = jnp.einsum("bn,bhpn->bhp", c, h)
    y = y + p["d_skip"][:, None] * xh
    y = y.reshape(B, cfg.d_inner)
    g = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm"].astype(jnp.float32)
    out = jnp.einsum("bi,id->bd", g.astype(u.dtype), p["out_proj"])
    new_cache = {"ssm": h, "conv": window[:, 1:].astype(cache["conv"].dtype)}
    return out[:, None, :], new_cache


def ssd_reference_scan(p: dict, u: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Step-by-step recurrence oracle (O(S) sequential) used by tests to
    validate the chunked path."""
    B, S, D = u.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    cache = {k: jnp.zeros(v.shape, v.dtype) for k, v in
             ssm_cache_shapes(cfg, 1, B).items()}
    cache = {"ssm": cache["ssm"][0], "conv": cache["conv"][0]}

    def body(carry, ut):
        y, new_cache = ssd_decode(p, ut[:, None, :], carry, cfg)
        return new_cache, y[:, 0]

    _, ys = jax.lax.scan(body, cache, jnp.moveaxis(u, 1, 0))
    return jnp.moveaxis(ys, 0, 1)

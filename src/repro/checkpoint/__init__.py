"""Fault-tolerant checkpointing: atomic saves, manifests, elastic reshard."""

from .manager import CheckpointManager, load_checkpoint, save_checkpoint

__all__ = ["CheckpointManager", "load_checkpoint", "save_checkpoint"]

"""Checkpointing substrate.

Fault-tolerance properties:
  * **Atomicity** — each checkpoint is written to ``step_NNN.tmp`` and
    renamed only after the manifest (with per-leaf shapes/dtypes and a
    content checksum) is fully flushed; a crash mid-save never corrupts
    the latest restorable state.
  * **Restart** — ``CheckpointManager.restore_latest`` finds the newest
    complete checkpoint; combined with the pure-function data pipeline the
    run resumes bit-exactly from (params, opt_state, step).
  * **Elastic reshard** — tensors are stored UNSHARDED (np arrays) with the
    logical layout in the manifest; ``load_checkpoint`` re-applies any
    target sharding at restore, so the same checkpoint restores onto a
    different mesh shape (shrink/grow after node failure).
  * **Retention** — ``keep`` newest checkpoints are retained; older ones
    are garbage-collected only after a newer one is durable.

Storage is a directory of ``.npz`` shards + ``manifest.json`` — no external
dependencies (the production swap-in would be ocp/tensorstore; the
interface is deliberately the same shape).
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import time
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")

# numpy's npz format cannot represent the ML dtypes; they round-trip as
# same-width integer views with the true dtype recorded in the manifest.
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}" if prefix else k))
        return out
    out[prefix] = np.asarray(tree)
    return out


def _unflatten(flat: dict[str, np.ndarray]) -> Any:
    tree: dict = {}
    for path, value in flat.items():
        node = tree
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    return tree


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Atomically persist a pytree; returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(jax.device_get(tree))
    npz_path = os.path.join(tmp, "arrays.npz")
    storable = {}
    for k, v in flat.items():
        exotic = _EXOTIC.get(str(v.dtype))
        storable[k] = v.view(exotic[1]) if exotic else v
    np.savez(npz_path, **storable)
    checksum = hashlib.sha256()
    with open(npz_path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            checksum.update(block)
    manifest = {
        "step": step,
        "time": time.time(),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "checksum": checksum.hexdigest(),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomicity barrier
    return final


def load_checkpoint(path: str, shardings: Any = None,
                    verify: bool = True) -> tuple[Any, dict]:
    """Load a checkpoint; optionally device_put each leaf with a target
    sharding tree (elastic reshard onto any mesh)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    npz_path = os.path.join(path, "arrays.npz")
    if verify:
        checksum = hashlib.sha256()
        with open(npz_path, "rb") as f:
            for block in iter(lambda: f.read(1 << 20), b""):
                checksum.update(block)
        if checksum.hexdigest() != manifest["checksum"]:
            raise IOError(f"checksum mismatch in {path}")
    with np.load(npz_path) as z:
        flat = {}
        for k in z.files:
            v = z[k]
            true_dtype = manifest["leaves"][k]["dtype"]
            exotic = _EXOTIC.get(true_dtype)
            flat[k] = v.view(exotic[0]) if exotic else v
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            tree, shardings)
    return tree, manifest


class CheckpointManager:
    """Retention + latest-discovery around save/load."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        path = save_checkpoint(self.directory, step, tree, extra)
        self._gc()
        return path

    def _steps(self) -> list[int]:
        if not os.path.isdir(self.directory):
            return []
        out = []
        for entry in os.listdir(self.directory):
            m = _STEP_RE.match(entry)
            if m and os.path.exists(os.path.join(self.directory, entry,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self._steps()
        return steps[-1] if steps else None

    def restore_latest(self, shardings: Any = None
                       ) -> Optional[tuple[Any, dict]]:
        step = self.latest_step()
        if step is None:
            return None
        path = os.path.join(self.directory, f"step_{step:08d}")
        return load_checkpoint(path, shardings)

    def _gc(self) -> None:
        steps = self._steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

"""Training/serving step construction (pjit-ready, mesh-aware)."""

from .steps import StepBundle, build_decode_step, build_prefill_step, build_train_step

__all__ = ["StepBundle", "build_decode_step", "build_prefill_step",
           "build_train_step"]

"""Step builders: bind (model config × workload shape × mesh × sharding
rules × execution knobs) into a jit-able function plus its sharding and
abstract-input trees.

The same bundles serve three consumers:
  * ``launch/dryrun.py``  — ``jit(fn, in_shardings).lower(abstract).compile()``
  * ``launch/train.py``   — real training on the host mesh
  * ``benchmarks``        — step-level wall-clock objectives for the tuner
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed import sharding as sh
from ..distributed.actctx import activation_sharding
from ..models import api
from ..models import layers as layers_lib
from ..models import params as params_lib
from ..models.config import (ModelConfig, WorkloadShape, input_specs)
from ..models.transformer import StepConfig
from ..optim import AdamWConfig, adamw_update, make_schedule, opt_state_defs


@dataclasses.dataclass(frozen=True)
class StepBundle:
    """Everything needed to lower/execute one step function."""

    fn: Callable
    abstract_args: tuple           # ShapeDtypeStruct pytrees, in order
    in_shardings: tuple            # NamedSharding pytrees, same order
    out_shardings: Any             # sharding pytree (or None leaves)
    donate_argnums: tuple = ()

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jitted().lower(*self.abstract_args)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def _named(mesh: Mesh, spec_tree_: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree_,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_shardings(cfg: ModelConfig, shape: WorkloadShape, mesh: Mesh,
                     rules: sh.ShardingRules) -> dict:
    specs = {}
    inputs = input_specs(cfg, shape)
    for name, sds in inputs.items():
        logical = ("batch",) + (None,) * (len(sds.shape) - 1)
        specs[name] = sh.logical_to_spec(logical, sds.shape, rules, mesh)
    return _named(mesh, specs)


def _resolver(mesh: Mesh, rules: sh.ShardingRules):
    """Logical-axes -> NamedSharding resolver for activation constraints."""

    def resolve(logical, shape):
        spec = sh.logical_to_spec(logical, shape, rules, mesh)
        return NamedSharding(mesh, spec)

    return resolve


def _cache_shardings(cfg: ModelConfig, shape: WorkloadShape, mesh: Mesh,
                     rules: sh.ShardingRules) -> Any:
    shapes = api.cache_shapes(cfg, shape)
    logical = api.cache_logical(cfg)

    def walk(shape_node, logical_node):
        if isinstance(shape_node, dict):
            return {k: walk(shape_node[k], logical_node[k])
                    for k in shape_node}
        return NamedSharding(mesh, sh.logical_to_spec(
            logical_node, shape_node.shape, rules, mesh))

    return walk(shapes, logical)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, shape: WorkloadShape, mesh: Mesh,
                     rules: Optional[sh.ShardingRules] = None,
                     step_cfg: StepConfig = StepConfig(),
                     opt_cfg: AdamWConfig = AdamWConfig(),
                     peak_lr: float = 3e-4, total_steps: int = 10_000,
                     ) -> StepBundle:
    rules = rules or sh.TRAIN_RULES
    defs = api.param_defs(cfg)
    opt_defs = opt_state_defs(defs)
    schedule = make_schedule(cfg.lr_schedule, peak_lr, total_steps)
    k = step_cfg.microbatches

    def train_step(params, opt_state, batch, step_idx):
        with activation_sharding(_resolver(mesh, rules)):
            return _train_step(params, opt_state, batch, step_idx)

    def _train_step(params, opt_state, batch, step_idx):
        def loss_of(p, b):
            return api.loss_fn(p, b, cfg, step_cfg)

        if k == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            def micro(accum, mb):
                l, g = jax.value_and_grad(loss_of)(params, mb)
                return jax.tree.map(jnp.add, accum,
                                    {"l": l, "g": g}), None

            mbs = jax.tree.map(
                lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch)
            zero = {"l": jnp.zeros((), jnp.float32),
                    "g": jax.tree.map(
                        lambda x: jnp.zeros(x.shape, jnp.float32), params)}
            accum, _ = layers_lib.xscan(micro, zero, mbs)
            loss = accum["l"] / k
            grads = jax.tree.map(lambda g: g / k, accum["g"])
        if step_cfg.grad_bf16:
            # halve gradient-sync traffic; Adam moments stay f32
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        lr = schedule(step_idx)
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  lr, opt_cfg)
        metrics = {"loss": loss, **metrics}
        return params, opt_state, metrics

    param_shapes = params_lib.shape_tree(defs)
    opt_shapes = params_lib.shape_tree(opt_defs)
    param_shard = sh.sharding_tree(defs, rules, mesh)
    opt_shard = sh.sharding_tree(opt_defs, rules, mesh)
    batch_shapes = input_specs(cfg, shape)
    batch_shard = _batch_shardings(cfg, shape, mesh, rules)
    idx_shape = jax.ShapeDtypeStruct((), jnp.int32)
    idx_shard = NamedSharding(mesh, P())
    metric_shard = {"loss": idx_shard, "grad_norm": idx_shard,
                    "lr": idx_shard}
    return StepBundle(
        fn=train_step,
        abstract_args=(param_shapes, opt_shapes, batch_shapes, idx_shape),
        in_shardings=(param_shard, opt_shard, batch_shard, idx_shard),
        out_shardings=(param_shard, opt_shard, metric_shard),
        donate_argnums=(0, 1),
    )


# ---------------------------------------------------------------------------
# prefill / decode steps
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, shape: WorkloadShape, mesh: Mesh,
                       rules: Optional[sh.ShardingRules] = None,
                       step_cfg: StepConfig = StepConfig()) -> StepBundle:
    rules = rules or sh.SERVE_RULES
    defs = api.param_defs(cfg)

    def prefill_step(params, batch):
        with activation_sharding(_resolver(mesh, rules)):
            return api.prefill_fn(params, batch, cfg, step_cfg)

    param_shapes = params_lib.shape_tree(defs)
    param_shard = sh.sharding_tree(defs, rules, mesh)
    batch_shapes = input_specs(cfg, shape)
    batch_shard = _batch_shardings(cfg, shape, mesh, rules)
    logits_shard = NamedSharding(mesh, sh.logical_to_spec(
        ("batch", None, "vocab"),
        (shape.global_batch, 1, cfg.vocab_padded), rules, mesh))
    cache_shard = _cache_shardings(cfg, shape, mesh, rules)
    return StepBundle(
        fn=prefill_step,
        abstract_args=(param_shapes, batch_shapes),
        in_shardings=(param_shard, batch_shard),
        out_shardings=(logits_shard, cache_shard),
    )


def build_decode_step(cfg: ModelConfig, shape: WorkloadShape, mesh: Mesh,
                      rules: Optional[sh.ShardingRules] = None,
                      step_cfg: StepConfig = StepConfig()) -> StepBundle:
    rules = rules or sh.SERVE_RULES
    defs = api.param_defs(cfg)

    def decode_step(params, batch, cache, pos):
        with activation_sharding(_resolver(mesh, rules)):
            return api.decode_fn(params, batch, cache, pos, cfg, step_cfg)

    param_shapes = params_lib.shape_tree(defs)
    param_shard = sh.sharding_tree(defs, rules, mesh)
    batch_shapes = input_specs(cfg, shape)
    batch_shard = _batch_shardings(cfg, shape, mesh, rules)
    cache_shapes_ = api.cache_shapes(cfg, shape)
    cache_shard = _cache_shardings(cfg, shape, mesh, rules)
    pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
    pos_shard = NamedSharding(mesh, P())
    logits_shard = NamedSharding(mesh, sh.logical_to_spec(
        ("batch", None, "vocab"),
        (shape.global_batch, 1, cfg.vocab_padded), rules, mesh))
    return StepBundle(
        fn=decode_step,
        abstract_args=(param_shapes, batch_shapes, cache_shapes_, pos_shape),
        in_shardings=(param_shard, batch_shard, cache_shard, pos_shard),
        out_shardings=(logits_shard, cache_shard),
        donate_argnums=(2,),
    )


def default_step_cfg(cfg: ModelConfig, shape: WorkloadShape) -> StepConfig:
    """Per-arch execution defaults: large models accumulate gradients over
    microbatches to bound the per-layer residual stacks (§Perf)."""
    if shape.kind == "train" and cfg.n_params() > 10e9:
        return StepConfig(microbatches=4)
    return StepConfig()


def build_step(cfg: ModelConfig, shape: WorkloadShape, mesh: Mesh,
               rules: Optional[sh.ShardingRules] = None,
               step_cfg: Optional[StepConfig] = None) -> StepBundle:
    """Dispatch on the workload kind (train/prefill/decode)."""
    if step_cfg is None:
        step_cfg = default_step_cfg(cfg, shape)
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, rules, step_cfg)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, rules, step_cfg)
    return build_decode_step(cfg, shape, mesh, rules, step_cfg)

"""Pass 2 — harness lint: AST checks for timing pitfalls.

A *timed region* is the span between ``t0 = <clock>()`` and the last
statement subtracting ``t0`` in the same statement block — the
gettimeofday-around-the-kernel pattern the paper (and
``timed_sampler``) uses. Within and around such regions this pass flags:

  MS201  region performs device work (jax/jnp call or a jitted callable)
         with no ``block_until_ready`` before the clock stops — async
         dispatch means the measured time excludes the actual compute
  MS202  ``time.time()`` in a timing path (wall clock, not monotonic;
         timestamps outside subtraction chains are fine)
  MS203  ``jax.jit`` invoked inside a loop in a timed region —
         recompilation is timed as if it were kernel work
  MS204  a device computation's result discarded inside a timed region —
         nothing forces the work to exist (DCE) or to finish (async)
  MS205  unseeded legacy RNG (``numpy.random.*`` module functions,
         stdlib ``random.*``) — benchmark data must be reproducible
  MS206  ``block_until_ready`` on one name of a multi-output unpacking
         whose sibling outputs are used later — the clock stops while
         the unsynced outputs may still be computing
  MS207  ``jax.jit`` invoked directly inside an *invocation factory*
         (a scope named ``factory``/``make_invocation``, or one
         returning a ``timed_sampler``/``steady_sampler``) — the
         factory runs once per outer-loop invocation, so every
         invocation re-traces the same kernel; route compilation
         through ``repro.core.ExecutableCache`` instead

Heuristics are deliberately scoped to this repo's idioms: opaque calls
(``fn()``, ``tuner.tune()``) are trusted to sync internally, so timing
wrappers over callbacks do not false-positive. Suppress intentional
exceptions with ``# lint: ok=MS2xx`` on the flagged line.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, Iterator, Optional

from .findings import Finding, make_finding

__all__ = ["lint_file", "lint_paths", "lint_source"]

_CLOCKS = {
    "time.time", "time.time_ns", "time.clock",
    "time.perf_counter", "time.perf_counter_ns",
    "time.monotonic", "time.monotonic_ns",
}
_WALL_CLOCKS = {"time.time", "time.time_ns", "time.clock"}

_SEEDED_NUMPY = {"default_rng", "Generator", "SeedSequence", "RandomState",
                 "BitGenerator", "PCG64", "MT19937", "Philox", "SFC64"}
_SEEDED_STDLIB = {"Random", "SystemRandom", "seed", "getstate", "setstate"}


def _dotted(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base is not None else None
    return None


def _walk_stmts(stmts: list[ast.stmt]) -> Iterator[ast.stmt]:
    """Statements of one scope, descending control flow but not defs."""
    for st in stmts:
        yield st
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            continue
        for field in ("body", "orelse", "finalbody"):
            sub = getattr(st, field, None)
            if sub:
                yield from _walk_stmts(sub)
        for handler in getattr(st, "handlers", ()):
            yield from _walk_stmts(handler.body)


def _child_functions(stmts: list[ast.stmt]) -> Iterator[ast.AST]:
    """Function scopes directly inside this scope (class bodies are
    transparent: methods chain to the enclosing module scope)."""
    for st in stmts:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield st
        elif isinstance(st, ast.ClassDef):
            yield from _child_functions(st.body)
        else:
            for field in ("body", "orelse", "finalbody"):
                sub = getattr(st, field, None)
                if sub:
                    yield from _child_functions(sub)
            for handler in getattr(st, "handlers", ()):
                yield from _child_functions(handler.body)


def _subtracts(st: ast.stmt, name: str) -> bool:
    """Does this statement compute ``... - name`` (or ``name - ...``)?"""
    for node in ast.walk(st):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub):
            for side in (node.left, node.right):
                if isinstance(side, ast.Name) and side.id == name:
                    return True
    return False


class _Scope:
    """Timed-region analysis of one function (or the module body)."""

    def __init__(self, linter: "_FileLinter", node: ast.AST,
                 jitted: frozenset[str]):
        self.linter = linter
        self.node = node
        self.jitted = set(jitted)

    # -- name resolution ------------------------------------------------------
    def resolve(self, dotted: Optional[str]) -> Optional[str]:
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        full = self.linter.aliases.get(head, head)
        return f"{full}.{rest}" if rest else full

    def call_name(self, call: ast.Call) -> Optional[str]:
        return self.resolve(_dotted(call.func))

    def is_clock(self, node: ast.AST) -> Optional[str]:
        """Resolved clock name when ``node`` is a clock call."""
        if not isinstance(node, ast.Call):
            return None
        name = self.call_name(node)
        if name in _CLOCKS:
            return name
        # injected clocks (``self.clock()``, ``clock()``): treated monotonic
        if name is not None and (name == "clock" or name.endswith(".clock")):
            return "clock"
        return None

    def is_sync(self, call: ast.Call) -> bool:
        name = self.call_name(call)
        return name is not None and name.endswith("block_until_ready")

    def is_device_call(self, call: ast.Call) -> bool:
        """Does this call visibly dispatch device work? Opaque calls
        (plain callbacks) are trusted to sync internally."""
        name = self.call_name(call)
        if name is None:
            return False
        if name.endswith("block_until_ready") or name.startswith("jax.debug"):
            return False
        if name == "jax" or name.startswith("jax."):
            return True
        return name.split(".")[0] in self.jitted

    # -- scanning -------------------------------------------------------------
    def scan(self) -> None:
        body = getattr(self.node, "body", [])
        self._collect_jitted(body)
        self._check_factory_jit(body)
        for block in self._blocks(body):
            self._scan_block(block)

    # -- MS207: invocation factories must use the executable cache -----------
    def _is_invocation_factory(self) -> bool:
        """An invocation-factory scope: named like one, or returning a
        sampler constructed by ``timed_sampler``/``steady_sampler``."""
        node = self.node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return False
        if node.name in ("factory", "make_invocation"):
            return True
        for st in _walk_stmts(node.body):
            if isinstance(st, ast.Return) and isinstance(st.value, ast.Call):
                name = self.call_name(st.value)
                leaf = name.rsplit(".", 1)[-1] if name else ""
                if leaf in ("timed_sampler", "steady_sampler"):
                    return True
        return False

    def _check_factory_jit(self, stmts: list[ast.stmt]) -> None:
        if not self._is_invocation_factory():
            return
        for st in _walk_stmts(stmts):
            for node in ast.walk(st):
                if isinstance(node, ast.Call) \
                        and self.call_name(node) == "jax.jit":
                    self._flag("MS207", node,
                               "jax.jit inside an invocation factory "
                               "re-traces the kernel every outer-loop "
                               "invocation — compile once through "
                               "ExecutableCache.compile (see "
                               "repro.core.exec_cache)")

    def _collect_jitted(self, stmts: list[ast.stmt]) -> None:
        """Names bound to jitted callables: ``f = jax.jit(g)`` or
        ``step = builder(...).jitted()``."""
        for st in _walk_stmts(stmts):
            if isinstance(st, ast.Assign) and len(st.targets) == 1 \
                    and isinstance(st.targets[0], ast.Name) \
                    and isinstance(st.value, ast.Call):
                name = self.call_name(st.value)
                jitted = name == "jax.jit" if name is not None else False
                # ``builder(...).jitted()``: the receiver is a call, so the
                # dotted chain is unresolvable — match the attr directly
                if isinstance(st.value.func, ast.Attribute) \
                        and st.value.func.attr == "jitted":
                    jitted = True
                if jitted:
                    self.jitted.add(st.targets[0].id)

    def _blocks(self, stmts: list[ast.stmt]) -> Iterator[list[ast.stmt]]:
        """Every statement list in this scope, stopping at nested defs."""
        yield stmts
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue
            for field in ("body", "orelse", "finalbody"):
                sub = getattr(st, field, None)
                if sub:
                    yield from self._blocks(sub)
            for handler in getattr(st, "handlers", ()):
                yield from self._blocks(handler.body)

    def _scan_block(self, stmts: list[ast.stmt]) -> None:
        for i, st in enumerate(stmts):
            if not (isinstance(st, ast.Assign) and len(st.targets) == 1
                    and isinstance(st.targets[0], ast.Name)):
                continue
            clock = self.is_clock(st.value)
            if clock is None:
                continue
            t0 = st.targets[0].id
            limit = len(stmts)   # a later ``t0 = clock()`` starts a new region
            for j in range(i + 1, len(stmts)):
                nxt = stmts[j]
                if isinstance(nxt, ast.Assign) \
                        and any(isinstance(t, ast.Name) and t.id == t0
                                for t in nxt.targets):
                    limit = j
                    break
            ends = [j for j in range(i + 1, limit)
                    if _subtracts(stmts[j], t0)]
            if not ends:
                continue   # never differenced: a timestamp, not a timer
            if clock in _WALL_CLOCKS:
                self._flag("MS202", st,
                           f"{t0} = {clock}(): wall clock in a timing "
                           f"path; use time.perf_counter")
            region = stmts[i + 1:ends[-1] + 1]
            self._check_region(region, stmts[ends[-1]], stmts[ends[-1] + 1:])

    def _check_region(self, region: list[ast.stmt], end: ast.stmt,
                      after: list[ast.stmt]) -> None:
        device_calls: list[ast.Call] = []
        syncs: list[ast.Call] = []
        for st in region:
            for node in ast.walk(st):
                if not isinstance(node, ast.Call):
                    continue
                if self.is_sync(node):
                    syncs.append(node)
                elif self.is_device_call(node):
                    device_calls.append(node)
                if st is end and self.call_name(node) in _WALL_CLOCKS:
                    self._flag("MS202", node,
                               "time.time() closes a timed region; "
                               "use time.perf_counter")
        if device_calls and not syncs:
            self._flag("MS201", end,
                       "timed region dispatches device work (line "
                       f"{device_calls[0].lineno}) but never calls "
                       "block_until_ready before reading the clock")
        for st in region:
            if isinstance(st, ast.Expr) and isinstance(st.value, ast.Call) \
                    and self.is_device_call(st.value):
                self._flag("MS204", st,
                           "device computation result discarded inside a "
                           "timed region — DCE/async dispatch make the "
                           "timing meaningless; bind and sync it")
            for loop in ast.walk(st):
                if isinstance(loop, (ast.For, ast.While)):
                    for node in ast.walk(loop):
                        if isinstance(node, ast.Call) \
                                and self.call_name(node) == "jax.jit":
                            self._flag("MS203", node,
                                       "jax.jit invoked inside a timed "
                                       "loop — compilation is measured "
                                       "as if it were kernel time")
        self._check_partial_sync(region, after, syncs)

    def _check_partial_sync(self, region: list[ast.stmt],
                            after: list[ast.stmt],
                            syncs: list[ast.Call]) -> None:
        unpacked: dict[str, set[str]] = {}
        for st in region:
            if isinstance(st, ast.Assign) and len(st.targets) == 1 \
                    and isinstance(st.targets[0], ast.Tuple) \
                    and isinstance(st.value, ast.Call) \
                    and self.is_device_call(st.value):
                names = {e.id for e in st.targets[0].elts
                         if isinstance(e, ast.Name)}
                for n in names:
                    unpacked[n] = names
        if not unpacked:
            return
        used_after = {node.id for st in after for node in ast.walk(st)
                      if isinstance(node, ast.Name)
                      and isinstance(node.ctx, ast.Load)}
        for sync in syncs:
            if len(sync.args) != 1 or not isinstance(sync.args[0], ast.Name):
                continue
            synced = sync.args[0].id
            siblings = unpacked.get(synced, set()) - {synced}
            stale = sorted(siblings & used_after)
            if stale:
                self._flag("MS206", sync,
                           f"block_until_ready({synced}) leaves sibling "
                           f"output(s) {', '.join(stale)} unsynced but used "
                           f"later — sync the full tuple so the timed "
                           f"region covers all outputs")

    def _flag(self, code: str, node: ast.AST, message: str) -> None:
        self.linter.findings.append(make_finding(
            code, self.linter.path, getattr(node, "lineno", 0), message))


class _FileLinter:
    def __init__(self, path: str, tree: ast.Module):
        self.path = path
        self.tree = tree
        self.aliases: dict[str, str] = {}
        self.findings: list[Finding] = []
        self._collect_imports()

    def _collect_imports(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:
                        self.aliases[a.asname] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for a in node.names:
                    self.aliases[a.asname or a.name] = \
                        f"{node.module}.{a.name}"

    def run(self) -> list[Finding]:
        self._visit_scope(self.tree, frozenset())
        self._check_rng()
        return self.findings

    def _visit_scope(self, node: ast.AST, jitted: frozenset[str]) -> None:
        scope = _Scope(self, node, jitted)
        scope.scan()
        inherited = frozenset(scope.jitted)
        for fn in _child_functions(getattr(node, "body", [])):
            self._visit_scope(fn, inherited)

    def _check_rng(self) -> None:
        scope = _Scope(self, self.tree, frozenset())
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            name = scope.call_name(node)
            if name is None or "." not in name:
                continue
            head, leaf = name.rsplit(".", 1)
            if head in ("numpy.random", "np.random") \
                    and leaf not in _SEEDED_NUMPY:
                self.findings.append(make_finding(
                    "MS205", self.path, node.lineno,
                    f"{name}: legacy global-state RNG — benchmark data "
                    f"must come from a seeded numpy Generator "
                    f"(default_rng(seed))"))
            elif head == "random" and leaf not in _SEEDED_STDLIB:
                self.findings.append(make_finding(
                    "MS205", self.path, node.lineno,
                    f"{name}: unseeded stdlib RNG — use a seeded "
                    f"random.Random(seed) instance"))


def lint_source(source: str, path: str = "<string>") -> list[Finding]:
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [make_finding("MS104", path, e.lineno or 0,
                             f"file does not parse: {e.msg}")]
    return _FileLinter(path, tree).run()


def lint_file(path: str | Path) -> list[Finding]:
    return lint_source(Path(path).read_text(encoding="utf-8"), str(path))


def lint_paths(paths: Iterable[str | Path],
               exclude: Iterable[str] = ()) -> list[Finding]:
    """Lint every ``*.py`` under the given files/directories."""
    out: list[Finding] = []
    skip = tuple(exclude)
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            if any(s in str(f) for s in skip):
                continue
            out.extend(lint_file(f))
    return out

"""Finding model for the measurement-soundness linter.

Every check in the three passes (workload audit, harness lint, lock
discipline — see ``docs/linting.md``) reports :class:`Finding`s carrying a
**stable code** from :data:`CODES`. Codes are part of the tool's contract:
CI configs, suppression comments and the JSON report all key on them, so a
code is never renumbered or reused once released.

Suppression is per-line, in the linted source itself::

    t1 = time.time() - t0   # lint: ok=MS202
    risky_call()            # lint: ok          (suppresses every code)

The marker must sit on the exact line a finding anchors to.
"""

from __future__ import annotations

import dataclasses
import re
from pathlib import Path
from typing import Iterable, Optional

__all__ = ["CODES", "Finding", "LINT_VERSION", "WorkloadAuditError",
           "WorkloadAuditWarning", "filter_suppressed", "findings_to_json",
           "make_finding", "worst_severity"]

LINT_VERSION = 1

#: severity ordering, mildest first; exit codes treat >= "warning" as dirty
_SEVERITIES = ("info", "warning", "error")

#: code -> (pass name, default severity, short title). Codes are stable:
#: MS1xx = workload audit, MS2xx = harness lint, MS3xx = lock discipline.
CODES: dict[str, tuple[str, str, str]] = {
    "MS100": ("workload", "info",
              "benchmark declares no audit spec; workload audit skipped"),
    "MS101": ("workload", "error",
              "declared work term diverges from traced cost"),
    "MS102": ("workload", "error",
              "timed computation is dead or constant-folded"),
    "MS103": ("workload", "warning",
              "traced compute dtype differs from the declared dtype"),
    "MS104": ("workload", "warning",
              "workload audit could not trace the benchmark"),
    "MS201": ("harness", "warning",
              "timed region has device work but no block_until_ready"),
    "MS202": ("harness", "warning",
              "time.time() used in a timing path (use perf_counter)"),
    "MS203": ("harness", "warning",
              "jax.jit invoked inside a timed loop"),
    "MS204": ("harness", "warning",
              "device computation discarded inside a timed region"),
    "MS205": ("harness", "warning",
              "unseeded RNG in benchmark data generation"),
    "MS206": ("harness", "warning",
              "sync covers only part of the timed computation's outputs"),
    "MS207": ("harness", "warning",
              "jax.jit inside an invocation factory bypasses the "
              "executable cache"),
    "MS301": ("locks", "error",
              "shared JSONL write outside an exclusive flock"),
    "MS302": ("locks", "error",
              "flock on a replaceable file without post-lock inode re-check"),
    "MS303": ("locks", "error",
              "shared-file rewrite without temp + fsync + os.replace"),
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One linter finding, anchored to a source location."""

    code: str
    path: str          # repo-relative when produced by scripts/lint.py
    line: int          # 1-based; 0 when the finding is file/benchmark-level
    message: str
    severity: str = "warning"
    pass_name: str = ""

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.code} [{self.severity}] {self.message}"

    def to_json(self) -> dict:
        return {"code": self.code, "path": self.path, "line": self.line,
                "severity": self.severity, "pass": self.pass_name,
                "message": self.message}


def make_finding(code: str, path: str, line: int, message: str) -> Finding:
    """Build a finding with the code's registered pass/severity."""
    pass_name, severity, _title = CODES[code]
    return Finding(code=code, path=str(path), line=line, message=message,
                   severity=severity, pass_name=pass_name)


class WorkloadAuditError(RuntimeError):
    """Raised by the engine's strict pre-run validation: the benchmark's
    declared workload failed the audit, so no trial was executed."""

    def __init__(self, findings: Iterable[Finding]):
        self.findings = tuple(findings)
        super().__init__("workload audit failed:\n" + "\n".join(
            f"  {f.render()}" for f in self.findings))


class WorkloadAuditWarning(UserWarning):
    """Category for warn-mode pre-run validation findings."""


_SUPPRESS_RE = re.compile(r"#\s*lint:\s*ok(?:=(?P<codes>[A-Z0-9, ]+))?")


def _suppressed_codes(source_line: str) -> Optional[set[str]]:
    """Codes suppressed on this line: an empty set means *all* codes."""
    m = _SUPPRESS_RE.search(source_line)
    if m is None:
        return None
    codes = m.group("codes")
    if codes is None:
        return set()
    return {c.strip() for c in codes.split(",") if c.strip()}


def filter_suppressed(findings: Iterable[Finding]) -> list[Finding]:
    """Drop findings whose anchor line carries a ``# lint: ok`` marker."""
    out: list[Finding] = []
    sources: dict[str, list[str]] = {}
    for f in findings:
        if f.line > 0:
            if f.path not in sources:
                try:
                    text = Path(f.path).read_text(encoding="utf-8")
                except OSError:
                    text = ""
                sources[f.path] = text.splitlines()
            lines = sources[f.path]
            if 0 < f.line <= len(lines):
                codes = _suppressed_codes(lines[f.line - 1])
                if codes is not None and (not codes or f.code in codes):
                    continue
        out.append(f)
    return out


def worst_severity(findings: Iterable[Finding]) -> Optional[str]:
    worst = -1
    for f in findings:
        worst = max(worst, _SEVERITIES.index(f.severity))
    return _SEVERITIES[worst] if worst >= 0 else None


def findings_to_json(findings: Iterable[Finding]) -> dict:
    """The stable ``scripts/lint.py --json`` document."""
    fs = sorted(findings, key=lambda f: (f.path, f.line, f.code))
    counts = {"error": 0, "warning": 0, "info": 0}
    for f in fs:
        counts[f.severity] = counts.get(f.severity, 0) + 1
    return {"lint_version": LINT_VERSION,
            "findings": [f.to_json() for f in fs],
            "summary": counts}

"""Measurement-soundness linter: static audit of benchmarks, timing
harness, and ledger lock discipline.

Three passes, three failure classes the paper's methodology cannot
tolerate (``docs/linting.md`` has the full catalogue):

  1. **workload audit** (:mod:`.workload`, MS1xx) — traces each
     benchmark's kernel and cross-checks the *declared* work term the
     evaluator divides by against the compiler's *actual* cost
  2. **harness lint** (:mod:`.harness`, MS2xx) — AST checks for timing
     pitfalls: missing ``block_until_ready``, wall clocks, jit inside
     timed loops, discarded results, unseeded RNG, partial syncs
  3. **lock discipline** (:mod:`.locks`, MS3xx) — concurrency
     invariants of the shared JSONL stores (flock, inode re-check,
     temp+fsync+replace)

``scripts/lint.py`` is the CLI; ``Tuner.tune(validate=...)`` runs pass 1
as a pre-run gate so a mis-declared workload is caught before the first
trial burns measurement time.
"""

from .findings import (CODES, LINT_VERSION, Finding, WorkloadAuditError,
                       WorkloadAuditWarning, filter_suppressed,
                       findings_to_json, make_finding, worst_severity)
from .harness import lint_file, lint_paths, lint_source
from .locks import (DEFAULT_LOCK_TARGETS, check_lock_discipline,
                    check_lock_source)
from .workload import (TracedCost, WorkloadSpec, audit_benchmark,
                       audit_workload, trace_cost)

__all__ = [
    "CODES", "DEFAULT_LOCK_TARGETS", "Finding", "LINT_VERSION",
    "TracedCost", "WorkloadAuditError", "WorkloadAuditWarning",
    "WorkloadSpec", "audit_benchmark", "audit_workload",
    "check_lock_discipline", "check_lock_source", "filter_suppressed",
    "findings_to_json", "lint_file", "lint_paths", "lint_source",
    "make_finding", "trace_cost", "worst_severity",
]

"""Pass 3 — lock discipline: concurrency invariants of shared JSONL stores.

The trial cache and the run ledger are *shared files*: multiple processes
(parallel sessions, a compaction, a perf-gate report) may touch the same
path concurrently. The repo's protocol for that — established by
:class:`repro.history.ledger.RunLedger` — has three invariants this pass
encodes as checks over the AST of the store modules:

  MS301  every write-mode ``open(self.path, ...)`` / ``os.replace(...,
         self.path)`` happens in a function that holds the exclusive
         advisory ``flock`` itself or runs inside a ``with
         self.<helper>()`` whose helper does
  MS302  when the module atomically replaces the shared file
         (``os.replace``), the flock-holding open helper must re-check
         the inode after locking (``os.fstat`` vs ``os.stat``) — an
         flock on a replaced inode serializes nothing
  MS303  rewrites must be crash-safe: never ``open(self.path, "w")`` in
         place, and every ``os.replace`` onto the shared path must
         ``os.fsync`` the temp file first

The *shared path* is recognized structurally: any expression ending in the
configured attribute (default ``.path`` — ``self.path``,
``self.ledger.path``, ...). Temp siblings (``self.path.with_name(...)``
bound to a local) are not shared. Read-mode opens are unchecked: JSONL
readers tolerate torn trailing lines by design.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, Optional

from .findings import Finding, make_finding

__all__ = ["DEFAULT_LOCK_TARGETS", "check_lock_discipline",
           "check_lock_source"]

#: the modules whose on-disk stores are shared across processes
DEFAULT_LOCK_TARGETS = ("src/repro/core/cache.py",
                        "src/repro/history/ledger.py")

_WRITE_MODES = {"a", "a+", "ab", "a+b", "w", "w+", "wb", "w+b", "r+", "r+b"}
_TRUNCATE_MODES = {"w", "w+", "wb", "w+b"}

_COMPOUND_HEADERS = ("test", "iter", "target", "subject")


def _unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:
        return ""


def _is_shared(node: ast.AST, attr: str) -> bool:
    """Is this expression the shared store path (``*.{attr}``)?"""
    text = _unparse(node)
    return text == attr or text.endswith(f".{attr}")


def _open_mode(call: ast.Call) -> Optional[str]:
    """The mode of an ``open`` call, "r" when omitted, None if dynamic."""
    mode: ast.AST
    if len(call.args) >= 2:
        mode = call.args[1]
    else:
        kw = {k.arg: k.value for k in call.keywords}
        if "mode" not in kw:
            return "r"
        mode = kw["mode"]
    if isinstance(mode, ast.Constant) and isinstance(mode.value, str):
        return mode.value
    return None


def _call_name(call: ast.Call) -> str:
    return _unparse(call.func)


def _has_call(node: ast.AST, *names: str) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and _call_name(sub) in names:
            return True
    return False


def _holds_flock(fn: ast.AST) -> bool:
    """Does this function itself take an exclusive flock?"""
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and _call_name(node).endswith("flock"):
            if any("LOCK_EX" in _unparse(a) for a in node.args):
                return True
    return False


class _ModuleChecker:
    def __init__(self, path: str, tree: ast.Module, attr: str):
        self.path = path
        self.tree = tree
        self.attr = attr
        self.findings: list[Finding] = []
        self.functions = [n for n in ast.walk(tree)
                          if isinstance(n, (ast.FunctionDef,
                                            ast.AsyncFunctionDef))]
        self.flock_helpers = {fn.name for fn in self.functions
                              if _holds_flock(fn)}
        self.module_replaces_shared = any(
            isinstance(n, ast.Call) and _call_name(n) == "os.replace"
            and len(n.args) >= 2 and _is_shared(n.args[1], attr)
            for n in ast.walk(tree))

    def run(self) -> list[Finding]:
        for fn in self.functions:
            self._check_function(fn)
        return self.findings

    def _check_function(self, fn: ast.AST) -> None:
        holds = _holds_flock(fn)
        has_fsync = _has_call(fn, "os.fsync")
        self._scan_block(fn.body, fn, locked=holds, has_fsync=has_fsync)
        if holds and self.module_replaces_shared \
                and self._opens_shared(fn) \
                and not (_has_call(fn, "os.fstat")
                         and _has_call(fn, "os.stat")):
            self.findings.append(make_finding(
                "MS302", self.path, fn.lineno,
                f"{fn.name}: holds the flock on a file the module "
                f"os.replace()s, but never re-checks the inode "
                f"(os.fstat vs os.stat) after locking — a lock on the "
                f"orphaned inode serializes nothing"))

    def _opens_shared(self, fn: ast.AST) -> bool:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and _call_name(node) == "open" \
                    and node.args and _is_shared(node.args[0], self.attr):
                return True
        return False

    def _blessed(self, with_stmt: ast.AST) -> bool:
        """Does this ``with`` enter a flock-holding helper context?"""
        for item in with_stmt.items:
            ctx = item.context_expr
            if isinstance(ctx, ast.Call):
                leaf = _call_name(ctx).rsplit(".", 1)[-1]
                if leaf in self.flock_helpers:
                    return True
        return False

    def _scan_block(self, stmts: list[ast.stmt], fn: ast.AST,
                    locked: bool, has_fsync: bool) -> None:
        """Walk one statement block tracking whether an flock is held.

        ``with`` statements are the only lock-state transition; simple
        statements cannot contain one, so checking their calls via
        ``ast.walk`` never crosses a lock boundary."""
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue   # nested defs are checked as their own functions
            if isinstance(st, (ast.With, ast.AsyncWith)):
                inner = locked or self._blessed(st)
                for item in st.items:   # items evaluate under the OUTER state
                    self._check_calls(item.context_expr, fn, locked,
                                      has_fsync)
                self._scan_block(st.body, fn, inner, has_fsync)
                continue
            if isinstance(st, (ast.For, ast.AsyncFor, ast.While, ast.If,
                               ast.Try)):
                for field in _COMPOUND_HEADERS:
                    sub = getattr(st, field, None)
                    if sub is not None:
                        self._check_calls(sub, fn, locked, has_fsync)
                for field in ("body", "orelse", "finalbody"):
                    sub = getattr(st, field, None)
                    if sub:
                        self._scan_block(sub, fn, locked, has_fsync)
                for handler in getattr(st, "handlers", ()):
                    self._scan_block(handler.body, fn, locked, has_fsync)
                continue
            self._check_calls(st, fn, locked, has_fsync)

    def _check_calls(self, node: ast.AST, fn: ast.AST,
                     locked: bool, has_fsync: bool) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                self._check_call(sub, fn, locked, has_fsync)

    def _check_call(self, node: ast.Call, fn: ast.AST,
                    locked: bool, has_fsync: bool) -> None:
        name = _call_name(node)
        fn_name = getattr(fn, "name", "?")
        if name == "open" and node.args \
                and _is_shared(node.args[0], self.attr):
            mode = _open_mode(node)
            if mode is not None and mode not in _WRITE_MODES:
                return
            if mode in _TRUNCATE_MODES:
                self.findings.append(make_finding(
                    "MS303", self.path, node.lineno,
                    f"{fn_name}: open(..{self.attr}, {mode!r}) truncates "
                    f"the shared store in place — a crash mid-write "
                    f"destroys it; write a temp sibling, fsync, then "
                    f"os.replace"))
            if not locked:
                self.findings.append(make_finding(
                    "MS301", self.path, node.lineno,
                    f"{fn_name}: write-mode open of the shared store "
                    f"outside an exclusive flock — concurrent processes "
                    f"can interleave or lose records; hold "
                    f"fcntl.flock(LOCK_EX) across the write"))
        elif name == "os.replace" and len(node.args) >= 2 \
                and _is_shared(node.args[1], self.attr):
            if not locked:
                self.findings.append(make_finding(
                    "MS301", self.path, node.lineno,
                    f"{fn_name}: os.replace onto the shared store outside "
                    f"the flock — a concurrent locked appender may still "
                    f"write to the old inode"))
            if not has_fsync:
                self.findings.append(make_finding(
                    "MS303", self.path, node.lineno,
                    f"{fn_name}: os.replace onto the shared store without "
                    f"os.fsync on the temp file — a crash can atomically "
                    f"install empty or partial data"))


def check_lock_source(source: str, path: str = "<string>",
                      attr: str = "path") -> list[Finding]:
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [make_finding("MS104", path, e.lineno or 0,
                             f"file does not parse: {e.msg}")]
    return _ModuleChecker(path, tree, attr).run()


def check_lock_discipline(paths: Iterable[str | Path] = DEFAULT_LOCK_TARGETS,
                          attr: str = "path",
                          root: str | Path = ".") -> list[Finding]:
    """Run the lock-discipline checks over the shared-store modules.

    Missing targets are skipped silently so the checker can run from any
    working directory subset (CI always passes the repo root)."""
    out: list[Finding] = []
    for p in paths:
        full = Path(root) / p
        if not full.is_file():
            continue
        out.extend(check_lock_source(full.read_text(encoding="utf-8"),
                                     str(full), attr=attr))
    return out

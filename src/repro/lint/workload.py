"""Pass 1 — workload audit: is the benchmark measuring what it claims?

The evaluator converts time into GFLOP/s (or GB/s) by dividing a
*declared* work term by the measured duration
(:func:`repro.core.evaluator.timed_sampler`). Every roofline placement
downstream inherits that constant, so a wrong declaration poisons the
whole analysis while every CI happily converges — the paper's <2% error
budget assumes the work term is right. This pass traces the benchmark's
kernel and cross-checks:

  MS101  declared work vs traced cost beyond tolerance
  MS102  traced computation is dead / constant-folded (a DCE'd kernel
         times an empty executable and reports fantasy throughput)
  MS103  traced dtype differs from the declared one (f32 masquerading
         as DGEMM when x64 is disabled)

Benchmarks opt in by exposing an ``audit_spec`` attribute: a callable
``config -> WorkloadSpec`` naming the pure jax function, example
arguments (``jax.ShapeDtypeStruct`` avoids allocation), and the declared
work in raw FLOPs/bytes — computed by the *same helper* the invocation
factory uses, so the audit checks the shared formula against reality.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

from .findings import Finding, make_finding

__all__ = ["TracedCost", "WorkloadSpec", "audit_benchmark",
           "audit_workload", "trace_cost"]


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Declaration of one benchmark's timed kernel, for the audit.

    ``work`` is in raw units (FLOPs or bytes) per timed call; the
    invocation factory may scale it for display (e.g. /1e9 for GFLOP/s)
    but must derive it from the same formula.
    """

    fn: Callable                     # pure jax callable to trace
    args: tuple                      # example args (ShapeDtypeStructs ok)
    work: float                      # declared work per timed call
    unit: str                        # "flops" | "bytes"
    dtype: Optional[str] = None      # declared compute dtype, e.g. "float32"
    name: str = "workload"
    tolerance: float = 0.05          # relative declared-vs-traced tolerance

    def __post_init__(self):
        if self.unit not in ("flops", "bytes"):
            raise ValueError(f"unit must be 'flops' or 'bytes', "
                             f"got {self.unit!r}")


@dataclasses.dataclass(frozen=True)
class TracedCost:
    """What the compiler says the kernel actually does."""

    flops: float
    bytes_accessed: float
    out_dtypes: tuple[str, ...]
    n_eqns: int                      # jaxpr equations (0 = constant-folded)

    def work(self, unit: str) -> float:
        return self.flops if unit == "flops" else self.bytes_accessed


def trace_cost(fn: Callable, args: Sequence[Any]) -> TracedCost:
    """Lower + compile ``fn`` and extract its cost.

    Primary source is the backend's ``cost_analysis`` (exact on CPU/TPU);
    when it reports neither flops nor bytes the optimized HLO text is
    re-parsed with :func:`repro.analysis.hlo.parse_hlo_cost`.
    """
    import jax

    from repro.analysis.hlo import parse_hlo_cost

    compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    ca = ca or {}
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    if flops == 0.0 and bytes_accessed == 0.0:
        cost = parse_hlo_cost(compiled.as_text())
        flops, bytes_accessed = cost.flops, cost.bytes_accessed
    jaxpr = jax.make_jaxpr(fn)(*args)
    out_dtypes = tuple(str(v.aval.dtype) for v in jaxpr.jaxpr.outvars
                       if hasattr(v, "aval"))
    return TracedCost(flops=flops, bytes_accessed=bytes_accessed,
                      out_dtypes=out_dtypes, n_eqns=len(jaxpr.jaxpr.eqns))


def _anchor(obj) -> tuple[str, int]:
    """Best-effort (path, line) of a python callable, for finding anchors."""
    import inspect
    try:
        path = inspect.getsourcefile(obj) or "<unknown>"
        _, line = inspect.getsourcelines(obj)
    except (TypeError, OSError):
        path, line = "<unknown>", 0
    return path, line


def audit_workload(spec: WorkloadSpec,
                   path: str = "<workload>", line: int = 0) -> list[Finding]:
    """Run the declared-vs-traced checks on one :class:`WorkloadSpec`."""
    findings: list[Finding] = []
    try:
        traced = trace_cost(spec.fn, spec.args)
    except Exception as e:  # trace/compile failed: report, don't crash
        return [make_finding(
            "MS104", path, line,
            f"{spec.name}: tracing the audit spec failed: "
            f"{type(e).__name__}: {e}")]
    traced_work = traced.work(spec.unit)
    if traced.n_eqns == 0 or traced_work == 0.0:
        findings.append(make_finding(
            "MS102", path, line,
            f"{spec.name}: declared {spec.work:.4g} {spec.unit} but the "
            f"traced kernel performs none (jaxpr eqns={traced.n_eqns}, "
            f"traced {spec.unit}={traced_work:.4g}) — the timed "
            f"computation was dead-code-eliminated or constant-folded"))
    else:
        rel = abs(spec.work - traced_work) / traced_work
        if rel > spec.tolerance:
            findings.append(make_finding(
                "MS101", path, line,
                f"{spec.name}: declared {spec.work:.6g} {spec.unit} but "
                f"trace shows {traced_work:.6g} ({rel:.1%} off, tolerance "
                f"{spec.tolerance:.0%}) — every derived {spec.unit}/s "
                f"score is scaled by this error"))
    if spec.dtype is not None and traced.out_dtypes \
            and any(dt != spec.dtype for dt in traced.out_dtypes):
        findings.append(make_finding(
            "MS103", path, line,
            f"{spec.name}: declared dtype {spec.dtype} but traced outputs "
            f"are {', '.join(sorted(set(traced.out_dtypes)))} — check "
            f"jax_enable_x64 / input dtypes (a demoted kernel does "
            f"different work than declared)"))
    return findings


def audit_benchmark(benchmark, config,
                    name: Optional[str] = None) -> list[Finding]:
    """Audit a tuner benchmark (``config -> InvocationFactory``) for one
    configuration, via its ``audit_spec`` attribute.

    A benchmark without ``audit_spec`` yields a single info-level MS100:
    not auditable is worth knowing, but never fails a run.
    """
    label = name or getattr(benchmark, "__name__", repr(benchmark))
    path, line = _anchor(benchmark)
    builder = getattr(benchmark, "audit_spec", None)
    if builder is None:
        return [make_finding(
            "MS100", path, line,
            f"{label}: no audit_spec attribute; workload audit skipped "
            f"(attach one to enable declared-vs-traced checking)")]
    try:
        spec = builder(config)
    except Exception as e:
        return [make_finding(
            "MS104", path, line,
            f"{label}: audit_spec({config!r}) raised "
            f"{type(e).__name__}: {e}")]
    return audit_workload(spec, path=path, line=line)

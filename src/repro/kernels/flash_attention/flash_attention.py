"""Online-softmax (flash) attention Pallas kernel for TPU.

Supports GQA/MQA (kv head broadcast via BlockSpec index mapping), causal
masking, and sliding-window attention (Mixtral SWA) — the attention variants
required by the assigned architecture pool.

Thematic note: the running (max, normalizer) pair that online softmax carries
across kv blocks is the same single-pass online-moment pattern as the paper's
Welford accumulation — both replace a two-pass statistic with an
incrementally corrected one so the loop can stream.

Grid: (batch, q_heads, q_blocks, kv_blocks); the kv axis is sequential and
carries f32 VMEM scratch (m, l, acc). Causal/window skipping is done with
``pl.when`` so fully-masked kv blocks cost no MXU work (the block is still
visited — Pallas TPU grids are static — but its body is predicated out).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...compat import tpu_compiler_params

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 sm_scale: float, causal: bool, window: int | None,
                 bq: int, bk: int, n_kv_steps: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Block-level skip: with a causal mask, kv blocks entirely in the future
    # contribute nothing; with a window, kv blocks entirely before the
    # horizon contribute nothing either.
    q_start = qi * bq
    k_start = kj * bk
    run = True
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window is not None:
        # newest q position in block is q_start + bq - 1; oldest visible
        # k position is q_pos - window + 1.
        run = jnp.logical_and(run, k_start + bk - 1 >= q_start - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                              # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                           # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)               # rescale factor
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == n_kv_steps - 1)
    def _finalize():
        l = l_ref[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           sm_scale: float | None = None, causal: bool = True,
                           window: int | None = None, bq: int = 512,
                           bk: int = 512,
                           interpret: bool = False) -> jax.Array:
    """Attention over (B, H, S, D) q and (B, Hkv, S, D) k/v.

    ``H % Hkv == 0``; query head h reads kv head ``h // (H // Hkv)`` (GQA).
    Sequence length must divide by the block sizes; ``ops.flash_attention``
    pads. Returns (B, H, S, D) in q's dtype.
    """
    b, h, s, d = q.shape
    _, hkv, sk, dk = k.shape
    if (sk, dk) != (s, d) or v.shape != k.shape:
        raise ValueError(f"shape mismatch q={q.shape} k={k.shape} v={v.shape}")
    if h % hkv:
        raise ValueError(f"q heads {h} not a multiple of kv heads {hkv}")
    if s % bq or s % bk:
        raise ValueError(f"seq {s} not divisible by blocks ({bq},{bk})")
    group = h // hkv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    n_kv_steps = s // bk
    kernel = functools.partial(
        _attn_kernel, sm_scale=sm_scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv_steps=n_kv_steps)
    return pl.pallas_call(
        kernel,
        grid=(b, h, s // bq, n_kv_steps),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running normalizer l
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def flops(b: int, h: int, s: int, d: int, causal: bool) -> float:
    """Attention FLOPs: 2 matmuls of (s, d)x(d, s) and (s, s)x(s, d)."""
    full = 2.0 * b * h * (2.0 * s * s * d)
    return full / 2.0 if causal else full

"""Pure-jnp oracle for flash attention: dense softmax attention with GQA,
causal and sliding-window masks."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  sm_scale: float | None = None, causal: bool = True,
                  window: int | None = None) -> jax.Array:
    """Dense attention over (B, H, S, D) q and (B, Hkv, S, D) k/v."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    kb = jnp.repeat(k, group, axis=1)
    vb = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kb.astype(jnp.float32)) * sm_scale
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vb.astype(jnp.float32))
    return out.astype(q.dtype)

"""Jit'd public wrapper for flash attention (padding + backend selection)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .ref import attention_ref


def _pallas_forward(q, k, v, *, sm_scale, causal, window, bq, bk,
                    interpret):
    b, h, s, d = q.shape
    bq_ = min(bq, s) if s >= 128 else s
    bk_ = min(bk, s) if s >= 128 else s
    pad = (-s) % max(bq_, bk_)
    if pad:
        cfg = ((0, 0), (0, 0), (0, pad), (0, 0))
        q = jnp.pad(q, cfg)
        k = jnp.pad(k, cfg)
        v = jnp.pad(v, cfg)
    out = flash_attention_pallas(q, k, v, sm_scale=sm_scale, causal=causal,
                                 window=window, bq=bq_, bk=bk_,
                                 interpret=interpret)
    return out[:, :, :s, :]


@functools.partial(jax.jit, static_argnames=("sm_scale", "causal", "window",
                                             "bq", "bk", "use_pallas",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    sm_scale: float | None = None, causal: bool = True,
                    window: int | None = None, bq: int = 512, bk: int = 512,
                    use_pallas: bool = True,
                    interpret: bool = False) -> jax.Array:
    """Attention over (B, H, S, D); pads S to the block size.

    Padding correctness: padded *query* rows are sliced away; padded *key*
    rows can only attend forward of all real queries under the causal mask
    (pad positions are appended), so they never contribute. For non-causal
    use the reference path or pre-masked inputs.

    Differentiable: ``pallas_call`` defines no autodiff rule, so the
    kernel carries a custom VJP whose backward recomputes attention
    through the reference path — same math, so gradients are exact for
    the function computed; train steps can tune the forward tiles
    (``bq``/``bk``) without losing ``jax.grad``.
    """
    if not use_pallas:
        return attention_ref(q, k, v, sm_scale=sm_scale, causal=causal,
                             window=window)

    @jax.custom_vjp
    def fa(q, k, v):
        return _pallas_forward(q, k, v, sm_scale=sm_scale, causal=causal,
                               window=window, bq=bq, bk=bk,
                               interpret=interpret)

    def fa_fwd(q, k, v):
        return fa(q, k, v), (q, k, v)

    def fa_bwd(res, g):
        q, k, v = res
        _, vjp = jax.vjp(
            lambda q_, k_, v_: attention_ref(q_, k_, v_, sm_scale=sm_scale,
                                             causal=causal, window=window),
            q, k, v)
        return vjp(g)

    fa.defvjp(fa_fwd, fa_bwd)
    return fa(q, k, v)

"""Jit'd public wrapper for flash attention (padding + backend selection)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .ref import attention_ref


@functools.partial(jax.jit, static_argnames=("sm_scale", "causal", "window",
                                             "bq", "bk", "use_pallas",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    sm_scale: float | None = None, causal: bool = True,
                    window: int | None = None, bq: int = 512, bk: int = 512,
                    use_pallas: bool = True,
                    interpret: bool = False) -> jax.Array:
    """Attention over (B, H, S, D); pads S to the block size.

    Padding correctness: padded *query* rows are sliced away; padded *key*
    rows can only attend forward of all real queries under the causal mask
    (pad positions are appended), so they never contribute. For non-causal
    use the reference path or pre-masked inputs.
    """
    if not use_pallas:
        return attention_ref(q, k, v, sm_scale=sm_scale, causal=causal,
                             window=window)
    b, h, s, d = q.shape
    bq_ = min(bq, s) if s >= 128 else s
    bk_ = min(bk, s) if s >= 128 else s
    pad = (-s) % max(bq_, bk_)
    if pad:
        cfg = ((0, 0), (0, 0), (0, pad), (0, 0))
        q = jnp.pad(q, cfg)
        k = jnp.pad(k, cfg)
        v = jnp.pad(v, cfg)
    out = flash_attention_pallas(q, k, v, sm_scale=sm_scale, causal=causal,
                                 window=window, bq=bq_, bk=bk_,
                                 interpret=interpret)
    return out[:, :, :s, :]

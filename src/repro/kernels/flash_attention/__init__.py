from .flash_attention import flash_attention_pallas, flops
from .ops import flash_attention
from .ref import attention_ref

__all__ = ["attention_ref", "flash_attention", "flash_attention_pallas",
           "flops"]

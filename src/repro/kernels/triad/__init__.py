from .ops import triad
from .ref import triad_ref
from .triad import LANES, bytes_moved, flops, triad_pallas

__all__ = ["LANES", "bytes_moved", "flops", "triad", "triad_pallas",
           "triad_ref"]

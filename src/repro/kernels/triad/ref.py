"""Pure-jnp oracle for the TRIAD kernel."""

from __future__ import annotations

import jax


def triad_ref(a: jax.Array, b: jax.Array, gamma: float) -> jax.Array:
    return a + gamma * b

"""STREAM TRIAD Pallas kernel — the paper's low-intensity benchmark.

C <- A + gamma * B over double-word vectors: 2 FLOP per 24 bytes moved
(paper Sec. III-B, I = 1/12 FLOP/byte). On CPU the paper sweeps the vector
length N to land the working set in L3 vs DRAM; on TPU the same sweep moves
the stream between VMEM-resident (small N) and HBM-streaming (large N)
regimes — the v5e analog of the paper's L3/DRAM distinction.

TPU adaptation: vectors are viewed as (rows, 1024) 2D tiles so blocks are
lane-aligned (1024 = 8 sublanes * 128 lanes); the row-block size ``br`` is
the kernel's tunable.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from ...compat import tpu_compiler_params

LANES = 1024  # elements per row: one (8, 128) f32 vreg tile


def _triad_kernel(a_ref, b_ref, o_ref, *, gamma: float):
    o_ref[...] = a_ref[...] + gamma * b_ref[...]


def triad_pallas(a: jax.Array, b: jax.Array, gamma: float, *, br: int = 256,
                 interpret: bool = False) -> jax.Array:
    """C = A + gamma*B over (rows, LANES)-shaped views.

    Args:
      a, b: equal-shape 2D arrays (rows, LANES); ``ops.triad`` reshapes/pads
        1D vectors into this layout.
      br: rows per block — the VMEM streaming-tile tunable.
    """
    if a.shape != b.shape or a.ndim != 2:
        raise ValueError(f"expected equal 2D shapes, got {a.shape} {b.shape}")
    rows, lanes = a.shape
    if rows % br:
        raise ValueError(f"rows {rows} not divisible by block {br}")
    kernel = functools.partial(_triad_kernel, gamma=gamma)
    return pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, lanes), lambda i: (i, 0)),
                  pl.BlockSpec((br, lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(a, b)


def bytes_moved(n_elements: int, dtype_bytes: int) -> float:
    """3 words per element (load A, load B, store C) — paper Sec. III-B."""
    return 3.0 * n_elements * dtype_bytes


def flops(n_elements: int) -> float:
    """2 FLOP per element (mul + add)."""
    return 2.0 * n_elements

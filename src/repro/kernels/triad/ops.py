"""Jit'd public wrapper for the TRIAD kernel: 1D vectors in, 1D out."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import triad_ref
from .triad import LANES, triad_pallas


@functools.partial(jax.jit, static_argnames=("gamma", "br", "use_pallas",
                                             "interpret"))
def triad(a: jax.Array, b: jax.Array, *, gamma: float = 3.0, br: int = 256,
          use_pallas: bool = True, interpret: bool = False) -> jax.Array:
    """C = A + gamma*B for 1D vectors of any length.

    Pads to a whole number of (br, LANES) tiles, runs the Pallas kernel,
    and slices back. ``use_pallas=False`` selects the XLA reference.
    """
    if not use_pallas:
        return triad_ref(a, b, gamma)
    (n,) = a.shape
    tile = br * LANES
    padded = n + ((-n) % tile)
    ap = jnp.pad(a, (0, padded - n)).reshape(padded // LANES, LANES)
    bp = jnp.pad(b, (0, padded - n)).reshape(padded // LANES, LANES)
    out = triad_pallas(ap, bp, gamma, br=br, interpret=interpret)
    return out.reshape(-1)[:n]

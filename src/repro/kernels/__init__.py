"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel subpackage ships:
  * ``<name>.py`` — the ``pl.pallas_call`` kernel with explicit BlockSpec
    VMEM tiling (TPU is the target; ``interpret=True`` validates on CPU);
  * ``ops.py``    — the jit'd public wrapper (padding, dtype policy, block
    autotuning hooks);
  * ``ref.py``    — a pure-jnp oracle used by tests and as the XLA fallback
    path on CPU.

Kernels:
  * ``matmul``          — blocked MXU matmul; the paper's DGEMM, TPU-adapted:
    the tunables are the VMEM tile sizes (bm, bn, bk), which on TPU play the
    role the paper's (n, m, k) matrix dims played on CPU.
  * ``triad``           — STREAM TRIAD (C = A + g*B), HBM-streaming;
    the paper's low-intensity benchmark (I = 1/12 FLOP/byte).
  * ``flash_attention`` — online-softmax attention (GQA + causal + sliding
    window); its running (max, sum) rescaling is the same online-moment trick
    as the paper's Welford accumulation, applied to softmax.
  * ``ssd``             — Mamba2 SSD chunk scan (the SSM family's hot loop);
    the carried (P, N) state lives in VMEM scratch across the sequential
    chunk grid dimension.
"""

from . import flash_attention, matmul, ssd, triad  # noqa: F401

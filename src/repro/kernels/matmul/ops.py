"""Jit'd public wrapper for the blocked matmul kernel.

Handles tile-divisibility padding, backend selection (Pallas on TPU,
interpret-mode Pallas for validation, XLA reference otherwise) and exposes
the tile sizes as keyword tunables for the autotuner.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .matmul import matmul_pallas
from .ref import matmul_ref


def _pad_to(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "use_pallas",
                                    "interpret"))
def matmul(a: jax.Array, b: jax.Array, *, bm: int = 512, bn: int = 512,
           bk: int = 512, use_pallas: bool = True,
           interpret: bool = False) -> jax.Array:
    """C = A @ B.

    Args:
      a, b: (m, k) and (k, n) operands, same dtype.
      bm, bn, bk: VMEM tile sizes (the autotuner's search dimensions).
      use_pallas: False selects the pure-XLA reference path.
      interpret: run the Pallas kernel in interpret mode (CPU validation).
    """
    if not use_pallas:
        return matmul_ref(a, b)
    m, k = a.shape
    _, n = b.shape
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    ap = _pad_to(a, bm_, bk_)
    bp = _pad_to(b, bk_, bn_)
    out = matmul_pallas(ap, bp, bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return out[:m, :n]

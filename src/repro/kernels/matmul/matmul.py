"""Blocked MXU matmul Pallas kernel — the DGEMM benchmark, TPU-native.

The paper autotunes the DGEMM call's matrix dimensions (n, m, k) because on
CPU those decide cache/SIMD behavior. On TPU the analogous lever is the VMEM
tile shape fed to the MXU: (bm, bn, bk) decide the working set that must fit
in ~128 MiB of VMEM and the systolic-array utilization (multiples of 128
align with the 128x128 MXU). The tile sizes are this kernel's tunables and
form the search space of ``repro.benchsuite.matmul_bench``.

Grid layout: (m/bm, n/bn, k/bk) with the k dimension sequential
("arbitrary") so a float32 VMEM scratch accumulator carries partial sums
across k steps (output dtype may be bf16; accumulation is always f32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...compat import tpu_compiler_params


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k_steps: int):
    """One (bm, bn) output tile; accumulates over the sequential k axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU op: (bm, bk) @ (bk, bn) accumulated in f32.
    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(a: jax.Array, b: jax.Array, *, bm: int = 512, bn: int = 512,
                  bk: int = 512, interpret: bool = False) -> jax.Array:
    """C = A @ B with explicit (bm, bn, bk) VMEM tiling.

    Requires shapes divisible by the tile sizes; ``ops.matmul`` handles
    padding. ``interpret=True`` runs the kernel body in Python on CPU.
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{n},{k}) not divisible by tiles "
                         f"({bm},{bn},{bk}); use ops.matmul for padding")
    n_k_steps = k // bk
    kernel = functools.partial(_matmul_kernel, n_k_steps=n_k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 2) -> int:
    """Working-set estimate for one grid step: A-tile + B-tile + out-tile in
    input dtype, plus the f32 accumulator. Used by the search-space
    constraint (paper Sec. IV: constraint specification)."""
    return (bm * bk + bk * bn + bm * bn) * dtype_bytes + bm * bn * 4


def flops(m: int, n: int, k: int) -> float:
    """FLOPs of one C = A@B evaluation (the paper's DGEMM FLOP count)."""
    return 2.0 * m * n * k

"""Pure-jnp oracle for the blocked matmul kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B with f32 accumulation, matching the kernel's dtype policy."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)

from .matmul import flops, matmul_pallas, vmem_bytes
from .ops import matmul
from .ref import matmul_ref

__all__ = ["flops", "matmul", "matmul_pallas", "matmul_ref", "vmem_bytes"]

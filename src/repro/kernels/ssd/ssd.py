"""Pallas TPU kernel for the Mamba2 SSD chunk scan (Dao & Gu 2024).

The hot loop of the SSM family (mamba2-130m, zamba2-2.7b): per (batch,
head), chunks of the sequence are processed with an attention-like
quadratic intra-chunk term while a (P, N) state carries across chunks.
The chunk axis is sequential ("arbitrary") and the running state lives in
a VMEM scratch accumulator — the same pattern as the flash-attention
kernel's (m, l, acc), i.e. the paper's online-statistics trick again, here
carrying a full state matrix instead of softmax moments.

Grid: (B, H, n_chunks). Per step the VMEM working set is
Q·P + 2·Q·N + Q + Q·Q + P·N floats — with Q=256, P=64, N=128 that is
~0.6 MiB, far under the ~128 MiB/core VMEM budget; Q is the tunable
(the autotuner's search dimension, see EXPERIMENTS §Perf cell 3).

Inputs (prepared by ``ops.ssd_chunk_scan``; f32):
  xdt (B, H, C, Q, P)   x * dt, head-major
  bm  (B, C, Q, N)      B projections (shared across heads, n_groups=1)
  cm  (B, C, Q, N)      C projections
  cum (B, H, C, Q)      within-chunk cumsum of a = dt * A  (<= 0)
Output: y (B, H, C, Q, P) = intra-chunk + inter-chunk contributions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...compat import tpu_compiler_params


def _ssd_kernel(xdt_ref, bm_ref, cm_ref, cum_ref, y_ref, h_ref, *,
                q_len: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xdt = xdt_ref[0, 0, 0]                       # (Q, P)
    bm = bm_ref[0, 0]                            # (Q, N)
    cm = cm_ref[0, 0]                            # (Q, N)
    cum = cum_ref[0, 0, 0]                       # (Q,)

    # intra-chunk quadratic form: L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, None] - cum[None, :]           # (Q, Q), <= 0 on tril
    mask = jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q_len, q_len), 1)
    decay = jnp.where(mask, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_intra = jax.lax.dot((scores * decay).astype(jnp.float32), xdt,
                          preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state h (P, N)
    h = h_ref[...]
    y_inter = jax.lax.dot_general(cm, h, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)[:, None]    # (Q, P)
    y_ref[0, 0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = exp(total) * h + (xdt * sd)^T @ bm
    total = cum[q_len - 1]
    sd = jnp.exp(total - cum)                    # (Q,)
    contrib = jax.lax.dot_general(xdt * sd[:, None], bm,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    h_ref[...] = jnp.exp(total) * h + contrib    # (P, N)


def ssd_chunk_scan_pallas(xdt: jax.Array, bm: jax.Array, cm: jax.Array,
                          cum: jax.Array, *,
                          interpret: bool = False) -> jax.Array:
    """Run the chunked SSD scan. Shapes as in the module docstring."""
    B, H, C, Q, P = xdt.shape
    N = bm.shape[-1]
    if bm.shape != (B, C, Q, N) or cm.shape != (B, C, Q, N):
        raise ValueError(f"bad B/C shapes: {bm.shape} {cm.shape}")
    if cum.shape != (B, H, C, Q):
        raise ValueError(f"bad cum shape: {cum.shape}")
    kernel = functools.partial(_ssd_kernel, q_len=Q)
    return pl.pallas_call(
        kernel,
        grid=(B, H, C),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Q, P),
                               lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(xdt.shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xdt.astype(jnp.float32), bm.astype(jnp.float32),
      cm.astype(jnp.float32), cum.astype(jnp.float32))


def flops(B: int, H: int, S: int, Q: int, P: int, N: int) -> float:
    """Per-forward FLOPs: scores QQN + intra QQP + inter QPN + state QPN
    per chunk per head."""
    n_chunks = S // Q
    per_chunk = 2.0 * (Q * Q * N + Q * Q * P + Q * P * N + Q * P * N)
    return B * H * n_chunks * per_chunk

"""Jit'd wrapper: model-layout tensors -> kernel layout -> chunk scan."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import ssd_chunk_scan_ref
from .ssd import ssd_chunk_scan_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas",
                                             "interpret"))
def ssd_chunk_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
                   cm: jax.Array, *, chunk: int = 256,
                   use_pallas: bool = True,
                   interpret: bool = False) -> jax.Array:
    """SSD scan over model-layout inputs.

    Args:
      x:  (B, S, H, P)  inner activations (post-conv, post-silu)
      dt: (B, S, H)     softplus'd timestep
      a:  (H,)          negative decay rates (-exp(A_log))
      bm: (B, S, N)     B projections (n_groups=1)
      cm: (B, S, N)     C projections
      chunk: chunk length Q (S % Q == 0); the tunable.
    Returns (B, S, H, P) in f32.
    """
    B, S, H, P = x.shape
    N = bm.shape[-1]
    Q = min(chunk, S)
    C = S // Q
    xdt = (x * dt[..., None]).reshape(B, C, Q, H, P)
    xdt = jnp.moveaxis(xdt, 3, 1)                        # (B,H,C,Q,P)
    cum = jnp.cumsum((dt * a).reshape(B, C, Q, H), axis=2)
    cum = jnp.moveaxis(cum, 3, 1)                        # (B,H,C,Q)
    bm_c = bm.reshape(B, C, Q, N)
    cm_c = cm.reshape(B, C, Q, N)
    fn = ssd_chunk_scan_pallas if use_pallas else \
        (lambda *args, **kw: ssd_chunk_scan_ref(*args))
    y = fn(xdt, bm_c, cm_c, cum, **({"interpret": interpret}
                                    if use_pallas else {}))
    y = jnp.moveaxis(y, 1, 3).reshape(B, S, H, P)        # back to (B,S,H,P)
    return y

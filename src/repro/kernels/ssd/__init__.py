from .ops import ssd_chunk_scan
from .ref import ssd_chunk_scan_ref
from .ssd import flops, ssd_chunk_scan_pallas

__all__ = ["flops", "ssd_chunk_scan", "ssd_chunk_scan_pallas",
           "ssd_chunk_scan_ref"]

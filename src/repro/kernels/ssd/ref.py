"""Pure-jnp oracle for the SSD chunk-scan kernel (sequential over chunks)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunk_scan_ref(xdt: jax.Array, bm: jax.Array, cm: jax.Array,
                       cum: jax.Array) -> jax.Array:
    """Same contract as ``ssd_chunk_scan_pallas`` (see ssd.py docstring)."""
    B, H, C, Q, P = xdt.shape
    N = bm.shape[-1]
    xdt = xdt.astype(jnp.float32)
    bm = bm.astype(jnp.float32)
    cm = cm.astype(jnp.float32)
    cum = cum.astype(jnp.float32)

    def head_scan(xdt_h, bm_b, cm_b, cum_h):
        # xdt_h (C,Q,P), bm_b/cm_b (C,Q,N), cum_h (C,Q)
        def body(h, inputs):
            x_c, b_c, c_c, u_c = inputs
            diff = u_c[:, None] - u_c[None, :]
            mask = jnp.tril(jnp.ones((Q, Q), bool))
            decay = jnp.where(mask, jnp.exp(diff), 0.0)
            scores = c_c @ b_c.T
            y = (scores * decay) @ x_c
            y = y + (c_c @ h.T) * jnp.exp(u_c)[:, None]
            total = u_c[-1]
            sd = jnp.exp(total - u_c)
            h_new = jnp.exp(total) * h + (x_c * sd[:, None]).T @ b_c
            return h_new, y

        h0 = jnp.zeros((P, N), jnp.float32)
        _, ys = jax.lax.scan(body, h0, (xdt_h, bm_b, cm_b, cum_h))
        return ys                                  # (C, Q, P)

    per_batch = jax.vmap(head_scan, in_axes=(0, None, None, 0))  # over H
    return jax.vmap(per_batch, in_axes=(0, 0, 0, 0))(xdt, bm, cm, cum)

"""Two-level benchmark evaluation (paper Fig. 2).

The paper evaluates every configuration with an *inner iteration loop*
(repeated timed calls inside one process) nested in an *outer invocation
loop* (fresh process/JIT state per invocation, after Georges et al.'s
VM-invocation-level repetition). Both loops carry their own Welford stream
and their own stop conditions:

  inner:  MaxTime + MaxCount + [CIConverged "C"] + [UpperBoundPrune "I"]
  outer:  MaxCount(invocations) + [CIConverged] + [UpperBoundPrune "O"]

``Evaluator.evaluate`` runs the full two-level process for one configuration
and returns an :class:`EvalResult` with the score (mean of invocation means),
sample/timing accounting, and the stop reasons — everything the benchmark
tables in the paper report (iteration counts, search time, result).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence, Union

from . import welford
from .stop_conditions import (CIConverged, Direction, EvalContext, MaxCount,
                              MaxTime, StopCondition, StopDecision,
                              UpperBoundPrune, first_decision)

# ``make_invocation()`` models one outer-loop program invocation: it performs
# per-invocation setup (allocation, jit, pre-heat — the paper pre-heats with
# one untimed DGEMM call) and returns a zero-arg sampler producing one metric
# observation per call (e.g. GFLOP/s of one timed kernel execution).
InvocationFactory = Callable[[], Callable[[], float]]

# The pruning reference (stop condition 4): a fixed score, absent, or a
# zero-arg supplier of the live global best (IncumbentCell.get) that
# concurrent backends re-read before every sample.
Incumbent = Union[float, Callable[[], Optional[float]], None]


@dataclasses.dataclass(frozen=True)
class InvocationResult:
    mean: float
    count: int
    elapsed_s: float
    stop_reason: str
    pruned: bool
    m2: float = 0.0   # corrected sum of squares — enables exact downstream
                      # Welford merges (distributed tuner)


@dataclasses.dataclass(frozen=True)
class EvalResult:
    """Outcome of evaluating one configuration."""

    score: float                      # mean of invocation means
    best_invocation: float
    invocations: tuple[InvocationResult, ...]
    total_samples: int
    total_time_s: float               # wall time incl. setup
    measured_time_s: float            # sum of timed sample durations only
    pruned: bool                      # stopped by condition 4 at any level
    stop_reason: str                  # outer-level stop reason


@dataclasses.dataclass
class EvaluationSettings:
    """Mirrors the paper's Table I auto-tuner configuration.

    The optimization flags map to the paper's technique labels:
      use_ci_convergence -> "C"  (stop condition 3, inner loop)
      use_inner_prune    -> "I"  (stop condition 4, iteration loop)
      use_outer_prune    -> "O"  (stop condition 4, invocation loop)
    With all three False the evaluator degenerates to the fixed-sample-size
    "Default" methodology the paper benchmarks against.
    """

    max_invocations: int = 10
    max_iterations: int = 200
    max_time_s: float = 10.0
    confidence: float = 0.99
    rel_margin: float = 0.01
    use_ci_convergence: bool = False
    use_inner_prune: bool = False
    use_outer_prune: bool = False
    min_count_ci: int = 5
    min_count_inner: int = 2
    min_count_outer: int = 2
    direction: Direction = Direction.MAXIMIZE
    use_t: bool = True
    # CI method for the inner loop (paper §VII future work, implemented):
    # "welford"   — normal/t interval from online moments (the paper)
    # "bootstrap" — percentile bootstrap over a bounded reservoir
    # "median"    — sign-test CI for the median (nonparametric)
    ci_method: str = "welford"
    bootstrap_capacity: int = 256
    bootstrap_resamples: int = 200

    def label(self) -> str:
        """Technique label as used in the paper's tables, e.g. 'C+I+O'."""
        parts = []
        if self.use_ci_convergence:
            parts.append("C")
        if self.use_inner_prune:
            parts.append("I")
        if self.use_outer_prune:
            parts.append("O")
        return "+".join(parts) if parts else "Default"

    # -- condition stacks ----------------------------------------------------
    def inner_conditions(self) -> list[StopCondition]:
        conds: list[StopCondition] = [
            MaxTime(self.max_time_s),
            MaxCount(self.max_iterations),
        ]
        if self.use_ci_convergence:
            conds.append(CIConverged(self.confidence, self.rel_margin,
                                     min_count=self.min_count_ci,
                                     use_t=self.use_t))
        if self.use_inner_prune:
            conds.append(UpperBoundPrune(self.confidence,
                                         min_count=self.min_count_inner,
                                         use_t=self.use_t))
        return conds

    def outer_conditions(self) -> list[StopCondition]:
        conds: list[StopCondition] = [MaxCount(self.max_invocations)]
        if self.use_ci_convergence:
            conds.append(CIConverged(self.confidence, self.rel_margin,
                                     min_count=min(3, self.max_invocations),
                                     use_t=self.use_t))
        if self.use_outer_prune:
            conds.append(UpperBoundPrune(self.confidence,
                                         min_count=self.min_count_outer,
                                         use_t=self.use_t))
        return conds


def _resolve_incumbent(incumbent: Incumbent) -> Optional[float]:
    """The incumbent may be a scalar or a zero-arg supplier of the *live*
    global best (concurrent backends share it through an IncumbentCell)."""
    return incumbent() if callable(incumbent) else incumbent


class Evaluator:
    """Runs the two-level evaluation process for one configuration.

    ``evaluate`` is re-entrant: all mutable state is local, so one
    Evaluator instance may serve many threads concurrently (the
    ThreadPoolBackend relies on this).
    """

    def __init__(self, settings: EvaluationSettings,
                 clock: Callable[[], float] = time.perf_counter):
        self.settings = settings
        self.clock = clock

    # -- inner loop -----------------------------------------------------------
    def _run_invocation(self, sample_fn: Callable[[], float],
                        incumbent: Incumbent,
                        conditions: Sequence[StopCondition]) -> InvocationResult:
        from .confidence import ReservoirBootstrap, sign_test_median_ci
        s = self.settings
        state = welford.init()
        boot = ReservoirBootstrap(s.bootstrap_capacity,
                                  s.bootstrap_resamples) \
            if s.ci_method == "bootstrap" else None
        samples: list[float] = [] if s.ci_method == "median" else None
        t0 = self.clock()
        count = 0
        decision: Optional[StopDecision] = None
        while True:
            x = float(sample_fn())
            count += 1
            state = welford.update(state, x)
            ci_fn = None
            if boot is not None:
                boot.update(x)
                ci_fn = lambda conf, _t: boot.ci_mean(conf)  # noqa: E731
            elif samples is not None:
                samples.append(x)
                ci_fn = lambda conf, _t: sign_test_median_ci(  # noqa: E731
                    samples, conf)
            ctx = EvalContext(welford=state,
                              elapsed_s=self.clock() - t0,
                              count=count,
                              incumbent=_resolve_incumbent(incumbent),
                              direction=self.settings.direction,
                              ci_fn=ci_fn)
            decision = first_decision(conditions, ctx)
            if decision is not None:
                break
        return InvocationResult(mean=float(state.mean), count=count,
                                elapsed_s=self.clock() - t0,
                                stop_reason=decision.reason,
                                pruned=decision.pruned,
                                m2=float(state.m2))

    # -- outer loop -----------------------------------------------------------
    def evaluate(self, make_invocation: InvocationFactory,
                 incumbent: Incumbent = None) -> EvalResult:
        s = self.settings
        inner_conds = s.inner_conditions()
        outer_conds = s.outer_conditions()
        outer_state = welford.init()
        invocations: list[InvocationResult] = []
        pruned = False
        t_start = self.clock()
        measured = 0.0
        decision: Optional[StopDecision] = None
        direction = s.direction
        best_inv: Optional[float] = None
        while True:
            sample_fn = make_invocation()
            inv = self._run_invocation(sample_fn, incumbent, inner_conds)
            invocations.append(inv)
            measured += inv.elapsed_s
            pruned = pruned or inv.pruned
            outer_state = welford.update(outer_state, inv.mean)
            if best_inv is None or direction.better(inv.mean, best_inv):
                best_inv = inv.mean
            ctx = EvalContext(welford=outer_state,
                              elapsed_s=self.clock() - t_start,
                              count=len(invocations),
                              incumbent=_resolve_incumbent(incumbent),
                              direction=direction)
            decision = first_decision(outer_conds, ctx)
            if decision is not None:
                pruned = pruned or decision.pruned
                break
            # An inner prune means this configuration cannot win; there is no
            # value in further invocations of a doomed configuration.
            if inv.pruned:
                decision = StopDecision(reason="inner_pruned", pruned=True)
                break
        return EvalResult(score=float(outer_state.mean),
                          best_invocation=float(best_inv),
                          invocations=tuple(invocations),
                          total_samples=sum(i.count for i in invocations),
                          total_time_s=self.clock() - t_start,
                          measured_time_s=measured,
                          pruned=pruned,
                          stop_reason=decision.reason)


def timed_sampler(fn: Callable[[], None], work: float,
                  clock: Callable[[], float] = time.perf_counter,
                  ) -> Callable[[], float]:
    """Wrap a side-effecting callable into a metric sampler.

    Returns a sampler yielding ``work / elapsed`` per call — e.g. FLOPs/s when
    ``work`` is the FLOP count of one call, or bytes/s for bandwidth
    benchmarks. This is the paper's gettimeofday-around-the-BLAS-call pattern.
    """

    def sample() -> float:
        t0 = clock()
        fn()
        t1 = clock()
        dt = max(t1 - t0, 1e-12)
        return work / dt

    return sample

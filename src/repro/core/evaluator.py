"""Two-level benchmark evaluation (paper Fig. 2).

The paper evaluates every configuration with an *inner iteration loop*
(repeated timed calls inside one process) nested in an *outer invocation
loop* (fresh process/JIT state per invocation, after Georges et al.'s
VM-invocation-level repetition). Both loops carry their own Welford stream
and their own stop conditions:

  inner:  MaxTime + MaxCount + [CIConverged "C"] + [UpperBoundPrune "I"]
  outer:  MaxCount(invocations) + [CIConverged] + [UpperBoundPrune "O"]

``Evaluator.evaluate`` runs the full two-level process for one configuration
and returns an :class:`EvalResult` with the score (mean of invocation means),
sample/timing accounting, and the stop reasons — everything the benchmark
tables in the paper report (iteration counts, search time, result).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Optional, Sequence, Union

from . import welford
from .profiling import (phase, record_phase, trace_instant, trace_sink,
                        trace_span)
from .stop_conditions import (CIConverged, Direction, EvalContext, MaxCount,
                              MaxTime, StopCondition, StopDecision,
                              UpperBoundPrune, first_decision)

# ``make_invocation()`` models one outer-loop program invocation: it performs
# per-invocation setup (allocation, jit, pre-heat — the paper pre-heats with
# one untimed DGEMM call) and returns a zero-arg sampler producing one metric
# observation per call (e.g. GFLOP/s of one timed kernel execution).
InvocationFactory = Callable[[], Callable[[], float]]

# The pruning reference (stop condition 4): a fixed score, absent, or a
# zero-arg supplier of the live global best (IncumbentCell.get) that
# concurrent backends re-read before every sample.
Incumbent = Union[float, Callable[[], Optional[float]], None]


@dataclasses.dataclass(frozen=True)
class InvocationResult:
    mean: float
    count: int
    elapsed_s: float
    stop_reason: str
    pruned: bool
    m2: float = 0.0   # corrected sum of squares — enables exact downstream
                      # Welford merges (distributed tuner)


@dataclasses.dataclass(frozen=True)
class EvalResult:
    """Outcome of evaluating one configuration."""

    score: float                      # mean of invocation means
    best_invocation: float
    invocations: tuple[InvocationResult, ...]
    total_samples: int
    total_time_s: float               # wall time incl. setup
    measured_time_s: float            # sum of timed sample durations only
    pruned: bool                      # stopped by condition 4 at any level
    stop_reason: str                  # outer-level stop reason


@dataclasses.dataclass
class EvaluationSettings:
    """Mirrors the paper's Table I auto-tuner configuration.

    The optimization flags map to the paper's technique labels:
      use_ci_convergence -> "C"  (stop condition 3, inner loop)
      use_inner_prune    -> "I"  (stop condition 4, iteration loop)
      use_outer_prune    -> "O"  (stop condition 4, invocation loop)
    With all three False the evaluator degenerates to the fixed-sample-size
    "Default" methodology the paper benchmarks against.
    """

    max_invocations: int = 10
    max_iterations: int = 200
    max_time_s: float = 10.0
    confidence: float = 0.99
    rel_margin: float = 0.01
    use_ci_convergence: bool = False
    use_inner_prune: bool = False
    use_outer_prune: bool = False
    min_count_ci: int = 5
    min_count_inner: int = 2
    min_count_outer: int = 2
    direction: Direction = Direction.MAXIMIZE
    use_t: bool = True
    # CI method for the inner loop (paper §VII future work, implemented):
    # "welford"   — normal/t interval from online moments (the paper)
    # "bootstrap" — percentile bootstrap over a bounded reservoir
    # "median"    — sign-test CI for the median (nonparametric)
    ci_method: str = "welford"
    bootstrap_capacity: int = 256
    bootstrap_resamples: int = 200
    # Opt-in on-device timing (repro.obs.device_timing): when a trace
    # recorder is installed, trials that beat the incumbent get one extra
    # profiled invocation whose device-side kernel time and host-vs-device
    # skew land in the trace. Off-GPU/TPU it degrades to an
    # "unavailable" instant. Never touches the measured samples.
    device_timing: bool = False

    def label(self) -> str:
        """Technique label as used in the paper's tables, e.g. 'C+I+O'."""
        parts = []
        if self.use_ci_convergence:
            parts.append("C")
        if self.use_inner_prune:
            parts.append("I")
        if self.use_outer_prune:
            parts.append("O")
        return "+".join(parts) if parts else "Default"

    # -- condition stacks ----------------------------------------------------
    def inner_conditions(self) -> list[StopCondition]:
        conds: list[StopCondition] = [
            MaxTime(self.max_time_s),
            MaxCount(self.max_iterations),
        ]
        if self.use_ci_convergence:
            conds.append(CIConverged(self.confidence, self.rel_margin,
                                     min_count=self.min_count_ci,
                                     use_t=self.use_t))
        if self.use_inner_prune:
            conds.append(UpperBoundPrune(self.confidence,
                                         min_count=self.min_count_inner,
                                         use_t=self.use_t))
        return conds

    def outer_conditions(self) -> list[StopCondition]:
        conds: list[StopCondition] = [MaxCount(self.max_invocations)]
        if self.use_ci_convergence:
            conds.append(CIConverged(self.confidence, self.rel_margin,
                                     min_count=min(3, self.max_invocations),
                                     use_t=self.use_t))
        if self.use_outer_prune:
            conds.append(UpperBoundPrune(self.confidence,
                                         min_count=self.min_count_outer,
                                         use_t=self.use_t))
        return conds


def _resolve_incumbent(incumbent: Incumbent) -> Optional[float]:
    """The incumbent may be a scalar or a zero-arg supplier of the *live*
    global best (concurrent backends share it through an IncumbentCell)."""
    return incumbent() if callable(incumbent) else incumbent


class Evaluator:
    """Runs the two-level evaluation process for one configuration.

    ``evaluate`` is re-entrant: all mutable state is local, so one
    Evaluator instance may serve many threads concurrently (the
    ThreadPoolBackend relies on this).
    """

    def __init__(self, settings: EvaluationSettings,
                 clock: Callable[[], float] = time.perf_counter):
        self.settings = settings
        self.clock = clock

    # -- inner loop -----------------------------------------------------------
    def _run_invocation(self, sample_fn: Callable[[], float],
                        incumbent: Incumbent,
                        conditions: Sequence[StopCondition]) -> InvocationResult:
        from .confidence import ReservoirBootstrap, sign_test_median_ci
        s = self.settings
        state = welford.init()
        boot = ReservoirBootstrap(s.bootstrap_capacity,
                                  s.bootstrap_resamples) \
            if s.ci_method == "bootstrap" else None
        samples: list[float] = [] if s.ci_method == "median" else None
        t0 = self.clock()
        count = 0
        decision: Optional[StopDecision] = None
        while True:
            x = float(sample_fn())
            count += 1
            with phase("stats"):
                state = welford.update(state, x)
                ci_fn = None
                if boot is not None:
                    boot.update(x)
                    ci_fn = lambda conf, _t: boot.ci_mean(conf)  # noqa: E731
                elif samples is not None:
                    samples.append(x)
                    ci_fn = lambda conf, _t: sign_test_median_ci(  # noqa: E731
                        samples, conf)
                ctx = EvalContext(welford=state,
                                  elapsed_s=self.clock() - t0,
                                  count=count,
                                  incumbent=_resolve_incumbent(incumbent),
                                  direction=self.settings.direction,
                                  ci_fn=ci_fn)
                decision = first_decision(conditions, ctx)
            if decision is not None:
                break
        return InvocationResult(mean=float(state.mean), count=count,
                                elapsed_s=self.clock() - t0,
                                stop_reason=decision.reason,
                                pruned=decision.pruned,
                                m2=float(state.m2))

    # -- outer loop -----------------------------------------------------------
    def evaluate(self, make_invocation: InvocationFactory,
                 incumbent: Incumbent = None) -> EvalResult:
        s = self.settings
        inner_conds = s.inner_conditions()
        outer_conds = s.outer_conditions()
        outer_state = welford.init()
        invocations: list[InvocationResult] = []
        pruned = False
        t_start = self.clock()
        measured = 0.0
        decision: Optional[StopDecision] = None
        direction = s.direction
        best_inv: Optional[float] = None
        while True:
            with trace_span("invocation", cat="invocation",
                            n=len(invocations) + 1) as ispan:
                with phase("setup"):
                    sample_fn = make_invocation()
                inv = self._run_invocation(sample_fn, incumbent,
                                           inner_conds)
                ispan.set(mean=inv.mean, count=inv.count,
                          stop_reason=inv.stop_reason, pruned=inv.pruned)
            invocations.append(inv)
            measured += inv.elapsed_s
            pruned = pruned or inv.pruned
            outer_state = welford.update(outer_state, inv.mean)
            if best_inv is None or direction.better(inv.mean, best_inv):
                best_inv = inv.mean
            ctx = EvalContext(welford=outer_state,
                              elapsed_s=self.clock() - t_start,
                              count=len(invocations),
                              incumbent=_resolve_incumbent(incumbent),
                              direction=direction)
            decision = first_decision(outer_conds, ctx)
            if decision is not None:
                pruned = pruned or decision.pruned
                break
            # An inner prune means this configuration cannot win; there is no
            # value in further invocations of a doomed configuration.
            if inv.pruned:
                decision = StopDecision(reason="inner_pruned", pruned=True)
                break
        if s.device_timing and not pruned:
            self._device_profile(sample_fn, float(outer_state.mean),
                                 incumbent, direction)
        return EvalResult(score=float(outer_state.mean),
                          best_invocation=float(best_inv),
                          invocations=tuple(invocations),
                          total_samples=sum(i.count for i in invocations),
                          total_time_s=self.clock() - t_start,
                          measured_time_s=measured,
                          pruned=pruned,
                          stop_reason=decision.reason)

    # -- on-device timing -----------------------------------------------------
    def _device_profile(self, sample_fn: Callable[[], float], score: float,
                        incumbent: Incumbent, direction: Direction) -> None:
        """One extra profiled invocation for incumbent-candidate trials.

        Only runs when a trace recorder is installed (the result is a
        trace attribute, nothing else consumes it) and only for scores
        that beat the current incumbent — profiling slows the profiled
        call, so doomed configurations never pay for it.
        """
        if trace_sink() is None:
            return
        inc = _resolve_incumbent(incumbent)
        if inc is not None and not direction.better(score, inc):
            return
        try:
            from repro.obs.device_timing import profile_sample
            timing = profile_sample(sample_fn)
        except Exception:
            timing = None
        if timing is None:
            trace_instant("device_timing_unavailable")
        else:
            trace_instant("device_timing", **timing.to_json())


class TimingResolutionWarning(UserWarning):
    """A timed sample landed under 10x the clock's resolution.

    At that scale quantization error alone is >10% of the reading — the
    observation is noise, not measurement. Switch to ``steady_sampler``
    (batch B calls per observation) or grow the per-call workload.
    """


@dataclasses.dataclass(frozen=True)
class ClockCalibration:
    """Measured properties of a clock callable.

    ``resolution_s`` — smallest positive delta two consecutive readings
    can differ by (timer quantum). ``overhead_s`` — mean cost of one
    ``clock()`` call, which a t0/t1 bracket adds to every sample.
    """

    resolution_s: float
    overhead_s: float


_CLOCK_CALIBRATION: Optional[ClockCalibration] = None


def calibrate_clock(clock: Callable[[], float] = time.perf_counter,
                    samples: int = 4096) -> ClockCalibration:
    """Measure a clock's resolution and per-call overhead.

    The default ``time.perf_counter`` is calibrated once per process and
    cached; custom clocks are measured fresh on every call (tests pass
    deterministic fake clocks that must not be consumed by calibration
    — samplers only auto-calibrate the default clock).
    """
    global _CLOCK_CALIBRATION
    is_default = clock is time.perf_counter
    if is_default and _CLOCK_CALIBRATION is not None:
        return _CLOCK_CALIBRATION
    # Overhead: time a tight loop of clock() calls.
    t0 = clock()
    for _ in range(samples):
        clock()
    overhead = (clock() - t0) / (samples + 1)
    # Resolution: smallest positive delta seen across consecutive reads.
    resolution = float("inf")
    prev = clock()
    for _ in range(samples):
        cur = clock()
        d = cur - prev
        if 0.0 < d < resolution:
            resolution = d
        prev = cur
    if resolution == float("inf"):    # clock never advanced
        resolution = 0.0
    cal = ClockCalibration(resolution_s=resolution, overhead_s=overhead)
    if is_default:
        _CLOCK_CALIBRATION = cal
    return cal


def timed_sampler(fn: Callable[[], None], work: float,
                  clock: Callable[[], float] = time.perf_counter,
                  calibration: Optional[ClockCalibration] = None,
                  ) -> Callable[[], float]:
    """Wrap a side-effecting callable into a metric sampler.

    Returns a sampler yielding ``work / elapsed`` per call — e.g. FLOPs/s when
    ``work`` is the FLOP count of one call, or bytes/s for bandwidth
    benchmarks. This is the paper's gettimeofday-around-the-BLAS-call pattern.

    The default clock is calibrated once per process: its per-call
    overhead is subtracted from every reading, and a sample landing
    under 10x the clock's resolution raises a one-shot
    :class:`TimingResolutionWarning` instead of silently reporting a
    quantization-noise throughput. Custom clocks are taken at face value
    unless an explicit ``calibration`` is passed.
    """
    if calibration is None and clock is time.perf_counter:
        calibration = calibrate_clock(clock)
    overhead = calibration.overhead_s if calibration else 0.0
    resolution = calibration.resolution_s if calibration else 0.0
    floor = resolution if resolution > 0.0 else 1e-12
    warned = [False]
    # clock readings only mark trace positions when they share the
    # recorder's clock; fake test clocks fall back to "now"
    default_clock = clock is time.perf_counter

    def sample() -> float:
        t0 = clock()
        fn()
        t1 = clock()
        dt = t1 - t0 - overhead
        if dt < 10.0 * resolution and not warned[0]:
            warned[0] = True
            warnings.warn(
                f"timed sample ({dt:.3g}s) is under 10x the clock "
                f"resolution ({resolution:.3g}s); use steady_sampler or a "
                f"larger per-call workload", TimingResolutionWarning,
                stacklevel=2)
        dt = max(dt, floor)
        record_phase("dispatch", t1 - t0,
                     at=t1 if default_clock else None)
        return work / dt

    return sample


@dataclasses.dataclass(frozen=True)
class BatchCalibration:
    """Fitted dispatch-batch timing model ``t(B) = overhead + B * t_exec``.

    ``batch`` is the smallest B keeping the fixed per-observation
    overhead (clock bracket + final sync + queue ramp) under the
    requested fraction of useful kernel time.
    """

    batch: int
    t_exec_s: float
    overhead_s: float


def calibrate_batch(dispatch: Callable[[], Any],
                    sync: Callable[[Any], None], *,
                    clock: Callable[[], float] = time.perf_counter,
                    overhead_frac: float = 0.02,
                    max_batch: int = 1024,
                    probe: int = 8) -> BatchCalibration:
    """Choose the dispatch batch size B for :func:`steady_sampler`.

    Times one synced call and one ``probe``-deep batch, fits
    ``t(B) = overhead + B * t_exec``, and returns the smallest B with
    ``overhead / (B * t_exec) <= overhead_frac``. Costs ``2 + probe + 3``
    kernel executions — calibrate once per workload and share the result
    across invocations (``steady_sampler(..., batch=cal.batch)``).
    """
    if probe < 2:
        raise ValueError(f"probe must be >= 2, got {probe}")
    sync(dispatch())               # warm: compile + allocator + queue
    sync(dispatch())
    singles = []
    for _ in range(3):
        t0 = clock()
        sync(dispatch())
        singles.append(clock() - t0)
    t1 = sorted(singles)[1]        # median of 3
    t0 = clock()
    h = None
    for _ in range(probe):
        h = dispatch()
    sync(h)
    tb = clock() - t0
    t_exec = max((tb - t1) / (probe - 1), 1e-12)
    overhead = max(t1 - t_exec, 0.0)
    batch = max(1, min(max_batch,
                       -(-overhead // (overhead_frac * t_exec))))
    return BatchCalibration(batch=int(batch), t_exec_s=t_exec,
                            overhead_s=overhead)


def steady_sampler(dispatch: Callable[[], Any], work: float, *,
                   sync: Callable[[Any], None],
                   batch: Optional[int] = None,
                   clock: Callable[[], float] = time.perf_counter,
                   overhead_frac: float = 0.02,
                   max_batch: int = 1024,
                   calibration: Optional[ClockCalibration] = None,
                   ) -> Callable[[], float]:
    """Batched low-overhead sampler: B async dispatches, one sync.

    ``dispatch`` enqueues one kernel execution without blocking and
    returns a handle (a jax async array); ``sync`` blocks on a handle
    (``jax.block_until_ready``). Each observation enqueues B dispatches
    back-to-back, syncs once, and reports ``work * B / elapsed`` — the
    per-sample clock + sync overhead is amortized over B and the device
    queue stays full between calls ("steady state" dispatch).

    ``batch=None`` auto-calibrates B via :func:`calibrate_batch` so the
    fixed overhead stays under ``overhead_frac`` of kernel time; the
    chosen B is exposed as ``sample.batch``. Calibration costs ~13
    kernel executions, so share an explicit ``batch`` across invocations
    of the same workload.

    Welford/CI semantics with B > 1: each observation is the *mean
    throughput of a B-call batch*, so downstream confidence intervals
    quantify run-to-run variation of batch means — per-call variance is
    averaged down by ~B inside each observation and CIConverged
    typically triggers sooner. Scores remain estimates of the same mean
    rate; see docs/harness-perf.md.
    """
    if batch is None:
        bcal = calibrate_batch(dispatch, sync, clock=clock,
                               overhead_frac=overhead_frac,
                               max_batch=max_batch)
        batch = bcal.batch
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if calibration is None and clock is time.perf_counter:
        calibration = calibrate_clock(clock)
    clock_overhead = 2.0 * calibration.overhead_s if calibration else 0.0
    total_work = work * batch
    b = batch
    default_clock = clock is time.perf_counter

    def sample() -> float:
        t0 = clock()
        h = None
        for _ in range(b):
            h = dispatch()
        tm = clock()
        sync(h)
        t1 = clock()
        dt = max(t1 - t0 - clock_overhead, 1e-12)
        record_phase("dispatch", tm - t0,
                     at=tm if default_clock else None)
        record_phase("sync", t1 - tm,
                     at=t1 if default_clock else None)
        return total_work / dt

    sample.batch = batch
    return sample

"""Pluggable execution backends for the autotuner.

The paper's search loop is inherently serial: one configuration at a time,
each pruned against the incumbent best found so far (stop condition 4).
This module factors the *scheduling* of configuration evaluations out of
:class:`~repro.core.tuner.Tuner` so the same search semantics run under
four execution regimes:

  * :class:`SerialBackend` — today's semantics, one evaluation at a time.
  * :class:`ThreadPoolBackend` — configurations evaluate concurrently;
    every evaluation reads the incumbent from a lock-protected
    :class:`IncumbentCell` *per sample*, so stop-condition-4 pruning works
    against the live global best rather than a stale snapshot. Real
    benchmarks block on device execution (``block_until_ready`` releases
    the GIL), so threads overlap genuinely on hardware.
  * :class:`ProcessPoolBackend` — configurations evaluate in worker
    *processes*, escaping the GIL for CPU-bound objectives. The evaluate
    callable and the benchmark factory must be picklable; the incumbent is
    frozen per batch (cross-process live sharing would serialize on IPC),
    so batch boundaries are this backend's all-reduce rounds, exactly like
    the simulated fleet.
  * :class:`SimulatedShardedBackend` — the fleet simulation previously
    hard-wired into ``repro.distributed.tuner``: one synchronized round
    per batch, incumbent all-reduced between rounds, faithful per-worker
    wall-clock accounting (parallel time = max over workers).

Since the strategy refactor, backends consume *batches* — the unit a
:class:`~repro.core.strategy.SearchStrategy` proposes via ``ask()`` — not
a flat configuration list. A :class:`Batch` carries its configurations
plus an optional per-batch :class:`~repro.core.evaluator.EvaluationSettings`
override (successive halving raises the iteration budget per rung this
way). Batch boundaries are semantic: round-synchronized backends
(simulated, process) freeze the incumbent per batch and all-reduce at the
batch end, and the strategy's ``tell()`` is guaranteed to have seen every
outcome of a batch before the next ``ask()``.

Backends receive an ``evaluate(config, incumbent, settings)`` callable
(built by the tuner; it owns the evaluator) where ``incumbent`` may be a
float, ``None``, or a zero-arg callable yielding the live best score, and
``settings`` is the batch override (``None`` — use the tuner's own). A
flat ``Sequence[Config]`` is still accepted by :meth:`ExecutionBackend.run`
and coerced to batches reproducing each backend's pre-strategy behaviour.
"""

from __future__ import annotations

import dataclasses
import pickle
import threading
import time
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from typing import Callable, Iterable, Mapping, Optional, Sequence, Union

from .evaluator import EvalResult, EvaluationSettings, Incumbent
from .profiling import trace_instant, trace_span
from .searchspace import Config
from .stop_conditions import Direction

__all__ = ["Batch", "BatchStats", "ExecutionBackend", "ExecutionStats",
           "IncumbentCell", "ProcessPoolBackend", "SerialBackend",
           "SimulatedShardedBackend", "ThreadPoolBackend", "TrialOutcome"]

# (config, incumbent, batch settings override) -> EvalResult; see
# evaluator.Incumbent for the float-or-live-supplier contract
EvaluateFn = Callable[[Config, Incumbent, Optional[EvaluationSettings]],
                      EvalResult]
ProgressFn = Callable[[Config, EvalResult], None]
#: batch-end feedback, called once per outcome in proposal order on the
#: scheduling thread (strategy tell + trial recording)
ObserveFn = Callable[["TrialOutcome"], None]
#: immediate persistence hook, called as soon as an outcome exists — from
#: the worker thread on concurrent backends, so it must be thread-safe
#: (TrialCache.put is); a killed run loses at most the trials in flight
PersistFn = Callable[["TrialOutcome"], None]


class IncumbentCell:
    """Lock-protected live best (score, config) shared across workers.

    ``offer`` folds a finished evaluation in; ``get`` is safe to call from
    inside a running evaluation (it is the pruning reference), so the cell
    is the single synchronization point between concurrent trials.
    """

    def __init__(self, direction: Direction,
                 score: Optional[float] = None,
                 config: Optional[Config] = None):
        self._lock = threading.Lock()
        self.direction = direction
        self._score = score
        self._config = config
        self._history: list[tuple[Optional[Config], float]] = []
        if score is not None:
            self._history.append((config, score))

    def get(self) -> Optional[float]:
        with self._lock:
            return self._score

    def snapshot(self) -> tuple[Optional[Config], Optional[float]]:
        with self._lock:
            return self._config, self._score

    def history(self) -> tuple[tuple[Optional[Config], float], ...]:
        """Every accepted incumbent in acceptance order (a warm-start seed,
        if any, is entry 0) — the convergence trajectory reports print."""
        with self._lock:
            return tuple(self._history)

    def offer(self, config: Config, score: float) -> bool:
        """Fold in a candidate; returns True iff it became the incumbent."""
        with self._lock:
            if self._score is None or self.direction.better(score,
                                                            self._score):
                self._score = score
                self._config = config
                self._history.append((config, score))
                return True
            return False


@dataclasses.dataclass(frozen=True)
class Batch:
    """One strategy proposal: configurations to evaluate together.

    ``settings`` overrides the tuner's evaluation settings for this batch
    only (e.g. a successive-halving rung budget); ``None`` means the
    tuner's own settings apply — and only then may the trial cache serve
    hits, since cached results were measured under those settings.
    """

    configs: tuple[Config, ...]
    settings: Optional[EvaluationSettings] = None

    def __len__(self) -> int:
        return len(self.configs)


@dataclasses.dataclass(frozen=True)
class TrialOutcome:
    """One scheduled evaluation as the backend saw it."""

    index: int           # position in the overall proposal order
    config: Config
    result: EvalResult
    worker: int = 0
    elapsed_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class BatchStats:
    """Per-batch scheduling accounting (one strategy round)."""

    index: int
    size: int
    wall_s: float
    n_pruned: int


@dataclasses.dataclass(frozen=True)
class ExecutionStats:
    """Scheduling accounting, uniform across backends."""

    backend: str
    n_workers: int
    serial_time_s: float     # sum of per-trial wall clock
    parallel_time_s: float   # run wall clock (simulated: max over workers)
    batches: tuple[BatchStats, ...] = ()


BatchSource = Union[Iterable[Batch], Sequence[Config]]


def _traced_trial(clock: Callable[[], float], evaluate: EvaluateFn,
                  cfg: Config, incumbent: Incumbent,
                  settings: Optional[EvaluationSettings],
                  cell: Optional[IncumbentCell], index: int, worker: int,
                  ) -> tuple[EvalResult, float]:
    """Evaluate one configuration inside a ``cat="trial"`` trace span.

    Runs on the thread that executes the trial, so the span lands on the
    right tid with the evaluator's invocation/phase spans nested inside.
    ``cell`` non-None folds the score into the live incumbent (serial and
    thread backends); round-synchronized backends pass ``None`` and
    all-reduce at the round end, emitting their improvement instants
    there instead.
    """
    with trace_span("trial", cat="trial", index=index,
                    config=dict(cfg)) as span:
        t1 = clock()
        res = evaluate(cfg, incumbent, settings)
        dt = clock() - t1
        improved = False
        if res.pruned:
            trace_instant("trial_pruned", reason=res.stop_reason)
        elif cell is not None:
            improved = cell.offer(cfg, res.score)
            if improved:
                trace_instant("incumbent_improved", score=res.score)
        span.set(score=res.score, pruned=res.pruned,
                 stop_reason=res.stop_reason, samples=res.total_samples,
                 worker=worker, improved=improved)
    return res, dt


class ExecutionBackend:
    """Schedules evaluations over strategy-proposed batches.

    Subclasses implement :meth:`_run_batch` (execute one batch, calling
    ``observe`` for every outcome before returning — that ordering is what
    guarantees a strategy's ``tell()`` runs before its next ``ask()``) and
    may override the per-run context hooks for pools or per-worker
    accounting. ``batch_hint`` is the batch size the backend schedules
    best (its parallel width); strategies treat it as a suggestion.
    """

    name: str = "base"
    n_workers: int = 1
    #: round width passed to ``SearchStrategy.ask``: the all-reduce batch
    #: size for round-synchronized backends (simulated, process), ``None``
    #: when the backend imposes no round structure (serial, thread) — the
    #: strategy then proposes its full natural unit per batch
    batch_hint: Optional[int] = None
    #: chunk size used when a flat config list is passed to :meth:`run`
    #: (``None`` — a single batch, the pre-strategy behaviour of the
    #: serial/thread backends; round-synchronized backends use n_workers)
    legacy_round: Optional[int] = None
    clock: Callable[[], float] = staticmethod(time.perf_counter)

    def run(self, batches: BatchSource, evaluate: EvaluateFn,
            cell: IncumbentCell, progress: Optional[ProgressFn] = None,
            observe: Optional[ObserveFn] = None,
            persist: Optional[PersistFn] = None,
            ) -> tuple[list[TrialOutcome], ExecutionStats]:
        """Drain ``batches`` (an iterable of :class:`Batch`, typically a
        generator pulling from a strategy, or a flat config list for
        compatibility) and return every outcome plus scheduling stats."""
        batches = self._as_batches(batches)
        outcomes: list[TrialOutcome] = []
        stats: list[BatchStats] = []
        serial = 0.0
        t0 = self.clock()
        ctx = self._start_run()
        try:
            for b, batch in enumerate(batches):
                if not batch.configs:
                    continue
                tb = self.clock()
                got = self._run_batch(ctx, batch, evaluate, cell, progress,
                                      observe, persist,
                                      base_index=len(outcomes))
                outcomes.extend(got)
                serial += sum(o.elapsed_s for o in got)
                stats.append(BatchStats(
                    index=b, size=len(got), wall_s=self.clock() - tb,
                    n_pruned=sum(1 for o in got if o.result.pruned)))
        finally:
            self._end_run(ctx)
        wall = self.clock() - t0
        return outcomes, ExecutionStats(
            backend=self.name, n_workers=self.n_workers,
            serial_time_s=serial,
            parallel_time_s=self._parallel_time(ctx, wall),
            batches=tuple(stats))

    # -- per-run hooks --------------------------------------------------------
    def _start_run(self):
        return None

    def _end_run(self, ctx) -> None:
        pass

    def _parallel_time(self, ctx, wall: float) -> float:
        return wall

    def _run_batch(self, ctx, batch: Batch, evaluate: EvaluateFn,
                   cell: IncumbentCell, progress: Optional[ProgressFn],
                   observe: Optional[ObserveFn],
                   persist: Optional[PersistFn],
                   base_index: int) -> list[TrialOutcome]:
        raise NotImplementedError

    # -- compatibility --------------------------------------------------------
    def _as_batches(self, batches: BatchSource) -> Iterable[Batch]:
        """Coerce a flat ``Sequence[Config]`` into this backend's
        pre-strategy batching (one batch, or ``legacy_round``-sized rounds
        for the round-synchronized backends)."""
        if isinstance(batches, Sequence) and not isinstance(batches,
                                                            (str, bytes)):
            items = list(batches)
            if items and all(isinstance(c, Mapping) for c in items):
                size = self.legacy_round or len(items)
                return [Batch(tuple(items[i:i + size]))
                        for i in range(0, len(items), size)]
        return batches


class SerialBackend(ExecutionBackend):
    """One evaluation at a time, in proposal order (the paper's loop)."""

    name = "serial"

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock

    def _run_batch(self, ctx, batch, evaluate, cell, progress, observe,
                   persist, base_index):
        outcomes: list[TrialOutcome] = []
        for j, cfg in enumerate(batch.configs):
            res, dt = _traced_trial(self.clock, evaluate, cfg, cell.get,
                                    batch.settings, cell, base_index + j,
                                    worker=0)
            out = TrialOutcome(index=base_index + j, config=cfg, result=res,
                               elapsed_s=dt)
            outcomes.append(out)
            # persist + observe before progress, so a progress callback
            # that aborts the run never loses the trial
            if persist is not None:
                persist(out)
            if observe is not None:
                observe(out)
            if progress is not None:
                progress(cfg, res)
        return outcomes


class ThreadPoolBackend(ExecutionBackend):
    """Concurrent evaluations sharing the incumbent cell live.

    Each in-flight evaluation re-reads the cell before every sample, so a
    best score found on one thread immediately tightens stop-condition-4
    pruning on all others. ``persist`` and ``progress`` fire live from
    the worker thread as each trial finishes (so a killed run keeps every
    completed trial on disk); ``observe`` runs on the scheduling thread
    at the batch end, in proposal order.
    """

    name = "thread"

    def __init__(self, n_workers: int = 4,
                 clock: Callable[[], float] = time.perf_counter):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        self.n_workers = n_workers
        self.clock = clock

    def _start_run(self):
        return {"pool": ThreadPoolExecutor(max_workers=self.n_workers),
                "progress_lock": threading.Lock()}

    def _end_run(self, ctx) -> None:
        ctx["pool"].shutdown(wait=True)

    def _run_batch(self, ctx, batch, evaluate, cell, progress, observe,
                   persist, base_index):
        lock = ctx["progress_lock"]

        def work(j: int, cfg: Config) -> TrialOutcome:
            res, dt = _traced_trial(self.clock, evaluate, cfg, cell.get,
                                    batch.settings, cell, base_index + j,
                                    worker=j % self.n_workers)
            out = TrialOutcome(index=base_index + j, config=cfg, result=res,
                               elapsed_s=dt)
            if persist is not None:
                persist(out)          # thread-safe; survives a killed run
            if progress is not None:
                with lock:
                    progress(cfg, res)
            return out

        outcomes = list(ctx["pool"].map(work, range(len(batch.configs)),
                                        batch.configs))
        if observe is not None:
            for out in outcomes:
                observe(out)
        return outcomes


def shard_configs(configs: Sequence[Config],
                  n_workers: int) -> list[list[Config]]:
    """Strided assignment: adjacent (similar-cost) configs spread across
    workers, balancing the size-correlated evaluation cost (paper Fig. 6)."""
    configs = list(configs)
    return [configs[w::n_workers] for w in range(n_workers)]


class SimulatedShardedBackend(ExecutionBackend):
    """Simulated fleet: one synchronized round per batch.

    Workers run lockstep rounds; within a round every worker prunes against
    the incumbent agreed at the end of the *previous* round (a scalar
    ``lax.pmax``/``pmin`` on a real mesh). Evaluations execute serially
    here but per-worker wall clock is accounted faithfully, so
    ``parallel_time_s`` is the simulated fleet wall clock. Batch boundaries
    are the all-reduce rounds: a flat config list is coerced to
    ``n_workers``-sized rounds, reproducing the pre-strategy strided
    schedule exactly.
    """

    name = "simulated"

    def __init__(self, n_workers: int = 4,
                 clock: Callable[[], float] = time.perf_counter):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        self.n_workers = n_workers
        self.batch_hint = n_workers
        self.legacy_round = n_workers
        self.clock = clock

    def _start_run(self):
        return {"worker_time": [0.0] * self.n_workers}

    def _parallel_time(self, ctx, wall: float) -> float:
        times = ctx["worker_time"]
        return max(times) if any(t > 0.0 for t in times) else 0.0

    def _run_batch(self, ctx, batch, evaluate, cell, progress, observe,
                   persist, base_index):
        frozen = cell.get()  # previous round's all-reduced incumbent
        outcomes: list[TrialOutcome] = []
        for j, cfg in enumerate(batch.configs):
            w = j % self.n_workers
            res, dt = _traced_trial(self.clock, evaluate, cfg, frozen,
                                    batch.settings, None, base_index + j,
                                    worker=w)
            ctx["worker_time"][w] += dt
            out = TrialOutcome(index=base_index + j, config=cfg,
                               result=res, worker=w, elapsed_s=dt)
            outcomes.append(out)
            if persist is not None:
                persist(out)
            if progress is not None:
                progress(cfg, res)
        for out in outcomes:            # the round's all-reduce
            if not out.result.pruned and cell.offer(out.config,
                                                    out.result.score):
                trace_instant("incumbent_improved",
                              score=out.result.score, trial=out.index)
        if observe is not None:
            for out in outcomes:
                observe(out)
        return outcomes


def _process_trial(evaluate: EvaluateFn, cfg: Config,
                   incumbent: Optional[float],
                   settings: Optional[EvaluationSettings],
                   ) -> tuple[EvalResult, float]:
    """Worker-side trial: runs in the pool process; the elapsed time is
    measured inside the worker so IPC overhead never pollutes trial time."""
    t1 = time.perf_counter()
    res = evaluate(cfg, incumbent, settings)
    return res, time.perf_counter() - t1


class ProcessPoolBackend(ExecutionBackend):
    """Evaluations in worker processes — escapes the GIL for CPU-bound
    objectives (the ROADMAP's process-pool backend).

    The evaluate callable (and therefore the benchmark factory and
    settings) must be picklable; module-level functions qualify, lambdas
    and closures do not — :meth:`run` raises a ``TypeError`` naming the
    offender up front rather than failing inside the pool. The incumbent
    is frozen per batch and all-reduced at the batch end (live
    cross-process sharing would serialize every sample on IPC), so this
    backend has the simulated fleet's round semantics with real
    parallelism.

    Workers start via the ``spawn`` method by default: JAX is
    multithreaded, so forking a process that has already initialized the
    jax backend deadlocks. Spawned workers re-import the evaluate task's
    modules (the parent's ``sys.path`` is inherited), costing ~2 s of
    startup per pool — amortized over a search, and the only start method
    that is safe after jax initialization. Pass ``start_method="fork"``
    only for jax-free objectives where startup dominates.
    """

    name = "process"

    def __init__(self, n_workers: int = 4,
                 clock: Callable[[], float] = time.perf_counter,
                 start_method: str = "spawn"):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        self.n_workers = n_workers
        self.batch_hint = n_workers
        self.legacy_round = n_workers
        self.clock = clock
        self.start_method = start_method

    def _make_pool(self) -> ProcessPoolExecutor:
        import multiprocessing
        try:
            mp_ctx = multiprocessing.get_context(self.start_method)
        except ValueError:                       # platform without it
            mp_ctx = multiprocessing.get_context()
        return ProcessPoolExecutor(max_workers=self.n_workers,
                                   mp_context=mp_ctx)

    def _start_run(self):
        return {"pool": None, "checked": False}

    def _end_run(self, ctx) -> None:
        if ctx["pool"] is not None:
            ctx["pool"].shutdown(wait=True)

    def _check_picklable(self, evaluate: EvaluateFn,
                         settings: Optional[EvaluationSettings]) -> None:
        try:
            pickle.dumps((evaluate, settings))
        except Exception as e:
            raise TypeError(
                "ProcessPoolBackend requires a picklable evaluate task: "
                "benchmark factories must be module-level callables, not "
                f"lambdas or closures ({e})") from e

    def _run_batch(self, ctx, batch, evaluate, cell, progress, observe,
                   persist, base_index):
        if not ctx["checked"]:
            self._check_picklable(evaluate, batch.settings)
            ctx["checked"] = True
        if ctx["pool"] is None:
            ctx["pool"] = self._make_pool()
        frozen = cell.get()  # previous batch's all-reduced incumbent
        futures = [ctx["pool"].submit(_process_trial, evaluate, cfg, frozen,
                                      batch.settings)
                   for cfg in batch.configs]
        outcomes: list[TrialOutcome] = []
        for j, (cfg, fut) in enumerate(zip(batch.configs, futures)):
            res, dt = fut.result()
            out = TrialOutcome(index=base_index + j, config=cfg, result=res,
                               worker=j % self.n_workers, elapsed_s=dt)
            outcomes.append(out)
            # worker processes carry no recorder, so trials surface as
            # parent-side instants (timing measured inside the worker)
            trace_instant("trial_completed", index=out.index,
                          score=res.score, pruned=res.pruned,
                          worker=out.worker, elapsed_s=dt)
            if persist is not None:     # parent-side, as futures land
                persist(out)
        for out in outcomes:            # the batch's all-reduce
            if not out.result.pruned and cell.offer(out.config,
                                                    out.result.score):
                trace_instant("incumbent_improved",
                              score=out.result.score, trial=out.index)
        for out in outcomes:
            if observe is not None:
                observe(out)
            if progress is not None:
                progress(out.config, out.result)
        return outcomes

"""Pluggable execution backends for the autotuner.

The paper's search loop is inherently serial: one configuration at a time,
each pruned against the incumbent best found so far (stop condition 4).
This module factors the *scheduling* of configuration evaluations out of
:class:`~repro.core.tuner.Tuner` so the same search semantics run under
three execution regimes:

  * :class:`SerialBackend` — today's semantics, one evaluation at a time.
  * :class:`ThreadPoolBackend` — configurations evaluate concurrently;
    every evaluation reads the incumbent from a lock-protected
    :class:`IncumbentCell` *per sample*, so stop-condition-4 pruning works
    against the live global best rather than a stale snapshot. Real
    benchmarks block on device execution (``block_until_ready`` releases
    the GIL), so threads overlap genuinely on hardware.
  * :class:`SimulatedShardedBackend` — the fleet simulation previously
    hard-wired into ``repro.distributed.tuner``: strided shards, one
    synchronized round per shard index, incumbent all-reduced between
    rounds, faithful per-worker wall-clock accounting
    (parallel time = max over workers).

Backends receive an ``evaluate(config, incumbent)`` callable (built by the
tuner; it owns the evaluator and the optional trial cache) where
``incumbent`` may be a float, ``None``, or a zero-arg callable yielding the
live best score.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Optional, Sequence

from .evaluator import EvalResult, Incumbent
from .searchspace import Config
from .stop_conditions import Direction

__all__ = ["ExecutionBackend", "ExecutionStats", "IncumbentCell",
           "SerialBackend", "SimulatedShardedBackend", "ThreadPoolBackend",
           "TrialOutcome"]

# (config, incumbent) -> EvalResult; see evaluator.Incumbent for the
# float-or-live-supplier contract
EvaluateFn = Callable[[Config, Incumbent], EvalResult]
ProgressFn = Callable[[Config, EvalResult], None]


class IncumbentCell:
    """Lock-protected live best (score, config) shared across workers.

    ``offer`` folds a finished evaluation in; ``get`` is safe to call from
    inside a running evaluation (it is the pruning reference), so the cell
    is the single synchronization point between concurrent trials.
    """

    def __init__(self, direction: Direction,
                 score: Optional[float] = None,
                 config: Optional[Config] = None):
        self._lock = threading.Lock()
        self.direction = direction
        self._score = score
        self._config = config
        self._history: list[tuple[Optional[Config], float]] = []
        if score is not None:
            self._history.append((config, score))

    def get(self) -> Optional[float]:
        with self._lock:
            return self._score

    def snapshot(self) -> tuple[Optional[Config], Optional[float]]:
        with self._lock:
            return self._config, self._score

    def history(self) -> tuple[tuple[Optional[Config], float], ...]:
        """Every accepted incumbent in acceptance order (a warm-start seed,
        if any, is entry 0) — the convergence trajectory reports print."""
        with self._lock:
            return tuple(self._history)

    def offer(self, config: Config, score: float) -> bool:
        """Fold in a candidate; returns True iff it became the incumbent."""
        with self._lock:
            if self._score is None or self.direction.better(score,
                                                            self._score):
                self._score = score
                self._config = config
                self._history.append((config, score))
                return True
            return False


@dataclasses.dataclass(frozen=True)
class TrialOutcome:
    """One scheduled evaluation as the backend saw it."""

    index: int           # position in the search order
    config: Config
    result: EvalResult
    worker: int = 0
    elapsed_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class ExecutionStats:
    """Scheduling accounting, uniform across backends."""

    backend: str
    n_workers: int
    serial_time_s: float     # sum of per-trial wall clock
    parallel_time_s: float   # run wall clock (simulated: max over workers)


class ExecutionBackend:
    """Schedules evaluations over an ordered configuration list."""

    name: str = "base"

    def run(self, configs: Sequence[Config], evaluate: EvaluateFn,
            cell: IncumbentCell, progress: Optional[ProgressFn] = None,
            ) -> tuple[list[TrialOutcome], ExecutionStats]:
        raise NotImplementedError


class SerialBackend(ExecutionBackend):
    """One evaluation at a time, in search order (the paper's loop)."""

    name = "serial"

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock

    def run(self, configs, evaluate, cell, progress=None):
        outcomes: list[TrialOutcome] = []
        t0 = self.clock()
        serial = 0.0
        for i, cfg in enumerate(configs):
            t1 = self.clock()
            res = evaluate(cfg, cell.get)
            dt = self.clock() - t1
            serial += dt
            if not res.pruned:
                cell.offer(cfg, res.score)
            outcomes.append(TrialOutcome(index=i, config=cfg, result=res,
                                         elapsed_s=dt))
            if progress is not None:
                progress(cfg, res)
        return outcomes, ExecutionStats(
            backend=self.name, n_workers=1, serial_time_s=serial,
            parallel_time_s=self.clock() - t0)


class ThreadPoolBackend(ExecutionBackend):
    """Concurrent evaluations sharing the incumbent cell live.

    Each in-flight evaluation re-reads the cell before every sample, so a
    best score found on one thread immediately tightens stop-condition-4
    pruning on all others.
    """

    name = "thread"

    def __init__(self, n_workers: int = 4,
                 clock: Callable[[], float] = time.perf_counter):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        self.n_workers = n_workers
        self.clock = clock

    def run(self, configs, evaluate, cell, progress=None):
        progress_lock = threading.Lock()

        def work(i: int, cfg: Config) -> TrialOutcome:
            t1 = self.clock()
            res = evaluate(cfg, cell.get)
            dt = self.clock() - t1
            if not res.pruned:
                cell.offer(cfg, res.score)
            if progress is not None:
                with progress_lock:
                    progress(cfg, res)
            return TrialOutcome(index=i, config=cfg, result=res,
                                elapsed_s=dt)

        t0 = self.clock()
        with ThreadPoolExecutor(max_workers=self.n_workers) as pool:
            outcomes = list(pool.map(work, range(len(configs)), configs))
        wall = self.clock() - t0
        return outcomes, ExecutionStats(
            backend=self.name, n_workers=self.n_workers,
            serial_time_s=sum(o.elapsed_s for o in outcomes),
            parallel_time_s=wall)


def shard_configs(configs: Sequence[Config],
                  n_workers: int) -> list[list[Config]]:
    """Strided assignment: adjacent (similar-cost) configs spread across
    workers, balancing the size-correlated evaluation cost (paper Fig. 6)."""
    configs = list(configs)
    return [configs[w::n_workers] for w in range(n_workers)]


class SimulatedShardedBackend(ExecutionBackend):
    """Simulated fleet: strided shards, per-round incumbent all-reduce.

    Workers run lockstep rounds; within a round every worker prunes against
    the incumbent agreed at the end of the *previous* round (a scalar
    ``lax.pmax``/``pmin`` on a real mesh). Evaluations execute serially
    here but per-worker wall clock is accounted faithfully, so
    ``parallel_time_s`` is the simulated fleet wall clock. This reproduces
    the paper-extension speedup tables exactly as before the refactor.
    """

    name = "simulated"

    def __init__(self, n_workers: int = 4,
                 clock: Callable[[], float] = time.perf_counter):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        self.n_workers = n_workers
        self.clock = clock

    def run(self, configs, evaluate, cell, progress=None):
        configs = list(configs)
        shards = shard_configs(list(enumerate(configs)), self.n_workers)
        worker_time = [0.0] * self.n_workers
        outcomes: list[TrialOutcome] = []
        rounds = max((len(s) for s in shards), default=0)
        for r in range(rounds):
            frozen = cell.get()  # previous round's all-reduced incumbent
            round_results: list[tuple[Config, EvalResult]] = []
            for w, shard in enumerate(shards):
                if r >= len(shard):
                    continue
                i, cfg = shard[r]
                t1 = self.clock()
                res = evaluate(cfg, frozen)
                dt = self.clock() - t1
                worker_time[w] += dt
                outcomes.append(TrialOutcome(index=i, config=cfg, result=res,
                                             worker=w, elapsed_s=dt))
                round_results.append((cfg, res))
                if progress is not None:
                    progress(cfg, res)
            for cfg, res in round_results:
                if not res.pruned:
                    cell.offer(cfg, res.score)
        return outcomes, ExecutionStats(
            backend=self.name, n_workers=self.n_workers,
            serial_time_s=sum(worker_time),
            parallel_time_s=max(worker_time) if worker_time else 0.0)

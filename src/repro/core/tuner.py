"""The autotuner engine: strategy-proposed, CI-pruned evaluation (paper
Fig. 2, generalized).

A :class:`~repro.core.strategy.SearchStrategy` proposes configuration
batches (``ask``), an :class:`~repro.core.executor.ExecutionBackend`
schedules their evaluation through the two-level
:class:`~repro.core.evaluator.Evaluator`, and every outcome is fed back
(``tell``) before the next proposal — with the incumbent best shared
through a lock-protected cell so stop condition 4 prunes doomed
configurations against the live (or round-frozen) global best. The
paper's experiments (Tables VIII-XI) are exactly runs of this engine
under the exhaustive strategy with different
:class:`EvaluationSettings` flags and search orders.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

from .evaluator import (EvalResult, EvaluationSettings, Evaluator, Incumbent,
                        InvocationFactory)
from .exec_cache import CompilePipeline, default_cache
from .executor import (Batch, BatchStats, ExecutionBackend, IncumbentCell,
                       SerialBackend, TrialOutcome)
from .profiling import phase, trace_instant, trace_sink, trace_span
from .searchspace import Config, SearchSpace
from .strategy import ExhaustiveStrategy, SearchStrategy, SuccessiveHalvingStrategy

__all__ = ["BenchmarkFactory", "EvaluateTask", "TrialRecord", "Tuner",
           "TuningResult", "compare_techniques", "standard_techniques",
           "tune_successive_halving"]

# A benchmark binds a configuration to a per-invocation sampler factory.
BenchmarkFactory = Callable[[Config], InvocationFactory]


@dataclasses.dataclass(frozen=True)
class TrialRecord:
    config: Config
    result: EvalResult
    cached: bool = False      # served from a TrialCache, not re-evaluated
    worker: int = 0           # backend worker that ran it


@dataclasses.dataclass
class EvaluateTask:
    """The engine's evaluation callable, shipped to backends.

    A plain dataclass (not a closure) so :class:`ProcessPoolBackend` can
    pickle it into worker processes — which also requires ``benchmark`` to
    be a module-level callable. The optional per-call ``settings`` is a
    strategy's batch override (e.g. a successive-halving rung budget).
    """

    settings: EvaluationSettings
    benchmark: BenchmarkFactory
    clock: Callable[[], float] = time.perf_counter

    def __call__(self, config: Config, incumbent: Incumbent,
                 settings: Optional[EvaluationSettings] = None) -> EvalResult:
        from repro.obs.metrics import metrics
        metrics().inc("trials.started")
        evaluator = Evaluator(settings or self.settings, clock=self.clock)
        return evaluator.evaluate(self.benchmark(config), incumbent=incumbent)


@dataclasses.dataclass(frozen=True)
class TuningResult:
    best_config: Optional[Config]
    best_score: Optional[float]
    trials: tuple[TrialRecord, ...]
    total_time_s: float
    total_samples: int
    n_pruned: int
    settings_label: str
    order: str
    # execution-backend accounting (serial defaults keep old pickles/tests)
    backend: str = "serial"
    n_workers: int = 1
    serial_time_s: float = 0.0     # sum of per-trial wall clock
    parallel_time_s: float = 0.0   # run wall clock (simulated: max/worker)
    n_cached: int = 0              # trials served from the cache
    # incumbent trajectory: every accepted (config, score) in acceptance
    # order; entry 0 is the warm-start seed when a cache seeded the cell
    improvements: tuple[tuple[Optional[Config], float], ...] = ()
    # strategy accounting
    strategy: str = "exhaustive"   # SearchStrategy.name that drove the run
    batches: tuple[BatchStats, ...] = ()   # one entry per strategy round
    n_seeded: int = 0              # transfer seeds injected into the search
    n_precompiled: int = 0         # executables compiled by the pipeline
    # observability (repro.obs): the session's trace file (None when
    # tracing was off), the per-session MetricsRegistry delta, and the
    # per-session ExecCacheStats delta of the shared process cache —
    # deltas, so back-to-back sessions never report each other's counts
    trace_path: Optional[str] = None
    metrics: Optional[dict] = None
    exec_cache: Optional[dict] = None

    def summary_row(self) -> dict:
        return {
            "technique": self.settings_label + ("+R" if self.order == "reverse" else ""),
            "best_score": self.best_score,
            "best_config": self.best_config,
            "time_s": round(self.total_time_s, 4),
            "samples": self.total_samples,
            "pruned": self.n_pruned,
            "trials": len(self.trials),
        }


class Tuner:
    """Strategy-driven autotuner with incumbent pruning.

    ``strategy`` is any :class:`~repro.core.strategy.SearchStrategy`;
    the default is the paper's exhaustive visit. ``order``/``seed`` are
    kept as a deprecated alias for
    ``strategy=ExhaustiveStrategy(order, seed)`` — passing both ``order``
    and ``strategy`` is an error.
    """

    def __init__(self, space: SearchSpace, settings: EvaluationSettings,
                 strategy: Optional[SearchStrategy] = None,
                 order: Optional[str] = None, seed: Optional[int] = None,
                 clock: Callable[[], float] = time.perf_counter):
        if strategy is not None and order is not None:
            raise ValueError("pass either strategy= or the deprecated "
                             "order= alias, not both")
        if strategy is None:
            strategy = ExhaustiveStrategy(order=order or "exhaustive",
                                          seed=seed)
        self.space = space
        self.settings = settings
        self.strategy = strategy
        self.order = getattr(strategy, "order", strategy.order_label)
        self.seed = seed
        self.clock = clock

    def tune(self, benchmark: BenchmarkFactory,
             progress: Optional[Callable[[Config, EvalResult], None]] = None,
             backend: Optional[ExecutionBackend] = None,
             cache=None, warm_start: bool = False,
             seeds: Sequence[Config] = (),
             ledger=None, timestamp: Optional[float] = None,
             validate: str = "warn",
             pipeline: "str | CompilePipeline | None" = "auto",
             ) -> TuningResult:
        """Search the space for the best configuration.

        ``backend`` schedules the evaluations (default
        :class:`~repro.core.executor.SerialBackend`, the paper's loop);
        ``cache`` is a :class:`~repro.core.cache.BoundCache` — configs
        already in it are served without re-evaluation and fresh results
        are appended; ``warm_start`` additionally seeds the incumbent from
        the best cached trial so pruning bites from trial 1. ``seeds`` are
        transfer-tuning warm-start configurations (e.g. a related
        benchmark's cached incumbents from ``TrialCache.suggest_seeds``);
        they are projected into the space and handed to the strategy,
        which evaluates them first. ``ledger`` is a
        :class:`~repro.history.ledger.BoundLedger`: on completion the
        run's incumbent (config, pooled moments, strategy, settings key)
        is appended to the performance-history ledger, stamped with the
        caller-supplied ``timestamp`` — the engine itself never reads a
        clock for record content.

        ``validate`` gates the pre-run **workload audit**
        (:mod:`repro.lint`): when the benchmark exposes an ``audit_spec``
        attribute, its declared work term is cross-checked against the
        traced kernel cost for the space's first configuration *before
        any trial executes*. ``"warn"`` (default) raises
        :class:`~repro.lint.WorkloadAuditWarning`s and proceeds;
        ``"strict"`` raises :class:`~repro.lint.WorkloadAuditError`
        instead, so a mis-declared workload never burns measurement
        time; ``"off"`` skips the audit.

        ``pipeline`` controls **pipelined compilation**: when the
        benchmark exposes a ``precompile(config)`` hook (the standard
        factories warm the :class:`~repro.core.exec_cache.ExecutableCache`
        from ``ShapeDtypeStruct``s), every fresh config in a proposed
        batch is submitted to a background
        :class:`~repro.core.exec_cache.CompilePipeline` before the batch
        executes — trial k+1's executable compiles while trial k runs.
        ``"auto"`` (default) enables this on the serial and thread
        backends; ``None``/``"off"`` disables it; an explicit
        :class:`CompilePipeline` is used as-is (and left open for the
        caller to close). The cache's in-flight deduplication guarantees
        a trial never compiles what the pipeline already started.
        """
        from repro.obs.metrics import metrics as obs_metrics

        from .cache import settings_key

        reg = obs_metrics()
        if validate not in ("off", "warn", "strict"):
            raise ValueError(f"validate must be 'off', 'warn' or 'strict', "
                             f"got {validate!r}")
        if validate != "off":
            self._validate_workload(benchmark, strict=validate == "strict")
        if backend is None:
            backend = SerialBackend(clock=self.clock)
        strategy = self.strategy
        direction = self.settings.direction
        session_key = settings_key(self.settings)
        cell = IncumbentCell(direction)
        if cache is not None and warm_start:
            # settings parity: never seed the incumbent from a trial
            # measured under other settings (e.g. a halving rung budget)
            best = cache.best(direction, settings_key=session_key)
            if best is not None:
                cell.offer(best[0], best[1])
        projected = self._project_seeds(seeds)
        strategy.reset(self.space, self.settings, seeds=projected)
        evaluate = EvaluateTask(self.settings, benchmark, clock=self.clock)
        hint = getattr(backend, "batch_hint", None)
        precompile = getattr(benchmark, "precompile", None)
        own_pipeline = False
        if pipeline == "auto":
            # process workers cannot share this process's executable
            # cache, and the simulated backend runs nothing — pipelining
            # pays off only where compiles land in our process
            if precompile is not None and \
                    getattr(backend, "name", "") in ("serial", "thread"):
                pipeline = CompilePipeline()
                own_pipeline = True
            else:
                pipeline = None
        elif pipeline == "off":
            pipeline = None
        if pipeline is not None and precompile is None:
            pipeline = None
        records: list[TrialRecord] = []
        # effective settings key of the batch currently executing; observe
        # runs between generator resumes, so this is stable per batch
        current_key = {"value": session_key}

        def batches():
            while True:
                asked = strategy.ask(hint)
                if asked is None or not asked.configs:
                    return
                fresh: list[Config] = []
                for cfg in asked.configs:
                    # cache hits are only served for batches without a
                    # settings override AND records measured under the
                    # tuner's own settings — a rung-truncated trial must
                    # never pass for a full-budget one
                    hit = cache.get(cfg, settings_key=session_key) \
                        if cache is not None and asked.settings is None \
                        else None
                    if hit is not None:
                        if not hit.pruned:
                            cell.offer(cfg, hit.score)
                        strategy.tell(cfg, hit)
                        trace_instant("cache_hit", config=dict(cfg),
                                      score=hit.score, pruned=hit.pruned,
                                      stop_reason=hit.stop_reason,
                                      samples=hit.total_samples)
                        reg.inc("trials.cached")
                        records.append(TrialRecord(config=cfg, result=hit,
                                                   cached=True))
                        if progress is not None:
                            progress(cfg, hit)
                    else:
                        fresh.append(cfg)
                if fresh:
                    if pipeline is not None:
                        # submitted before the batch executes: the worker
                        # compiles ahead while the backend measures, and
                        # a trial that overtakes it just waits on the
                        # cache's in-flight entry instead of recompiling
                        for cfg in fresh:
                            pipeline.submit(
                                lambda c=cfg: precompile(c))
                    current_key["value"] = session_key \
                        if asked.settings is None \
                        else settings_key(asked.settings)
                    yield Batch(tuple(fresh), asked.settings)

        def persist(outcome: TrialOutcome) -> None:
            # called by the backend as soon as the trial finishes — from
            # the worker thread on concurrent backends (TrialCache.put is
            # thread-safe) — so a killed run keeps every completed trial
            reg.inc("trials.completed")
            if outcome.result.pruned:
                reg.inc("trials.pruned")
            if cache is not None:
                with phase("cache_io"):
                    cache.put(outcome.config, outcome.result,
                              strategy=strategy.name,
                              settings_key=current_key["value"])

        def observe(outcome: TrialOutcome) -> None:
            strategy.tell(outcome.config, outcome.result)
            records.append(TrialRecord(config=outcome.config,
                                       result=outcome.result,
                                       worker=outcome.worker))

        t0 = self.clock()
        # per-session observability deltas: snapshot the process-global
        # registries at entry, report only the movement at exit
        metrics_at_entry = reg.snapshot()
        exec_at_entry = default_cache().stats
        recorder = trace_sink()
        try:
            with trace_span(
                    "tune", cat="session", context=True,
                    strategy=strategy.name,
                    backend=getattr(backend, "name", "?"),
                    n_workers=getattr(backend, "n_workers", 1),
                    settings=self.settings.label(),
                    settings_key=session_key) as session_span:
                _, stats = backend.run(batches(), evaluate, cell,
                                       progress=progress, observe=observe,
                                       persist=persist)
                session_span.set(n_trials=len(records))
        finally:
            n_precompiled = 0
            if pipeline is not None:
                if own_pipeline:
                    # discard queued leftovers; the in-flight task (if
                    # any) finishes — never kill a compile mid-way
                    pipeline.close(wait=False)
                n_precompiled = pipeline.counts[1]
        exec_delta = default_cache().stats.delta(exec_at_entry)
        for key, moved in (("exec_cache.hits", exec_delta.hits),
                           ("exec_cache.misses", exec_delta.misses),
                           ("exec_cache.compiles", exec_delta.compiles)):
            if moved:
                reg.inc(key, moved)
        metrics_delta = reg.delta(metrics_at_entry)
        if recorder is not None:
            recorder.meta_event(metrics=metrics_delta,
                                exec_cache=exec_delta.to_json())
        best_cfg, best_score = cell.snapshot()
        trials = tuple(records)
        result = TuningResult(
            best_config=best_cfg,
            best_score=best_score,
            trials=trials,
            total_time_s=self.clock() - t0,
            total_samples=sum(t.result.total_samples for t in trials),
            n_pruned=sum(1 for t in trials if t.result.pruned),
            settings_label=self.settings.label(),
            order=strategy.order_label,
            backend=stats.backend,
            n_workers=stats.n_workers,
            serial_time_s=stats.serial_time_s,
            parallel_time_s=stats.parallel_time_s,
            n_cached=sum(1 for t in trials if t.cached),
            improvements=cell.history(),
            strategy=strategy.name,
            batches=stats.batches,
            n_seeded=len(projected),
            n_precompiled=n_precompiled,
            trace_path=str(recorder.path)
            if recorder is not None and getattr(recorder, "path", None)
            else None,
            metrics=metrics_delta,
            exec_cache=exec_delta.to_json(),
        )
        if ledger is not None:
            # duck-typed BoundLedger so core never imports repro.history
            ledger.record(result, settings_key=session_key,
                          timestamp=timestamp, direction=direction)
        return result

    def _validate_workload(self, benchmark, strict: bool) -> None:
        """Pre-run measurement-soundness audit (lint pass 1).

        Audits the benchmark's ``audit_spec`` against the space's first
        configuration. Info-level findings (MS100: benchmark opted out)
        are always silent; anything else raises
        :class:`~repro.lint.WorkloadAuditError` in strict mode or is
        surfaced as :class:`~repro.lint.WorkloadAuditWarning`s otherwise.
        Audit *machinery* failures never abort a warn-mode run."""
        import warnings

        from repro.lint import (WorkloadAuditError, WorkloadAuditWarning,
                                audit_benchmark)
        try:
            config = next(iter(self.space.configs()))
        except StopIteration:
            return   # empty space: tune() will produce an empty result
        try:
            findings = [f for f in audit_benchmark(benchmark, config)
                        if f.severity != "info"]
        except Exception as e:
            if strict:
                raise
            warnings.warn(f"workload audit could not run: "
                          f"{type(e).__name__}: {e}",
                          WorkloadAuditWarning, stacklevel=3)
            return
        if not findings:
            return
        if strict:
            raise WorkloadAuditError(findings)
        for f in findings:
            warnings.warn(f.render(), WorkloadAuditWarning, stacklevel=3)

    def _project_seeds(self, seeds: Sequence[Config]) -> tuple[Config, ...]:
        """Map transfer seeds into this space (nearest in-space config),
        dropping duplicates and constraint-violating projections."""
        from .cache import config_key
        out: list[Config] = []
        seen: set[str] = set()
        for cfg in seeds:
            proj = self.space.project(cfg)
            if proj is None:
                continue
            key = config_key(proj)
            if key not in seen:
                seen.add(key)
                out.append(proj)
        return tuple(out)


def compare_techniques(space: SearchSpace, benchmark: BenchmarkFactory,
                       base: EvaluationSettings,
                       techniques: Optional[dict[str, tuple[
                           EvaluationSettings,
                           "str | SearchStrategy"]]] = None,
                       backend: Optional[ExecutionBackend] = None,
                       cache=None, warm_start: bool = False,
                       cache_prefix: str = "technique",
                       ) -> dict[str, TuningResult]:
    """Run the paper's technique grid (Default / C / C+I / C+I+O, +-R) on one
    benchmark and return the per-technique :class:`TuningResult`s.

    This is the engine behind the Tables VIII-XI reproduction. ``backend``
    schedules every technique's evaluations (so the grid can run on the
    thread/process pools); ``cache`` is an *unbound*
    :class:`~repro.core.cache.TrialCache` — each technique gets its own
    benchmark namespace (``<cache_prefix>:<label>``) so the grid is
    resumable without cross-technique contamination, and ``warm_start``
    seeds each technique's incumbent from its own cached best.

    A technique row is ``(settings, order)`` where ``order`` is either a
    visit-order string for the exhaustive strategy (the paper's rows) or
    a :class:`~repro.core.strategy.SearchStrategy` instance — so the grid
    can pit the paper's techniques against e.g. a model-guided
    ``SurrogateStrategy`` row under identical evaluation settings.
    """
    if techniques is None:
        techniques = standard_techniques(base)
    out: dict[str, TuningResult] = {}
    for label, (settings, order) in techniques.items():
        bound = cache.bound(f"{cache_prefix}:{label}") \
            if cache is not None else None
        tuner = Tuner(space, settings, order=order) if isinstance(order, str) \
            else Tuner(space, settings, strategy=order)
        out[label] = tuner.tune(
            benchmark, backend=backend, cache=bound, warm_start=warm_start)
    return out


def tune_successive_halving(space: SearchSpace, benchmark: BenchmarkFactory,
                            base: EvaluationSettings, eta: int = 3,
                            min_iterations: int = 4,
                            clock: Callable[[], float] = time.perf_counter,
                            ) -> TuningResult:
    """Successive halving with CI-informed promotion (beyond-paper,
    DESIGN.md §8.3).

    Compatibility wrapper: the loop now lives in
    :class:`~repro.core.strategy.SuccessiveHalvingStrategy`, which runs
    through the same engine as every other strategy — prefer
    ``Tuner(space, base, strategy=SuccessiveHalvingStrategy(...))``, which
    adds backend/cache/warm-start support this wrapper predates.
    """
    strategy = SuccessiveHalvingStrategy(eta=eta,
                                         min_iterations=min_iterations)
    result = Tuner(space, base, strategy=strategy, clock=clock).tune(benchmark)
    return dataclasses.replace(result, settings_label="SuccessiveHalving",
                               order="exhaustive")


def standard_techniques(base: EvaluationSettings,
                        ) -> dict[str, tuple[EvaluationSettings, str]]:
    """The paper's Tables VIII-XI rows (minus hand-tuned rows, which are
    constructed by the benchmark harness since they need manual counts)."""

    def with_flags(**kw) -> EvaluationSettings:
        return dataclasses.replace(base, **kw)

    c = dict(use_ci_convergence=True)
    ci = dict(use_ci_convergence=True, use_inner_prune=True)
    cio = dict(use_ci_convergence=True, use_inner_prune=True,
               use_outer_prune=True)
    return {
        "Default": (with_flags(), "exhaustive"),
        "Single": (with_flags(max_invocations=1, max_iterations=1), "exhaustive"),
        "Confidence": (with_flags(**c), "exhaustive"),
        "C+Inner": (with_flags(**ci), "exhaustive"),
        "C+Inner+R": (with_flags(**ci), "reverse"),
        "C+I+Outer": (with_flags(**cio), "exhaustive"),
        "C+I+O+R": (with_flags(**cio), "reverse"),
    }

"""The autotuner: exhaustive search + CI-pruned evaluation (paper Fig. 2).

For every configuration in the (ordered) search space the tuner runs the
two-level :class:`~repro.core.evaluator.Evaluator`, passing the incumbent
best score so that stop condition 4 can prune doomed configurations early.
The paper's experiments (Tables VIII-XI) are exactly runs of this object
under different :class:`EvaluationSettings` flags and search orders.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

from .evaluator import (EvalResult, EvaluationSettings, Evaluator,
                        InvocationFactory)
from .executor import (ExecutionBackend, ExecutionStats, IncumbentCell,
                       SerialBackend)
from .searchspace import Config, SearchSpace
from .stop_conditions import Direction

__all__ = ["BenchmarkFactory", "TrialRecord", "Tuner", "TuningResult",
           "compare_techniques", "standard_techniques",
           "tune_successive_halving"]

# A benchmark binds a configuration to a per-invocation sampler factory.
BenchmarkFactory = Callable[[Config], InvocationFactory]


@dataclasses.dataclass(frozen=True)
class TrialRecord:
    config: Config
    result: EvalResult
    cached: bool = False      # served from a TrialCache, not re-evaluated
    worker: int = 0           # backend worker that ran it


@dataclasses.dataclass(frozen=True)
class TuningResult:
    best_config: Optional[Config]
    best_score: Optional[float]
    trials: tuple[TrialRecord, ...]
    total_time_s: float
    total_samples: int
    n_pruned: int
    settings_label: str
    order: str
    # execution-backend accounting (serial defaults keep old pickles/tests)
    backend: str = "serial"
    n_workers: int = 1
    serial_time_s: float = 0.0     # sum of per-trial wall clock
    parallel_time_s: float = 0.0   # run wall clock (simulated: max/worker)
    n_cached: int = 0              # trials served from the cache
    # incumbent trajectory: every accepted (config, score) in acceptance
    # order; entry 0 is the warm-start seed when a cache seeded the cell
    improvements: tuple[tuple[Optional[Config], float], ...] = ()

    def summary_row(self) -> dict:
        return {
            "technique": self.settings_label + ("+R" if self.order == "reverse" else ""),
            "best_score": self.best_score,
            "best_config": self.best_config,
            "time_s": round(self.total_time_s, 4),
            "samples": self.total_samples,
            "pruned": self.n_pruned,
            "trials": len(self.trials),
        }


class Tuner:
    """Exhaustive/reversed/random-order autotuner with incumbent pruning."""

    def __init__(self, space: SearchSpace, settings: EvaluationSettings,
                 order: str = "exhaustive", seed: Optional[int] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.space = space
        self.settings = settings
        self.order = order
        self.seed = seed
        self.clock = clock

    def tune(self, benchmark: BenchmarkFactory,
             progress: Optional[Callable[[Config, EvalResult], None]] = None,
             backend: Optional[ExecutionBackend] = None,
             cache=None, warm_start: bool = False) -> TuningResult:
        """Search the space for the best configuration.

        ``backend`` schedules the evaluations (default
        :class:`~repro.core.executor.SerialBackend`, the paper's loop);
        ``cache`` is a :class:`~repro.core.cache.BoundCache` — configs
        already in it are served without re-evaluation and fresh results
        are appended; ``warm_start`` additionally seeds the incumbent from
        the best cached trial so pruning bites from trial 1.
        """
        if backend is None:
            backend = SerialBackend(clock=self.clock)
        evaluator = Evaluator(self.settings, clock=self.clock)
        direction = self.settings.direction
        cell = IncumbentCell(direction)
        if cache is not None and warm_start:
            seed = cache.best(direction)
            if seed is not None:
                cell.offer(seed[0], seed[1])
        hits: set[int] = set()
        hits_lock = threading.Lock()

        def evaluate(cfg: Config, incumbent) -> EvalResult:
            if cache is not None:
                hit = cache.get(cfg)
                if hit is not None:
                    with hits_lock:
                        hits.add(id(cfg))
                    return hit
            res = evaluator.evaluate(benchmark(cfg), incumbent=incumbent)
            if cache is not None:
                cache.put(cfg, res)
            return res

        t0 = self.clock()
        configs = self.space.ordered(self.order, seed=self.seed)
        outcomes, stats = backend.run(configs, evaluate, cell,
                                      progress=progress)
        best_cfg, best_score = cell.snapshot()
        trials = tuple(
            TrialRecord(config=o.config, result=o.result,
                        cached=id(o.config) in hits, worker=o.worker)
            for o in outcomes)
        return TuningResult(
            best_config=best_cfg,
            best_score=best_score,
            trials=trials,
            total_time_s=self.clock() - t0,
            total_samples=sum(t.result.total_samples for t in trials),
            n_pruned=sum(1 for t in trials if t.result.pruned),
            settings_label=self.settings.label(),
            order=self.order,
            backend=stats.backend,
            n_workers=stats.n_workers,
            serial_time_s=stats.serial_time_s,
            parallel_time_s=stats.parallel_time_s,
            n_cached=sum(1 for t in trials if t.cached),
            improvements=cell.history(),
        )


def compare_techniques(space: SearchSpace, benchmark: BenchmarkFactory,
                       base: EvaluationSettings,
                       techniques: Optional[dict[str, tuple[EvaluationSettings, str]]] = None,
                       ) -> dict[str, TuningResult]:
    """Run the paper's technique grid (Default / C / C+I / C+I+O, +-R) on one
    benchmark and return the per-technique :class:`TuningResult`s.

    This is the engine behind the Tables VIII-XI reproduction.
    """
    if techniques is None:
        techniques = standard_techniques(base)
    out: dict[str, TuningResult] = {}
    for label, (settings, order) in techniques.items():
        out[label] = Tuner(space, settings, order=order).tune(benchmark)
    return out


def tune_successive_halving(space: SearchSpace, benchmark: BenchmarkFactory,
                            base: EvaluationSettings, eta: int = 3,
                            min_iterations: int = 4,
                            clock: Callable[[], float] = time.perf_counter,
                            ) -> TuningResult:
    """Successive halving with CI-informed promotion (beyond-paper,
    DESIGN.md §8.3).

    Rung r evaluates the survivors with an iteration budget that grows by
    ``eta`` per rung; only the top 1/eta (by CI-aware comparison: a config
    survives if its CI upper bound reaches the cutoff score) advance. The
    same stop conditions apply inside each rung, so condition 4 still
    prunes doomed configs early within a rung.
    """
    from .confidence import ci_mean
    from .welford import WelfordState

    direction = base.direction
    configs = space.ordered("exhaustive")
    trials: list[TrialRecord] = []
    t0 = clock()
    total_samples = 0
    budget = min_iterations
    rung_settings = dataclasses.replace(
        base, max_invocations=1, max_iterations=budget)
    best_cfg: Optional[Config] = None
    best_score: Optional[float] = None
    survivors = configs
    while survivors:
        evaluator = Evaluator(rung_settings, clock=clock)
        scored = []
        for cfg in survivors:
            res = evaluator.evaluate(benchmark(cfg), incumbent=best_score)
            trials.append(TrialRecord(config=cfg, result=res))
            total_samples += res.total_samples
            if not res.pruned:
                scored.append((cfg, res))
                if best_score is None or direction.better(res.score,
                                                          best_score):
                    best_score, best_cfg = res.score, cfg
        if len(scored) <= 1:
            break
        scored.sort(key=lambda cr: cr[1].score,
                    reverse=(direction is Direction.MAXIMIZE))
        keep = max(1, len(scored) // eta)
        cutoff = scored[keep - 1][1].score
        kept = []
        for cfg, res in scored:
            # CI-aware promotion: survive if the CI bound facing the cutoff
            # still reaches it (the paper's Listing-1 logic as a promoter)
            state = WelfordState(count=float(res.total_samples),
                                 mean=res.score,
                                 m2=sum(i.m2 for i in res.invocations))
            interval = ci_mean(state, base.confidence)
            bound = interval.hi if direction is Direction.MAXIMIZE \
                else interval.lo
            if direction.better(bound, cutoff) or bound == cutoff or \
                    res.score == cutoff or direction.better(res.score,
                                                            cutoff):
                kept.append(cfg)
        survivors = kept[:max(1, len(scored) // eta)] \
            if len(kept) > len(scored) // eta else kept
        if len(survivors) == 1:
            break
        budget *= eta
        rung_settings = dataclasses.replace(rung_settings,
                                            max_iterations=budget)
    return TuningResult(
        best_config=best_cfg, best_score=best_score, trials=tuple(trials),
        total_time_s=clock() - t0, total_samples=total_samples,
        n_pruned=sum(1 for t in trials if t.result.pruned),
        settings_label="SuccessiveHalving", order="exhaustive")


def standard_techniques(base: EvaluationSettings,
                        ) -> dict[str, tuple[EvaluationSettings, str]]:
    """The paper's Tables VIII-XI rows (minus hand-tuned rows, which are
    constructed by the benchmark harness since they need manual counts)."""

    def with_flags(**kw) -> EvaluationSettings:
        return dataclasses.replace(base, **kw)

    c = dict(use_ci_convergence=True)
    ci = dict(use_ci_convergence=True, use_inner_prune=True)
    cio = dict(use_ci_convergence=True, use_inner_prune=True,
               use_outer_prune=True)
    return {
        "Default": (with_flags(), "exhaustive"),
        "Single": (with_flags(max_invocations=1, max_iterations=1), "exhaustive"),
        "Confidence": (with_flags(**c), "exhaustive"),
        "C+Inner": (with_flags(**ci), "exhaustive"),
        "C+Inner+R": (with_flags(**ci), "reverse"),
        "C+I+Outer": (with_flags(**cio), "exhaustive"),
        "C+I+O+R": (with_flags(**cio), "reverse"),
    }

"""Persistent trial cache + resumable tuning sessions.

Real kernel-tuner infrastructure never throws trial data away: a search
interrupted at config 40/96 should restart at 41, and a nightly re-tune on
identical hardware should reuse yesterday's measurements outright (cf.
*Towards a Benchmarking Suite for Kernel Tuners*, arXiv:2303.08976). This
module provides:

  * :func:`hardware_fingerprint` — identifies the measurement substrate
    (platform, device kinds/count, jax version). Trials recorded under a
    different fingerprint are ignored on load: timings do not transfer
    across hardware.
  * :class:`TrialCache` — an append-only JSONL store keyed by
    (benchmark name, canonical config). Each record round-trips the full
    :class:`~repro.core.evaluator.EvalResult`, including every
    invocation's Welford moments (count/mean/m2) *exactly* — JSON float
    serialization uses ``repr`` so float64 survives bit-for-bit — which
    keeps downstream parallel Welford merges exact across a resume.
  * :class:`BoundCache` — a :class:`TrialCache` view fixed to one
    benchmark name, the shape ``Tuner.tune(cache=...)`` consumes.
  * :class:`TuningSession` — a named run/resume wrapper: restarting a
    killed session skips every already-evaluated config and warm-starts
    the incumbent from the best cached trial so stop-condition-4 pruning
    bites from trial 1.
  * a read/query layer for reporting: :class:`CachedTrial`,
    :func:`iter_trials` and :func:`load_trials` read cache files across
    *all* hardware fingerprints (unlike :class:`TrialCache`, which serves
    only its own fingerprint), so downstream consumers — notably
    :mod:`repro.core.report` — can group sessions by benchmark ×
    fingerprint and assemble roofline dashboards without re-measuring.

The on-disk format is specified in ``docs/cache-format.md``
(``CACHE_VERSION`` gates compatibility).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
from pathlib import Path
from typing import Iterable, Iterator, Optional

from repro.obs.metrics import metrics as obs_metrics

from .confidence import spearman
from .evaluator import EvalResult, InvocationResult
from .searchspace import Config
from .stop_conditions import Direction

__all__ = ["AUTO_LEDGER", "BoundCache", "CACHE_VERSION", "CachedTrial",
           "TrialCache", "TuningSession", "config_key",
           "hardware_fingerprint", "iter_trials", "load_trials",
           "settings_key"]

CACHE_VERSION = 1

_FINGERPRINT: Optional[str] = None


def hardware_fingerprint() -> str:
    """Stable id of this measurement substrate, cached per process.

    .. warning:: Computed lazily because the first call touches
       ``jax.devices()``, which **initializes the jax backend** as a side
       effect. Call it only after any platform selection
       (``JAX_PLATFORMS``, ``jax.config.update``) has happened, and never
       at import time — once the backend is up, platform flags are
       ignored.
    """
    global _FINGERPRINT
    if _FINGERPRINT is None:
        import jax
        kinds = sorted({d.device_kind for d in jax.devices()})
        _FINGERPRINT = (f"{jax.default_backend()}:{','.join(kinds)}"
                        f":n{jax.device_count()}:jax-{jax.__version__}")
    return _FINGERPRINT


def config_key(config: Config) -> str:
    """Canonical JSON key of a configuration (order-insensitive)."""
    return json.dumps(config, sort_keys=True, separators=(",", ":"),
                      default=str)


def settings_key(settings) -> str:
    """Short stable fingerprint of an :class:`EvaluationSettings`.

    A trial is only as good as the budget it was measured under: a
    successive-halving rung evaluated at ``max_iterations=4`` must never
    be served back as a full-budget result. Records carry this key so
    cache reads can demand settings parity; records written before the
    key existed (or by hand) have none and match any request.
    """
    d = dataclasses.asdict(settings)
    blob = json.dumps(d, sort_keys=True, default=str)
    return hashlib.sha1(blob.encode("utf-8")).hexdigest()[:12]


def _result_to_json(result: EvalResult) -> dict:
    return {
        "score": result.score,
        "best_invocation": result.best_invocation,
        "total_samples": result.total_samples,
        "total_time_s": result.total_time_s,
        "measured_time_s": result.measured_time_s,
        "pruned": result.pruned,
        "stop_reason": result.stop_reason,
        "invocations": [
            {"mean": i.mean, "count": i.count, "elapsed_s": i.elapsed_s,
             "stop_reason": i.stop_reason, "pruned": i.pruned, "m2": i.m2}
            for i in result.invocations],
    }


def _result_from_json(d: dict) -> EvalResult:
    return EvalResult(
        score=d["score"],
        best_invocation=d["best_invocation"],
        invocations=tuple(InvocationResult(**inv)
                          for inv in d["invocations"]),
        total_samples=d["total_samples"],
        total_time_s=d["total_time_s"],
        measured_time_s=d["measured_time_s"],
        pruned=d["pruned"],
        stop_reason=d["stop_reason"])


@dataclasses.dataclass(frozen=True)
class CachedTrial:
    """One persisted trial, as the reporting layer sees it: unlike the
    entries :class:`TrialCache` serves back to the tuner, a CachedTrial
    carries its hardware fingerprint so trials from many machines can
    coexist in one analysis, plus the name of the search strategy that
    produced it (``None`` for records predating the strategy layer)."""

    benchmark: str
    fingerprint: str
    config: Config
    result: EvalResult
    strategy: Optional[str] = None

    @property
    def key(self) -> str:
        return config_key(self.config)


def iter_trials(path: str | os.PathLike) -> Iterator[CachedTrial]:
    """Yield every readable trial in a cache file, across *all* hardware
    fingerprints (``TrialCache`` filters to one; reports want them all).

    Tolerates a torn trailing line and skips records whose
    ``CACHE_VERSION`` does not match. Records are yielded in file order,
    so re-evaluated configs appear more than once — last one wins; use
    :func:`load_trials` for the deduplicated view.
    """
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue   # torn trailing write from a killed run
            if rec.get("version") != CACHE_VERSION:
                continue
            yield CachedTrial(benchmark=rec["benchmark"],
                              fingerprint=rec["fingerprint"],
                              config=rec["config"],
                              result=_result_from_json(rec["result"]),
                              strategy=rec.get("strategy"))


def load_trials(path: str | os.PathLike) -> list[CachedTrial]:
    """Load the deduplicated trials of a cache file *or* of every
    ``*.jsonl`` under a directory of session caches.

    Duplicate (benchmark, fingerprint, config) records keep the last
    occurrence — the same resolution :class:`TrialCache` applies on load —
    while preserving first-seen order, so incumbent extraction downstream
    breaks score ties exactly like ``TrialCache.best``.
    """
    p = Path(path)
    files: Iterable[Path] = sorted(p.glob("*.jsonl")) if p.is_dir() else (p,)
    dedup: dict[tuple[str, str, str], CachedTrial] = {}
    for f in files:
        for t in iter_trials(f):
            dedup[(t.benchmark, t.fingerprint, t.key)] = t
    return list(dedup.values())


class TrialCache:
    """Append-only JSONL store of evaluated trials.

    Thread-safe: concurrent backends write through one lock, and every
    record is flushed as a single line so a killed process loses at most
    the trial in flight (a torn trailing line is tolerated on load).
    """

    def __init__(self, path: str | os.PathLike,
                 fingerprint: Optional[str] = None):
        self.path = Path(path)
        self.fingerprint = fingerprint or hardware_fingerprint()
        self._lock = threading.Lock()
        # settings-keyed store: records measured under different
        # EvaluationSettings coexist — a halving rung's truncated trial
        # never shadows (or is shadowed by) a full-budget record of the
        # same config.
        # (benchmark, config_key, settings_key-or-None) ->
        #     (config, EvalResult, strategy-or-None)
        self._entries: dict[
            tuple[str, str, Optional[str]],
            tuple[Config, EvalResult, Optional[str]]] = {}
        # wildcard view: last write per (benchmark, config_key), first-seen
        # position preserved — the pre-settings-key lookup semantics
        self._latest: dict[
            tuple[str, str],
            tuple[Config, EvalResult, Optional[str], Optional[str]]] = {}
        self.n_stale = 0   # records skipped on load (other hardware/version)
        if self.path.exists():
            self._load()

    def _load(self) -> None:
        with open(self.path, encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue   # torn trailing write from a killed run
                if (rec.get("version") != CACHE_VERSION
                        or rec.get("fingerprint") != self.fingerprint):
                    self.n_stale += 1
                    continue
                bench, ckey = rec["benchmark"], config_key(rec["config"])
                skey = rec.get("settings_key")
                entry = (rec["config"], _result_from_json(rec["result"]),
                         rec.get("strategy"))
                self._entries[(bench, ckey, skey)] = entry
                self._latest[(bench, ckey)] = entry + (skey,)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    # -- query layer (reporting) ----------------------------------------------
    def benchmarks(self, prefix: Optional[str] = None) -> list[str]:
        """Benchmark names with at least one cached trial, sorted. With
        ``prefix``, only names starting with it — how the sweep layer
        finds every per-shape benchmark of one campaign
        (``"<base>@" + shape_key``, see :mod:`repro.sweep.shapes`)."""
        with self._lock:
            return sorted({bench for bench, _ in self._latest
                           if prefix is None or bench.startswith(prefix)})

    def items(self, benchmark: Optional[str] = None,
              ) -> list[tuple[str, Config, EvalResult]]:
        """Snapshot of cached trials as (benchmark, config, result) tuples
        — the latest record per config, in first-seen order, optionally
        restricted to one benchmark."""
        with self._lock:
            return [(bench, cfg, res)
                    for (bench, _), (cfg, res, *_meta)
                    in self._latest.items()
                    if benchmark is None or bench == benchmark]

    def trials(self) -> list[CachedTrial]:
        """This cache's entries as :class:`CachedTrial`s — latest record
        per config, all stamped with the cache's own fingerprint
        (stale-fingerprint records were dropped on load; use
        :func:`load_trials` to see every machine)."""
        with self._lock:
            return [CachedTrial(benchmark=bench, fingerprint=self.fingerprint,
                                config=cfg, result=res, strategy=strat)
                    for (bench, _), (cfg, res, strat, _skey)
                    in self._latest.items()]

    def get(self, benchmark: str, config: Config,
            settings_key: Optional[str] = None) -> Optional[EvalResult]:
        """Cached result for a config. With ``settings_key``, only a
        record measured under those settings (or a legacy record with no
        key) satisfies the read — a halving rung's truncated trial never
        passes for a full-budget one. Without it, the latest record per
        config wins (the pre-settings-key semantics)."""
        ckey = config_key(config)
        with self._lock:
            if settings_key is not None:
                hit = self._entries.get((benchmark, ckey, settings_key)) \
                    or self._entries.get((benchmark, ckey, None))
                return hit[1] if hit is not None else None
            hit = self._latest.get((benchmark, ckey))
            return hit[1] if hit is not None else None

    def put(self, benchmark: str, config: Config, result: EvalResult,
            strategy: Optional[str] = None,
            settings_key: Optional[str] = None) -> None:
        rec = {"version": CACHE_VERSION, "fingerprint": self.fingerprint,
               "benchmark": benchmark, "config": config,
               "result": _result_to_json(result)}
        if strategy is not None:
            rec["strategy"] = strategy
        if settings_key is not None:
            rec["settings_key"] = settings_key
        line = json.dumps(rec, default=str)
        ckey = config_key(config)
        entry = (config, result, strategy)
        # the threading lock serializes writers in this process; the
        # advisory flock serializes them across processes — parallel
        # sessions (or a session racing a report) share one cache file,
        # and interleaved buffered appends would tear both records
        try:
            import fcntl
        except ImportError:              # pragma: no cover - non-POSIX
            fcntl = None
        with self._lock:
            self._entries[(benchmark, ckey, settings_key)] = entry
            self._latest[(benchmark, ckey)] = entry + (settings_key,)
            self.path.parent.mkdir(parents=True, exist_ok=True)
            with open(self.path, "a", encoding="utf-8") as f:
                if fcntl is not None:
                    fcntl.flock(f.fileno(), fcntl.LOCK_EX)
                try:
                    f.write(line + "\n")
                    f.flush()
                finally:
                    if fcntl is not None:
                        fcntl.flock(f.fileno(), fcntl.LOCK_UN)
        reg = obs_metrics()
        reg.inc("cache.appends")
        reg.inc("cache.bytes_written", len(line) + 1)

    def best(self, benchmark: str, direction: Direction,
             settings_key: Optional[str] = None,
             ) -> Optional[tuple[Config, float]]:
        """Best non-pruned cached (config, score) for warm-starting the
        incumbent. Pruned trials carry truncated estimates and never seed,
        and with ``settings_key`` neither do trials measured under other
        settings (e.g. a halving rung's reduced budget) — legacy records
        without a key still qualify."""
        with self._lock:
            if settings_key is not None:
                pool = [(cfg, res)
                        for (bench, _, skey), (cfg, res, _strat)
                        in self._entries.items()
                        if bench == benchmark
                        and skey in (None, settings_key)]
            else:
                pool = [(cfg, res)
                        for (bench, _), (cfg, res, *_meta)
                        in self._latest.items() if bench == benchmark]
            best: Optional[tuple[Config, float]] = None
            for cfg, res in pool:
                if res.pruned:
                    continue
                if best is None or direction.better(res.score, best[1]):
                    best = (cfg, res.score)
            return best

    def suggest_seeds(self, benchmark: str,
                      fingerprint: Optional[str] = None,
                      direction: Direction = Direction.MAXIMIZE,
                      limit: int = 3) -> list[Config]:
        """Transfer-tuning warm-start seeds: the best unpruned cached
        configurations of ``benchmark``, best first.

        With ``fingerprint=None`` (or this cache's own) the in-memory
        entries answer first; when they fill fewer than ``limit`` seeds,
        *donor* fingerprints found in the cache file top the list up —
        ranked by :meth:`rank_donors` (Spearman rank-correlation of
        shared-config scores against this machine, recency fallback), so
        machines that rank configurations the way this one does get their
        incumbents trusted first. An explicit foreign ``fingerprint``
        reads that single donor, since :class:`TrialCache` drops foreign
        records on load. Timings never transfer across hardware — but
        *configurations* are still informative starting points, which is
        all a seed is. Feed the result to ``Tuner.tune(seeds=...)``
        (configs are projected into the target space there).
        """
        if fingerprint is not None and fingerprint != self.fingerprint:
            pool = list(self._donor_pool(benchmark, fingerprint).values())
            pool.sort(key=lambda cr: cr[1].score,
                      reverse=(direction is Direction.MAXIMIZE))
            return [cfg for cfg, _ in pool[:max(0, limit)]]
        with self._lock:
            pool = [(cfg, res) for (bench, _), (cfg, res, *_meta)
                    in self._latest.items()
                    if bench == benchmark and not res.pruned]
        pool.sort(key=lambda cr: cr[1].score,
                  reverse=(direction is Direction.MAXIMIZE))
        seeds = [cfg for cfg, _ in pool[:max(0, limit)]]
        if len(seeds) >= limit:
            return seeds
        # top up from donor fingerprints: one file scan serves both the
        # ranking and the per-donor candidate pools
        pools, last_seen = self._donor_scan(benchmark)
        seen = {config_key(cfg) for cfg in seeds}
        for donor_fp, _rho in self._rank_donors(benchmark, pools, last_seen):
            donor = list(pools[donor_fp].values())
            donor.sort(key=lambda cr: cr[1].score,
                       reverse=(direction is Direction.MAXIMIZE))
            for cfg, _ in donor:
                key = config_key(cfg)
                if key in seen:
                    continue
                seen.add(key)
                seeds.append(cfg)
                if len(seeds) >= limit:
                    return seeds
        return seeds

    def _donor_pool(self, benchmark: str, fingerprint: str,
                    ) -> dict[str, tuple[Config, EvalResult]]:
        """Latest unpruned record per config of one foreign fingerprint,
        re-read from the cache file (foreign records are dropped on load)."""
        if not self.path.exists():
            return {}
        dedup: dict[str, tuple[Config, EvalResult]] = {}
        for t in iter_trials(self.path):
            if t.benchmark == benchmark and t.fingerprint == fingerprint \
                    and not t.result.pruned:
                dedup[t.key] = (t.config, t.result)
        return dedup

    def _donor_scan(self, benchmark: str,
                    ) -> tuple[dict[str, dict[str, tuple[Config, EvalResult]]],
                               dict[str, int]]:
        """Single pass over the cache file: every foreign fingerprint's
        latest unpruned record per config, plus each donor's last write
        position (the recency-ranking key)."""
        pools: dict[str, dict[str, tuple[Config, EvalResult]]] = {}
        last_seen: dict[str, int] = {}
        if not self.path.exists():
            return pools, last_seen
        for pos, t in enumerate(iter_trials(self.path)):
            if t.benchmark != benchmark or t.fingerprint == self.fingerprint \
                    or t.result.pruned:
                continue
            pools.setdefault(t.fingerprint, {})[t.key] = (t.config, t.result)
            last_seen[t.fingerprint] = pos
        return pools, last_seen

    def _rank_donors(self, benchmark: str,
                     pools: dict[str, dict[str, tuple[Config, EvalResult]]],
                     last_seen: dict[str, int],
                     min_overlap: int = 3,
                     ) -> list[tuple[str, Optional[float]]]:
        with self._lock:
            own = {ckey: res.score
                   for (bench, ckey), (_cfg, res, *_meta)
                   in self._latest.items()
                   if bench == benchmark and not res.pruned}
        correlated: list[tuple[str, float]] = []
        uncorrelated: list[str] = []
        for fp, entries in pools.items():
            shared = sorted(set(entries) & set(own))
            rho = (spearman([own[k] for k in shared],
                            [entries[k][1].score for k in shared])
                   if len(shared) >= min_overlap else None)
            if rho is None:
                uncorrelated.append(fp)
            else:
                correlated.append((fp, rho))
        correlated.sort(key=lambda fr: (-fr[1], -last_seen[fr[0]]))
        uncorrelated.sort(key=lambda fp: -last_seen[fp])
        return correlated + [(fp, None) for fp in uncorrelated]

    def rank_donors(self, benchmark: str,
                    min_overlap: int = 3,
                    ) -> list[tuple[str, Optional[float]]]:
        """Donor fingerprints for transfer seeding, most trustworthy first.

        A donor whose scores **rank** the shared configurations the same
        way this machine's do is likely to rank the unshared ones
        similarly too — so donors are ordered by Spearman rank-correlation
        of shared-config scores (descending), computed when at least
        ``min_overlap`` configs overlap with this fingerprint's own
        records. Donors below the overlap threshold (including every donor
        when this machine has no trials yet) keep the recency fallback:
        most recently written first. Returns ``(fingerprint, rho)`` pairs,
        ``rho=None`` for the recency-ordered tail.
        """
        pools, last_seen = self._donor_scan(benchmark)
        return self._rank_donors(benchmark, pools, last_seen,
                                 min_overlap=min_overlap)

    def bound(self, benchmark: str) -> "BoundCache":
        return BoundCache(self, benchmark)


class BoundCache:
    """A :class:`TrialCache` view fixed to one benchmark name — the shape
    ``Tuner.tune(cache=...)`` consumes."""

    def __init__(self, cache: TrialCache, benchmark: str):
        self.cache = cache
        self.benchmark = benchmark

    def get(self, config: Config,
            settings_key: Optional[str] = None) -> Optional[EvalResult]:
        return self.cache.get(self.benchmark, config,
                              settings_key=settings_key)

    def put(self, config: Config, result: EvalResult,
            strategy: Optional[str] = None,
            settings_key: Optional[str] = None) -> None:
        self.cache.put(self.benchmark, config, result, strategy=strategy,
                       settings_key=settings_key)

    def best(self, direction: Direction,
             settings_key: Optional[str] = None,
             ) -> Optional[tuple[Config, float]]:
        return self.cache.best(self.benchmark, direction,
                               settings_key=settings_key)

    def suggest_seeds(self, direction: Direction = Direction.MAXIMIZE,
                      limit: int = 3) -> list[Config]:
        return self.cache.suggest_seeds(self.benchmark, direction=direction,
                                        limit=limit)


#: Default sentinel for ``TuningSession(ledger=...)``: create/append the
#: shared run ledger next to the session caches (``<cache_dir>/history.jsonl``).
AUTO_LEDGER = object()


class TuningSession:
    """A named, resumable tuning run.

    ``run()`` executes the wrapped tuner with the session's cache: configs
    already on disk are served from the cache (no re-evaluation), fresh
    evaluations append as they finish, and the incumbent warm-starts from
    the best cached trial. Kill the process at any point and ``run()``
    again — it completes the remaining configs only.

    Every completed ``run()`` also appends one record to the
    performance-history **run ledger** (``<cache_dir>/history.jsonl`` by
    default — a shared longitudinal file, unlike the per-session trial
    caches), so drift across runs of the same benchmark × fingerprint is
    detectable later (``repro.history``, ``scripts/perf_gate.py``). Pass
    ``ledger=None`` to disable, or a :class:`~repro.history.ledger.RunLedger`
    (or path) to redirect.

    ``trace=True`` records a span trace of every ``run()`` to
    ``<cache_dir>/<name>.trace.jsonl`` (a path redirects it); the result
    carries it as ``TuningResult.trace_path``. When a recorder is
    already installed (an enclosing campaign or test owns it), the
    session joins that trace instead of opening its own.
    """

    def __init__(self, name: str, tuner, benchmark,
                 cache_dir: str | os.PathLike = ".tuning_sessions",
                 warm_start: bool = True,
                 fingerprint: Optional[str] = None,
                 benchmark_name: Optional[str] = None,
                 ledger=AUTO_LEDGER,
                 campaign: Optional[str] = None,
                 trace: "bool | str | os.PathLike" = False):
        self.name = name
        self.tuner = tuner
        self.benchmark = benchmark
        # distinct cache namespace per objective: a session file reused with
        # a different benchmark must not warm-start across metrics
        self.benchmark_name = benchmark_name or name
        # sweep campaigns stamp their name on every ledger record so one
        # grid-tuning pass is recognizable as a unit in history tooling
        self.campaign = campaign
        self.warm_start = warm_start
        self.trace = trace
        self.trace_path: Optional[Path] = None
        if trace:
            self.trace_path = (Path(cache_dir) / f"{name}.trace.jsonl"
                               if trace is True else Path(trace))
        self.cache = TrialCache(Path(cache_dir) / f"{name}.jsonl",
                                fingerprint=fingerprint)
        if ledger is AUTO_LEDGER or isinstance(ledger, (str, os.PathLike)):
            # deferred import: repro.history depends on repro.core
            from repro.history.ledger import RunLedger
            path = (Path(cache_dir) / "history.jsonl"
                    if ledger is AUTO_LEDGER else ledger)
            ledger = RunLedger(path)
        self.ledger = ledger

    def run(self, backend=None, progress=None, seeds=(), timestamp=None,
            validate: str = "warn"):
        """Execute the wrapped tuner against the session cache. ``seeds``
        are transfer-tuning warm-start configs (see
        ``TrialCache.suggest_seeds``), forwarded to ``Tuner.tune``.
        ``timestamp`` (caller-supplied epoch seconds — core never reads a
        clock for records) stamps the ledger entry this run appends.
        ``validate`` gates the pre-run workload audit exactly as in
        ``Tuner.tune`` — strict mode fails the session before any trial
        (or cache read) happens."""
        import contextlib

        bound_ledger = None
        if self.ledger is not None:
            bound_ledger = self.ledger.bound(self.benchmark_name,
                                             self.cache.fingerprint,
                                             session=self.name,
                                             campaign=self.campaign)
        with contextlib.ExitStack() as stack:
            if self.trace_path is not None:
                # deferred import + already-active check: an enclosing
                # campaign/test recorder wins, the session joins its trace
                from repro.obs.trace import TraceRecorder, recorder
                if recorder() is None:
                    stack.enter_context(TraceRecorder(self.trace_path,
                                                      session=self.name))
            return self.tuner.tune(self.benchmark, progress=progress,
                                   backend=backend,
                                   cache=self.cache.bound(
                                       self.benchmark_name),
                                   warm_start=self.warm_start,
                                   seeds=seeds, ledger=bound_ledger,
                                   timestamp=timestamp, validate=validate)

"""Confidence intervals for benchmark sample streams.

The paper computes a normal-theory CI from the Welford moments after every
sample, and terminates the evaluation loop when the 99% CI is within +-1% of
the mean (stop condition 3), or when the CI upper bound drops below the
incumbent best (stop condition 4).

The paper notes (Sec. III-C.3) that benchmark runtimes are usually
*non-normal* and names bootstrapping as the ideal-but-too-expensive
alternative, leaving efficient online versions as future work (Sec. VII).
We implement that future work here:

  * normal CI        — the paper's default (n >= 30 rule of Georges et al.);
  * Student-t CI     — small-sample correction (exact under normality);
  * reservoir bootstrap CI — percentile bootstrap over a bounded reservoir,
    O(K) memory independent of stream length => "online" in the paper's sense;
  * median-of-means + sign-test CI — robust nonparametric location estimate.

No scipy available: the normal quantile uses Acklam's rational approximation
(|rel err| < 1.15e-9) and the t quantile inverts the incomplete-beta CDF by
bisection.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from .welford import WelfordState

# ---------------------------------------------------------------------------
# Quantiles (no scipy)
# ---------------------------------------------------------------------------


def normal_quantile(p: float) -> float:
    """Inverse standard-normal CDF, Acklam's algorithm."""
    if not 0.0 < p < 1.0:
        raise ValueError(f"p must be in (0,1), got {p}")
    a = (-3.969683028665376e01, 2.209460984245205e02, -2.759285104469687e02,
         1.383577518672690e02, -3.066479806614716e01, 2.506628277459239e00)
    b = (-5.447609879822406e01, 1.615858368580409e02, -1.556989798598866e02,
         6.680131188771972e01, -1.328068155288572e01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e00,
         -2.549732539343734e00, 4.374664141464968e00, 2.938163982698783e00)
    d = (7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e00,
         3.754408661907416e00)
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        q = math.sqrt(-2 * math.log(p))
        return (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / (
            (((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    if p > phigh:
        q = math.sqrt(-2 * math.log(1 - p))
        return -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / (
            (((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    q = p - 0.5
    r = q * q
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * q / (
        ((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)


def _betacf(a: float, b: float, x: float) -> float:
    """Continued fraction for the incomplete beta function (NR in C, 6.4)."""
    MAXIT, EPS, FPMIN = 200, 3e-12, 1e-300
    qab, qap, qam = a + b, a + 1.0, a - 1.0
    c = 1.0
    d = 1.0 - qab * x / qap
    if abs(d) < FPMIN:
        d = FPMIN
    d = 1.0 / d
    h = d
    for m in range(1, MAXIT + 1):
        m2 = 2 * m
        aa = m * (b - m) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        if abs(d) < FPMIN:
            d = FPMIN
        c = 1.0 + aa / c
        if abs(c) < FPMIN:
            c = FPMIN
        d = 1.0 / d
        h *= d * c
        aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        if abs(d) < FPMIN:
            d = FPMIN
        c = 1.0 + aa / c
        if abs(c) < FPMIN:
            c = FPMIN
        d = 1.0 / d
        delta = d * c
        h *= delta
        if abs(delta - 1.0) < EPS:
            break
    return h


def _betainc(a: float, b: float, x: float) -> float:
    """Regularized incomplete beta I_x(a, b)."""
    if x <= 0.0:
        return 0.0
    if x >= 1.0:
        return 1.0
    ln_bt = (math.lgamma(a + b) - math.lgamma(a) - math.lgamma(b)
             + a * math.log(x) + b * math.log(1.0 - x))
    bt = math.exp(ln_bt)
    if x < (a + 1.0) / (a + b + 2.0):
        return bt * _betacf(a, b, x) / a
    return 1.0 - bt * _betacf(b, a, 1.0 - x) / b


def t_cdf(t: float, df: float) -> float:
    """CDF of Student's t with ``df`` degrees of freedom."""
    if df <= 0:
        raise ValueError("df must be positive")
    x = df / (df + t * t)
    p = 0.5 * _betainc(df / 2.0, 0.5, x)
    return 1.0 - p if t > 0 else p


def t_quantile(p: float, df: float) -> float:
    """Inverse t CDF by bisection (robust, ~1e-10 accurate, fast enough
    because stop-condition checks cache the quantile per (p, df))."""
    if not 0.0 < p < 1.0:
        raise ValueError(f"p must be in (0,1), got {p}")
    if df <= 0:
        raise ValueError("df must be positive")
    if df > 1e6:
        return normal_quantile(p)
    if abs(p - 0.5) < 1e-15:
        return 0.0
    lo, hi = -1e3, 1e3
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if t_cdf(mid, df) < p:
            lo = mid
        else:
            hi = mid
        if hi - lo < 1e-12 * max(1.0, abs(lo)):
            break
    return 0.5 * (lo + hi)


_QUANTILE_CACHE: dict[tuple[float, float], float] = {}


def _critical_value(confidence: float, n: float, use_t: bool) -> float:
    p = 1.0 - (1.0 - confidence) / 2.0
    if use_t and n >= 2:
        key = (p, float(int(n)))
        if key not in _QUANTILE_CACHE:
            _QUANTILE_CACHE[key] = t_quantile(p, float(int(n)) - 1.0)
        return _QUANTILE_CACHE[key]
    key = (p, -1.0)
    if key not in _QUANTILE_CACHE:
        _QUANTILE_CACHE[key] = normal_quantile(p)
    return _QUANTILE_CACHE[key]


# ---------------------------------------------------------------------------
# Confidence interval of the mean (paper stop conditions 3 & 4)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Interval:
    lo: float
    hi: float
    mean: float

    @property
    def margin(self) -> float:
        """marg in the paper's Listing 1: half-width of the CI."""
        return 0.5 * (self.hi - self.lo)

    @property
    def relative_margin(self) -> float:
        """margin / |mean| — the paper terminates at 1% (stop condition 3)."""
        if self.mean == 0.0:
            return float("inf")
        return self.margin / abs(self.mean)


def ci_mean(state: WelfordState, confidence: float = 0.99,
            use_t: bool = True) -> Interval:
    """CI of the mean from Welford moments.

    The paper assumes normality (citing Georges et al.'s n>=30 rule); with
    ``use_t=True`` (default) we apply the Student-t small-sample correction,
    which converges to the paper's z interval as n grows.
    """
    n = float(state.count)
    mean = float(state.mean)
    if n < 2:
        return Interval(lo=-math.inf, hi=math.inf, mean=mean)
    crit = _critical_value(confidence, n, use_t)
    half = crit * float(state.sem)
    return Interval(lo=mean - half, hi=mean + half, mean=mean)


# ---------------------------------------------------------------------------
# Online (bounded-memory) bootstrap — the paper's Sec. VII future work
# ---------------------------------------------------------------------------


class ReservoirBootstrap:
    """Percentile-bootstrap CI over a uniform reservoir of the stream.

    The paper rejects bootstrapping because re-resampling the full history per
    iteration is too expensive. A reservoir of K samples is an unbiased
    uniform subsample of the stream, so bootstrapping the reservoir gives a
    bounded-cost online approximation: O(K) memory, O(B*K) per query (queries
    are issued only when a stop condition is actually evaluated).
    """

    def __init__(self, capacity: int = 256, resamples: int = 200, seed: int = 0):
        self.capacity = int(capacity)
        self.resamples = int(resamples)
        self._rng = np.random.default_rng(seed)
        self._buf: list[float] = []
        self._seen = 0

    def update(self, x: float) -> None:
        self._seen += 1
        if len(self._buf) < self.capacity:
            self._buf.append(float(x))
        else:
            j = int(self._rng.integers(0, self._seen))
            if j < self.capacity:
                self._buf[j] = float(x)

    @property
    def count(self) -> int:
        return self._seen

    def ci_mean(self, confidence: float = 0.99) -> Interval:
        if len(self._buf) < 2:
            return Interval(-math.inf, math.inf, float(np.mean(self._buf) if self._buf else 0.0))
        buf = np.asarray(self._buf)
        idx = self._rng.integers(0, len(buf), size=(self.resamples, len(buf)))
        means = buf[idx].mean(axis=1)
        alpha = (1.0 - confidence) / 2.0
        lo, hi = np.quantile(means, [alpha, 1.0 - alpha])
        return Interval(lo=float(lo), hi=float(hi), mean=float(buf.mean()))


# ---------------------------------------------------------------------------
# Robust nonparametric statistics (paper Sec. VII: "basing the stop
# conditions on other statistics, like the median")
# ---------------------------------------------------------------------------


def _average_ranks(xs: Sequence[float]) -> list[float]:
    """1-based ranks with ties sharing their average rank."""
    order = sorted(range(len(xs)), key=lambda i: xs[i])
    ranks = [0.0] * len(xs)
    i = 0
    while i < len(order):
        j = i
        while j + 1 < len(order) and xs[order[j + 1]] == xs[order[i]]:
            j += 1
        avg = (i + j) / 2.0 + 1.0
        for k in range(i, j + 1):
            ranks[order[k]] = avg
        i = j + 1
    return ranks


def spearman(xs: Sequence[float], ys: Sequence[float]) -> Optional[float]:
    """Spearman rank-correlation of two paired samples (average ranks for
    ties — the tie-robust form, not the 6Σd² shortcut). ``None`` when a
    side is degenerate (fewer than two pairs, or all values tied): rank
    agreement is undefined there, and consumers — e.g. transfer-seed
    donor ranking in :meth:`~repro.core.cache.TrialCache.rank_donors` —
    treat it as "no signal" rather than 0.
    """
    if len(xs) != len(ys):
        raise ValueError("paired samples must have equal length")
    n = len(xs)
    if n < 2:
        return None
    rx, ry = _average_ranks(xs), _average_ranks(ys)
    mx, my = sum(rx) / n, sum(ry) / n
    cov = sum((a - mx) * (b - my) for a, b in zip(rx, ry))
    vx = sum((a - mx) ** 2 for a in rx)
    vy = sum((b - my) ** 2 for b in ry)
    if vx <= 0.0 or vy <= 0.0:
        return None
    return cov / math.sqrt(vx * vy)


def median_of_means(samples: Sequence[float], n_blocks: int = 8) -> float:
    xs = np.asarray(list(samples), dtype=np.float64)
    if xs.size == 0:
        raise ValueError("no samples")
    k = max(1, min(n_blocks, xs.size))
    blocks = np.array_split(xs, k)
    return float(np.median([b.mean() for b in blocks]))


def sign_test_median_ci(samples: Sequence[float],
                        confidence: float = 0.99) -> Interval:
    """Distribution-free CI for the median from order statistics.

    P(X_(r) < median < X_(n-r+1)) derives from the Binomial(n, 1/2) CDF; we
    pick the largest r whose coverage is >= ``confidence``.
    """
    xs = np.sort(np.asarray(list(samples), dtype=np.float64))
    n = xs.size
    if n < 2:
        m = float(xs[0]) if n else 0.0
        return Interval(-math.inf, math.inf, m)
    # Binomial(n, 1/2) CDF via cumulative sum of exact pmf (n is small here).
    pmf = np.array([math.comb(n, k) for k in range(n + 1)], dtype=np.float64)
    pmf /= 2.0 ** n
    cdf = np.cumsum(pmf)
    r_best = 0
    for r in range(1, n // 2 + 1):
        # coverage = P(r <= K <= n-r) where K ~ Bin(n, 1/2)
        coverage = cdf[n - r] - (cdf[r - 1] if r >= 1 else 0.0)
        if coverage >= confidence:
            r_best = r
        else:
            break
    med = float(np.median(xs))
    if r_best == 0:
        return Interval(-math.inf, math.inf, med)
    return Interval(lo=float(xs[r_best - 1]), hi=float(xs[n - r_best]), mean=med)

"""Phase-bucket profiling of the tuning harness itself.

The paper's 116x search-time win came from cutting *sample counts*; the
next order of magnitude is per-trial overhead, and you cannot cut what
you cannot see. This module is the minimal instrumentation layer the
harness self-benchmark (``scripts/bench_harness.py``) activates to
attribute a tuning session's wall clock to phase buckets:

  ``setup``     invocation-factory work (data generation, pre-heat)
  ``compile``   kernel lowering + compilation (ExecutableCache misses)
  ``dispatch``  timed kernel work as seen by the samplers
  ``sync``      device synchronization at the end of a batched sample
  ``stats``     Welford updates + stop-condition evaluation
  ``cache_io``  trial-cache JSONL appends

Buckets may nest (a cache-served ``compile`` happens inside ``setup``);
each records its own wall time independently, so buckets are a
*profile*, not a partition — ``bench_harness`` derives its headline
non-measured metric from session wall clock and kernel-time references,
and uses these buckets to explain where the overhead went.

Instrumentation sites call :func:`phase`, which is a no-op (one global
read, no allocation) unless a :class:`PhaseProfiler` is installed — the
hot per-sample paths stay hardware-fast when nobody is profiling.
Thread-safe: concurrent trials on the thread backend fold into the same
buckets under a lock.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Optional

__all__ = ["PhaseProfiler", "PhaseStats", "phase", "profiler"]


class PhaseStats:
    """Accumulated (wall seconds, enter count) of one bucket."""

    __slots__ = ("seconds", "count")

    def __init__(self):
        self.seconds = 0.0
        self.count = 0

    def to_json(self) -> dict:
        return {"seconds": self.seconds, "count": self.count}


class _NullPhase:
    """Shared no-op context manager returned when no profiler is active."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullPhase()


class _Span:
    __slots__ = ("profiler", "name", "t0")

    def __init__(self, profiler: "PhaseProfiler", name: str):
        self.profiler = profiler
        self.name = name

    def __enter__(self):
        self.t0 = self.profiler.clock()
        return self

    def __exit__(self, *exc):
        self.profiler.add(self.name, self.profiler.clock() - self.t0)
        return False


class PhaseProfiler:
    """Collects phase buckets while installed as the active profiler.

    Use as a context manager (installation is process-global — one
    profiler at a time; nested installs raise)::

        prof = PhaseProfiler()
        with prof:
            tuner.tune(benchmark)
        print(prof.report())
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self._lock = threading.Lock()
        self._buckets: dict[str, PhaseStats] = {}

    # -- collection -----------------------------------------------------------
    def add(self, name: str, seconds: float) -> None:
        with self._lock:
            st = self._buckets.get(name)
            if st is None:
                st = self._buckets[name] = PhaseStats()
            st.seconds += seconds
            st.count += 1

    def phase(self, name: str) -> _Span:
        return _Span(self, name)

    # -- reading --------------------------------------------------------------
    def buckets(self) -> dict[str, PhaseStats]:
        with self._lock:
            return dict(self._buckets)

    def to_json(self) -> dict:
        return {name: st.to_json()
                for name, st in sorted(self.buckets().items())}

    def report(self) -> str:
        rows = [f"  {name:<10s} {st.seconds * 1e3:9.3f} ms x{st.count}"
                for name, st in sorted(self.buckets().items())]
        return "harness phases:\n" + "\n".join(rows) if rows \
            else "harness phases: (empty)"

    # -- installation ---------------------------------------------------------
    def __enter__(self) -> "PhaseProfiler":
        global _ACTIVE
        with _INSTALL_LOCK:
            if _ACTIVE is not None:
                raise RuntimeError("a PhaseProfiler is already active")
            _ACTIVE = self
        return self

    def __exit__(self, *exc) -> bool:
        global _ACTIVE
        with _INSTALL_LOCK:
            _ACTIVE = None
        return False


_INSTALL_LOCK = threading.Lock()
_ACTIVE: Optional[PhaseProfiler] = None


def profiler() -> Optional[PhaseProfiler]:
    """The currently installed profiler, or ``None``."""
    return _ACTIVE


def phase(name: str):
    """Context manager timing one phase span; free when not profiling."""
    active = _ACTIVE
    if active is None:
        return _NULL
    return active.phase(name)

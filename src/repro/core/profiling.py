"""Phase-bucket profiling of the tuning harness itself.

The paper's 116x search-time win came from cutting *sample counts*; the
next order of magnitude is per-trial overhead, and you cannot cut what
you cannot see. This module is the minimal instrumentation layer the
harness self-benchmark (``scripts/bench_harness.py``) activates to
attribute a tuning session's wall clock to phase buckets:

  ``setup``     invocation-factory work (data generation, pre-heat)
  ``compile``   kernel lowering + compilation (ExecutableCache misses)
  ``dispatch``  timed kernel work as seen by the samplers
  ``sync``      device synchronization at the end of a batched sample
  ``stats``     Welford updates + stop-condition evaluation
  ``cache_io``  trial-cache JSONL appends

Buckets may nest (a cache-served ``compile`` happens inside ``setup``);
each records its own wall time independently, so buckets are a
*profile*, not a partition — ``bench_harness`` derives its headline
non-measured metric from session wall clock and kernel-time references,
and uses these buckets to explain where the overhead went.

Instrumentation sites call :func:`phase`, which is a no-op (two global
reads, no allocation) unless a :class:`PhaseProfiler` *or* a trace sink
is installed — the hot per-sample paths stay hardware-fast when nobody
is watching.  Thread-safe: concurrent trials on the thread backend fold
into the same buckets under a lock.

The module is also the **dual-sink seam** for ``repro.obs``: a
:class:`~repro.obs.trace.TraceRecorder` installs itself via
:func:`set_trace_sink`, after which every :func:`phase` site feeds both
the aggregate buckets (when a profiler is active) and a per-thread span
in the trace — per-trial attribution the folded buckets cannot give.
Core modules never import ``repro.obs``; they call the sink-agnostic
helpers here (:func:`trace_span`, :func:`trace_instant`,
:func:`record_phase`), which no-op when no recorder is installed.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Optional

__all__ = ["PhaseProfiler", "PhaseStats", "phase", "profiler",
           "record_phase", "set_trace_sink", "trace_instant", "trace_sink",
           "trace_span"]


class PhaseStats:
    """Accumulated (wall seconds, enter count) of one bucket."""

    __slots__ = ("seconds", "count")

    def __init__(self):
        self.seconds = 0.0
        self.count = 0

    def to_json(self) -> dict:
        return {"seconds": self.seconds, "count": self.count}


class _NullPhase:
    """Shared no-op context manager returned when no sink is active."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return None


_NULL = _NullPhase()


class _Span:
    __slots__ = ("profiler", "name", "t0")

    def __init__(self, profiler: "PhaseProfiler", name: str):
        self.profiler = profiler
        self.name = name

    def __enter__(self):
        self.t0 = self.profiler.clock()
        return self

    def __exit__(self, *exc):
        self.profiler.add(self.name, self.profiler.clock() - self.t0)
        return False


class PhaseProfiler:
    """Collects phase buckets while installed as the active profiler.

    Use as a context manager (installation is process-global — one
    profiler at a time; nested installs raise)::

        prof = PhaseProfiler()
        with prof:
            tuner.tune(benchmark)
        print(prof.report())
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self._lock = threading.Lock()
        self._buckets: dict[str, PhaseStats] = {}

    # -- collection -----------------------------------------------------------
    def add(self, name: str, seconds: float) -> None:
        with self._lock:
            st = self._buckets.get(name)
            if st is None:
                st = self._buckets[name] = PhaseStats()
            st.seconds += seconds
            st.count += 1

    def phase(self, name: str) -> _Span:
        return _Span(self, name)

    # -- reading --------------------------------------------------------------
    def buckets(self) -> dict[str, PhaseStats]:
        with self._lock:
            return dict(self._buckets)

    def to_json(self) -> dict:
        return {name: st.to_json()
                for name, st in sorted(self.buckets().items())}

    def report(self) -> str:
        rows = [f"  {name:<10s} {st.seconds * 1e3:9.3f} ms x{st.count}"
                for name, st in sorted(self.buckets().items())]
        return "harness phases:\n" + "\n".join(rows) if rows \
            else "harness phases: (empty)"

    # -- installation ---------------------------------------------------------
    def __enter__(self) -> "PhaseProfiler":
        global _ACTIVE
        with _INSTALL_LOCK:
            if _ACTIVE is not None:
                raise RuntimeError("a PhaseProfiler is already active")
            _ACTIVE = self
        return self

    def __exit__(self, *exc) -> bool:
        global _ACTIVE
        with _INSTALL_LOCK:
            _ACTIVE = None
        return False


_INSTALL_LOCK = threading.Lock()
_ACTIVE: Optional[PhaseProfiler] = None

# the installed TraceRecorder (repro.obs.trace), or None; duck-typed so
# this module never has to import obs
_TRACE = None


def profiler() -> Optional[PhaseProfiler]:
    """The currently installed profiler, or ``None``."""
    return _ACTIVE


def set_trace_sink(sink) -> None:
    """Install/clear the trace sink (called by ``TraceRecorder``)."""
    global _TRACE
    _TRACE = sink


def trace_sink():
    """The installed trace sink, or ``None`` when tracing is off."""
    return _TRACE


class _DualPhase:
    """One ``phase()`` site feeding bucket and/or span sinks."""

    __slots__ = ("name", "_prof", "_sink", "_span", "_bucket")

    def __init__(self, name: str, prof: Optional[PhaseProfiler], sink):
        self.name = name
        self._prof = prof
        self._sink = sink
        self._span = None
        self._bucket = None

    def __enter__(self):
        if self._prof is not None:
            self._bucket = self._prof.phase(self.name).__enter__()
        self._span = self._sink.span(self.name, cat="phase").__enter__()
        return self

    def set(self, **attrs):
        self._span.set(**attrs)

    def __exit__(self, *exc):
        self._span.__exit__(*exc)
        if self._bucket is not None:
            self._bucket.__exit__(*exc)
        return False


def phase(name: str):
    """Context manager timing one phase span; free when nobody watches.

    Dual-sink: feeds the active :class:`PhaseProfiler` buckets and the
    active trace sink's span tree, whichever (or both) is installed.
    """
    active = _ACTIVE
    sink = _TRACE
    if sink is None:
        if active is None:
            return _NULL
        return active.phase(name)
    return _DualPhase(name, active, sink)


def record_phase(name: str, seconds: float,
                 at: Optional[float] = None) -> None:
    """Record an interval the caller already measured, into both sinks.

    The samplers use this for their hot-loop deltas (clock readings are
    already taken; a context manager would add overhead).  ``at`` is the
    interval's end on ``time.perf_counter`` so adjacent phases land
    adjacent in the trace; ``None`` means "now".
    """
    active = _ACTIVE
    if active is not None:
        active.add(name, seconds)
    sink = _TRACE
    if sink is not None:
        sink.add_phase(name, seconds, at=at)


def trace_span(name: str, cat: str = "phase", *, context: bool = False,
               **attrs):
    """Open a span on the trace sink; shared no-op when tracing is off."""
    sink = _TRACE
    if sink is None:
        return _NULL
    return sink.span(name, cat=cat, context=context, **attrs)


def trace_instant(name: str, **attrs) -> None:
    """Emit an instant event on the trace sink, if one is installed."""
    sink = _TRACE
    if sink is not None:
        sink.instant(name, **attrs)

"""Cache-backed roofline dashboards (ROADMAP: the cache as system of record).

The paper's end product is a roofline model assembled from *measured* peaks:
the autotuned DGEMM incumbent supplies the compute ceiling ``F_p`` and the
autotuned TRIAD incumbents supply each memory subsystem's bandwidth slope
``B_a`` (paper Sec. II-III). Every trial behind those peaks is already
persisted by :mod:`repro.core.cache` with exact Welford moments and a
hardware fingerprint, so the model — and a confidence interval for every
peak — can be reassembled from disk at any time without re-measuring,
treating trial archives as reusable artifacts the way *Towards a
Benchmarking Suite for Kernel Tuners* (arXiv:2303.08976) prescribes.

Pipeline:

  :func:`~repro.core.cache.load_trials`  (one file or a session directory)
      -> :func:`group_by_fingerprint`
      -> :func:`extract_incumbent` / :func:`triad_subsystems`
      -> :func:`build_reports`   (one :class:`FingerprintReport` per machine)
      -> :func:`render_markdown` / :func:`render_csv`

Unit convention: trial scores are **GFLOP/s** for the compute benchmark and
**GB/s** for the bandwidth benchmark (the ``timed_sampler(work=…/1e9)``
contract in ``benchmarks/common.py``); ``unit_scale`` converts them to
FLOP/s and B/s for the model. Incumbent selection matches
``TrialCache.best`` exactly — best non-pruned score, first-seen wins ties —
so a report names the same winner a resumed ``TuningSession`` warm-starts
from.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional, Sequence

from . import welford
from .cache import CachedTrial, config_key
from .confidence import Interval, ci_mean
from .roofline import (TRIAD_INTENSITY, RooflineModel, from_measurements,
                       operational_intensity, ridge_point)
from .searchspace import Config
from .stop_conditions import Direction
from .welford import WelfordState

__all__ = ["FingerprintReport", "IncumbentTrial", "build_reports",
           "dgemm_config_intensity", "extract_incumbent",
           "group_by_fingerprint", "pooled_state", "render_csv",
           "render_markdown", "trials_from_result", "triad_subsystems"]

#: Benchmark names the CLIs record under (``scripts/tune.py --benchmark``).
DGEMM_BENCHMARK = "dgemm"
TRIAD_BENCHMARK = "triad"

#: Scores are GFLOP/s / GB/s; the roofline model wants FLOP/s / B/s.
UNIT_SCALE = 1e9


# ---------------------------------------------------------------------------
# Incumbent extraction (must mirror TrialCache.best / warm-start selection)
# ---------------------------------------------------------------------------


def pooled_state(result) -> WelfordState:
    """Exact sample-level moments of an :class:`EvalResult`, recovered by
    merging every invocation's stored (count, mean, m2) with the Chan et
    al. combiner — the cache's exact-Welford round-trip makes this
    bit-identical to having streamed all samples into one accumulator."""
    return welford.tree_merge([
        WelfordState(count=float(i.count), mean=i.mean, m2=i.m2)
        for i in result.invocations])


@dataclasses.dataclass(frozen=True)
class IncumbentTrial:
    """A benchmark's best cached trial, with its CI recoverable from the
    stored moments."""

    trial: CachedTrial

    @property
    def benchmark(self) -> str:
        return self.trial.benchmark

    @property
    def config(self) -> Config:
        return self.trial.config

    @property
    def score(self) -> float:
        return self.trial.result.score

    @property
    def total_samples(self) -> int:
        return self.trial.result.total_samples

    @property
    def strategy(self) -> Optional[str]:
        """Search strategy that produced this incumbent (``None`` for
        records predating the strategy layer)."""
        return self.trial.strategy

    def interval(self, confidence: float = 0.99) -> Interval:
        """CI of the mean over the pooled sample stream (same units as
        ``score``)."""
        return ci_mean(pooled_state(self.trial.result), confidence)


def extract_incumbent(trials: Iterable[CachedTrial], benchmark: str,
                      direction: Direction = Direction.MAXIMIZE,
                      ) -> Optional[IncumbentTrial]:
    """Best non-pruned trial of one benchmark — the selection rule of
    ``TrialCache.best`` (pruned trials carry truncated estimates and never
    win; ties keep the first-seen trial), so the reported incumbent is the
    one a resumed session would warm-start from."""
    best: Optional[CachedTrial] = None
    for t in trials:
        if t.benchmark != benchmark or t.result.pruned:
            continue
        if best is None or direction.better(t.result.score,
                                            best.result.score):
            best = t
    return IncumbentTrial(best) if best is not None else None


def group_by_fingerprint(trials: Iterable[CachedTrial],
                         ) -> dict[str, list[CachedTrial]]:
    """Trials bucketed by hardware fingerprint, insertion order preserved
    within each bucket (timings do not transfer across hardware, so every
    downstream aggregation happens per bucket)."""
    groups: dict[str, list[CachedTrial]] = {}
    for t in trials:
        groups.setdefault(t.fingerprint, []).append(t)
    return groups


# ---------------------------------------------------------------------------
# Benchmark-specific interpretation
# ---------------------------------------------------------------------------


def dgemm_config_intensity(config: Config,
                           itemsize: int = 4) -> Optional[float]:
    """Operational intensity of one (n, m, k) matmul config: 2nmk FLOPs
    over the three operand/result arrays (paper Eq. 1). None when the
    config does not look like a matmul."""
    try:
        n, m, k = int(config["n"]), int(config["m"]), int(config["k"])
    except (KeyError, TypeError, ValueError):
        return None
    return operational_intensity(2.0 * n * m * k,
                                 float(itemsize) * (n * k + k * m + n * m))


def _humanize_bytes(n: float) -> str:
    for unit, scale in (("GiB", 2 ** 30), ("MiB", 2 ** 20), ("KiB", 2 ** 10)):
        if n >= scale:
            return f"{n / scale:g}{unit}"
    return f"{n:g}B"


def _subsystem_name(config: Config) -> str:
    """Stable display name of the memory subsystem one TRIAD config probes
    (working-set size decides which level of the hierarchy it streams)."""
    if set(config) == {"n_bytes"}:
        return f"mem[{_humanize_bytes(config['n_bytes'])}]"
    return "mem[" + ";".join(f"{k}={config[k]}" for k in sorted(config)) + "]"


def triad_subsystems(trials: Iterable[CachedTrial],
                     benchmark: str = TRIAD_BENCHMARK,
                     direction: Direction = Direction.MAXIMIZE,
                     ) -> dict[str, IncumbentTrial]:
    """Per-config TRIAD incumbents, one memory subsystem each.

    Each distinct TRIAD configuration (e.g. cache-resident vs streaming
    working set) probes a different memory subsystem, so its own best
    non-pruned trial becomes that subsystem's measured ``B_a``. Configs
    whose every trial was pruned are dropped: pruned bandwidths are
    truncated estimates.
    """
    per_config: dict[str, CachedTrial] = {}
    for t in trials:
        if t.benchmark != benchmark or t.result.pruned:
            continue
        prev = per_config.get(t.key)
        if prev is None or direction.better(t.result.score,
                                            prev.result.score):
            per_config[t.key] = t
    out = {_subsystem_name(t.config): IncumbentTrial(t)
           for t in per_config.values()}
    return dict(sorted(out.items()))


def trials_from_result(result, benchmark: str,
                       fingerprint: str) -> list[CachedTrial]:
    """Adapt an in-memory :class:`~repro.core.tuner.TuningResult` to the
    reporting layer's input, so fresh runs can render the same dashboards
    as persisted caches."""
    strategy = getattr(result, "strategy", None)
    return [CachedTrial(benchmark=benchmark, fingerprint=fingerprint,
                        config=t.config, result=t.result, strategy=strategy)
            for t in result.trials]


# ---------------------------------------------------------------------------
# Report assembly
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FingerprintReport:
    """One machine's measured roofline: model, incumbents, kernel marks."""

    fingerprint: str
    model: RooflineModel
    dgemm: IncumbentTrial
    bandwidths: tuple[tuple[str, IncumbentTrial], ...]  # name-sorted
    marks: tuple[tuple[str, float, float], ...]         # (label, I, FLOP/s)
    n_trials: int
    confidence: float = 0.99
    unit_scale: float = UNIT_SCALE   # score units -> FLOP/s / B/s

    @property
    def peak_flops(self) -> float:
        return self.model.machine.peak_flops

    def gap_rows(self) -> list[dict]:
        """Model-vs-measured rows. A mark labeled ``<kernel>:<subsystem>``
        (the TRIAD convention) gaps only against its own subsystem's roof —
        a cache-resident stream measured against the DRAM slope would show
        a meaningless >100% "gap"; unqualified marks gap against every
        subsystem."""
        subsystems = set(self.model.machine.mem_bandwidths)
        rows = []
        for row in self.model.gap_table(self.marks):
            _, _, qualifier = row["kernel"].partition(":")
            if qualifier in subsystems and row["subsystem"] != qualifier:
                continue
            rows.append(row)
        return rows


def build_reports(trials: Sequence[CachedTrial], *,
                  dgemm_benchmark: str = DGEMM_BENCHMARK,
                  triad_benchmark: str = TRIAD_BENCHMARK,
                  direction: Direction = Direction.MAXIMIZE,
                  unit_scale: float = UNIT_SCALE,
                  confidence: float = 0.99,
                  ) -> tuple[list[FingerprintReport],
                             list[tuple[str, str]]]:
    """Assemble one report per hardware fingerprint.

    A fingerprint is reportable when it has at least one unpruned trial of
    *both* benchmarks (DGEMM for ``F_p``, TRIAD for the ``B_a`` slopes);
    the second return value lists the fingerprints skipped, with reasons.
    Reports come back sorted by fingerprint for deterministic rendering.
    """
    reports: list[FingerprintReport] = []
    skipped: list[tuple[str, str]] = []
    for fp, group in sorted(group_by_fingerprint(trials).items()):
        peak = extract_incumbent(group, dgemm_benchmark, direction)
        bws = triad_subsystems(group, triad_benchmark, direction)
        if peak is None:
            skipped.append((fp, f"no unpruned {dgemm_benchmark!r} trials"))
            continue
        if not bws:
            skipped.append((fp, f"no unpruned {triad_benchmark!r} trials"))
            continue
        model = from_measurements(
            fp, peak.score * unit_scale,
            {name: inc.score * unit_scale for name, inc in bws.items()})
        marks: list[tuple[str, float, float]] = []
        dgemm_i = dgemm_config_intensity(peak.config)
        if dgemm_i is not None:
            marks.append((dgemm_benchmark, dgemm_i, peak.score * unit_scale))
        for name, inc in bws.items():
            # TRIAD achieves B_a at I = 1/12 by construction, so its marker
            # sits on its own slope: F = B_a * I.
            marks.append((f"{triad_benchmark}:{name}", TRIAD_INTENSITY,
                          inc.score * unit_scale * TRIAD_INTENSITY))
        reports.append(FingerprintReport(
            fingerprint=fp, model=model, dgemm=peak,
            bandwidths=tuple(bws.items()), marks=tuple(marks),
            n_trials=len(group), confidence=confidence,
            unit_scale=unit_scale))
    return reports, skipped


# ---------------------------------------------------------------------------
# Rendering
# ---------------------------------------------------------------------------


def _num(x: float) -> str:
    return f"{x:.4g}"


def _margin(interval: Interval) -> str:
    return "n/a" if math.isinf(interval.margin) else f"±{interval.margin:.3g}"


def _md_table(header: Sequence[str], rows: Sequence[Sequence[str]]) -> list[str]:
    lines = ["| " + " | ".join(header) + " |",
             "|" + "|".join("---" for _ in header) + "|"]
    lines += ["| " + " | ".join(r) + " |" for r in rows]
    return lines


def render_markdown(reports: Sequence[FingerprintReport],
                    skipped: Sequence[tuple[str, str]] = ()) -> str:
    """The dashboard: per-fingerprint measured peaks (with CIs), ASCII
    roofline with achieved-kernel markers, %-of-roof gap table, and a
    side-by-side comparison across fingerprints."""
    n_trials = sum(r.n_trials for r in reports)
    lines = ["# Cache-backed roofline dashboard", ""]
    lines.append(f"Assembled from {n_trials} cached trials across "
                 f"{len(reports)} hardware fingerprint(s), without "
                 f"re-measuring.")
    lines.append("")
    for r in reports:
        conf_pct = f"{r.confidence * 100:g}%"
        # scores are GFLOP/s / GB/s under the default scale; under a custom
        # unit_scale they are whatever the caller measured
        gf, gb = (("GFLOP/s", "GB/s") if r.unit_scale == UNIT_SCALE
                  else ("(score)", "(score)"))
        lines.append(f"## Fingerprint `{r.fingerprint}`")
        lines.append("")
        # annotate which search strategy produced each incumbent, when any
        # trial recorded one (records predating the strategy layer do not)
        annotate = (r.dgemm.strategy is not None
                    or any(inc.strategy is not None
                           for _, inc in r.bandwidths))

        def _via(inc: IncumbentTrial) -> list[str]:
            if not annotate:
                return []
            return [inc.strategy if inc.strategy is not None else "—"]

        rows = []
        iv = r.dgemm.interval(r.confidence)
        rows.append(["peak compute F_p (dgemm)",
                     f"{_num(r.dgemm.score)} {gf}", _margin(iv),
                     f"`{config_key(r.dgemm.config)}`",
                     str(r.dgemm.total_samples)] + _via(r.dgemm))
        for name, inc in r.bandwidths:
            iv = inc.interval(r.confidence)
            rows.append([f"bandwidth B_a {name} (triad)",
                         f"{_num(inc.score)} {gb}", _margin(iv),
                         f"`{config_key(inc.config)}`",
                         str(inc.total_samples)] + _via(inc))
        for name, _ in r.bandwidths:
            ridge = ridge_point(r.peak_flops,
                                r.model.machine.mem_bandwidths[name])
            rows.append([f"ridge point I* {name}",
                         f"{_num(ridge)} FLOP/B", "", "", ""]
                        + ([""] if annotate else []))
        lines += _md_table(["quantity", "value", f"{conf_pct} CI",
                            "incumbent config", "samples"]
                           + (["strategy"] if annotate else []), rows)
        lines += ["", "```text", r.model.dashboard(marks=r.marks), "```", ""]
        lines.append("### Model vs measured (% of roof)")
        lines.append("")
        unit = "GFLOP/s" if r.unit_scale == UNIT_SCALE else "FLOP/s"
        scale = r.unit_scale if r.unit_scale == UNIT_SCALE else 1.0
        gap_rows = [[g["kernel"], g["subsystem"],
                     _num(g["intensity_flop_per_byte"]),
                     f"{_num(g['achieved_flops'] / scale)} {unit}",
                     f"{_num(g['attainable_flops'] / scale)} {unit}",
                     f"{g['pct_of_roof']:.1f}%", g["bound"]]
                    for g in r.gap_rows()]
        lines += _md_table(["kernel", "subsystem", "I (FLOP/B)", "achieved",
                            "attainable", "% of roof", "bound"], gap_rows)
        lines.append("")
    if len(reports) > 1:
        lines.append("## Fingerprint comparison")
        lines.append("")
        subsystems = sorted({name for r in reports
                             for name, _ in r.bandwidths})
        default_units = all(r.unit_scale == UNIT_SCALE for r in reports)
        gf, gb = (("GFLOP/s", "GB/s") if default_units
                  else ("score", "score"))
        header = ["quantity"] + [f"`{r.fingerprint}`" for r in reports]
        rows = [[f"peak compute ({gf})"]
                + [_num(r.dgemm.score) for r in reports]]
        for name in subsystems:
            row = [f"B_a {name} ({gb})"]
            for r in reports:
                bw = dict(r.bandwidths).get(name)
                row.append(_num(bw.score) if bw is not None else "—")
            rows.append(row)
        for name in subsystems:
            row = [f"ridge I* {name} (FLOP/B)"]
            for r in reports:
                b = r.model.machine.mem_bandwidths.get(name)
                row.append(_num(ridge_point(r.peak_flops, b))
                           if b is not None else "—")
            rows.append(row)
        rows.append(["best dgemm config"]
                    + [f"`{config_key(r.dgemm.config)}`" for r in reports])
        rows.append(["cached trials"] + [str(r.n_trials) for r in reports])
        lines += _md_table(header, rows)
        lines.append("")
    if skipped:
        lines.append("## Skipped fingerprints")
        lines.append("")
        lines += [f"- `{fp}`: {reason}" for fp, reason in skipped]
        lines.append("")
    return "\n".join(lines)


def render_csv(reports: Sequence[FingerprintReport]) -> str:
    """Flat CSV of every report: measured peaks, roof curves, kernel marks,
    and %-of-roof gap rows. Text cells are sanitized to carry no embedded
    commas (configs as ``;``-separated key=value pairs; commas inside a
    hardware fingerprint — multi-device-kind hosts — become ``;``), so
    every row has exactly 7 naive-split fields."""

    def txt(s: str) -> str:
        return str(s).replace(",", ";")

    def cfg(c: Config) -> str:
        return ";".join(f"{k}={c[k]}" for k in sorted(c))

    rows = ["fingerprint,kind,name,intensity_flop_per_byte,value,"
            "pct_of_roof,config"]
    for r in reports:
        fp = txt(r.fingerprint)
        rows.append(f"{fp},peak_flops,{txt(r.dgemm.benchmark)},,"
                    f"{r.peak_flops:.6g},,{cfg(r.dgemm.config)}")
        for name, inc in r.bandwidths:
            rows.append(f"{fp},bandwidth,{txt(name)},,"
                        f"{inc.score * r.unit_scale:.6g},,{cfg(inc.config)}")
        for name, _ in r.bandwidths:
            for i, f in r.model.curve(name):
                rows.append(f"{fp},curve,{txt(name)},{i:.6g},{f:.6g},,")
        for label, mi, mf in r.marks:
            rows.append(f"{fp},mark,{txt(label)},{mi:.6g},{mf:.6g},,")
        for g in r.gap_rows():
            rows.append(f"{fp},gap,{txt(g['kernel'])}/{txt(g['subsystem'])},"
                        f"{g['intensity_flop_per_byte']:.6g},"
                        f"{g['achieved_flops']:.6g},"
                        f"{g['pct_of_roof']:.2f},")
    return "\n".join(rows)

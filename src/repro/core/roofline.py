"""Roofline model assembly (paper Sec. II, Eq. 1-2).

    I = W / Q                      (operational intensity, FLOP/byte)
    F_a(I) = min(B_a * I, F_p)     (attainable performance)

The paper's tool emits this model from *measured* peaks (autotuned DGEMM for
F_p, autotuned TRIAD for each memory subsystem's B_a) with no vendor specs.
We keep that shape, and additionally ship the TPU-v5e theoretical machine
description used by the dry-run roofline analysis (EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """Peak terms for one machine (theoretical or measured)."""

    name: str
    peak_flops: float                      # FLOP/s (per chip for TPU specs)
    mem_bandwidths: Mapping[str, float]    # subsystem name -> bytes/s
    link_bandwidth: float = 0.0            # bytes/s per ICI link (TPU)
    chips: int = 1

    @property
    def total_flops(self) -> float:
        return self.peak_flops * self.chips

    def total_bandwidth(self, subsystem: str) -> float:
        return self.mem_bandwidths[subsystem] * self.chips


# TPU v5e constants given by the assignment (per chip).
TPU_V5E = MachineSpec(
    name="tpu-v5e",
    peak_flops=197e12,                     # bf16
    mem_bandwidths={"hbm": 819e9},
    link_bandwidth=50e9,
)


def attainable(intensity: float, peak_flops: float, bandwidth: float) -> float:
    """F(I) = min(B*I, Fp) — paper Eq. 2."""
    return min(bandwidth * intensity, peak_flops)


def ridge_point(peak_flops: float, bandwidth: float) -> float:
    """Intensity at which the roof flattens: I* = Fp / B."""
    return peak_flops / bandwidth


@dataclasses.dataclass(frozen=True)
class RooflineModel:
    """A machine's roofline: one compute ceiling, N bandwidth slopes."""

    machine: MachineSpec

    def attainable(self, intensity: float, subsystem: str) -> float:
        return attainable(intensity, self.machine.total_flops,
                          self.machine.total_bandwidth(subsystem))

    def bound(self, intensity: float, subsystem: str) -> str:
        ridge = ridge_point(self.machine.total_flops,
                            self.machine.total_bandwidth(subsystem))
        return "compute" if intensity >= ridge else "memory"

    def percent_of_roof(self, intensity: float, achieved_flops: float,
                        subsystem: str) -> float:
        """Measured performance as a percentage of the attainable roof at
        this intensity — the model-vs-measured gap the dashboards report."""
        roof = self.attainable(intensity, subsystem)
        if roof <= 0:
            return 0.0
        return 100.0 * achieved_flops / roof

    def gap_table(self, marks: Sequence[tuple[str, float, float]],
                  ) -> list[dict]:
        """Model-vs-measured rows for achieved-kernel markers: one row per
        (marker, memory subsystem) with the attainable roof at the
        marker's intensity, the %-of-roof gap, and the bound class."""
        rows = []
        for label, mi, mf in marks:
            for sub in self.machine.mem_bandwidths:
                rows.append({
                    "kernel": label,
                    "subsystem": sub,
                    "intensity_flop_per_byte": mi,
                    "achieved_flops": mf,
                    "attainable_flops": self.attainable(mi, sub),
                    "pct_of_roof": self.percent_of_roof(mi, mf, sub),
                    "bound": self.bound(mi, sub),
                })
        return rows

    # -- emission --------------------------------------------------------------
    def curve(self, subsystem: str, i_lo: float = 2 ** -6, i_hi: float = 2 ** 12,
              points_per_decade: int = 8) -> list[tuple[float, float]]:
        """Log-spaced (I, F(I)) samples for plotting/CSV."""
        out = []
        lo, hi = math.log2(i_lo), math.log2(i_hi)
        n = max(2, int((hi - lo) * points_per_decade / math.log2(10)))
        for k in range(n + 1):
            i = 2.0 ** (lo + (hi - lo) * k / n)
            out.append((i, self.attainable(i, subsystem)))
        return out

    def to_csv(self) -> str:
        rows = ["subsystem,intensity_flop_per_byte,attainable_flops"]
        for sub in self.machine.mem_bandwidths:
            for i, f in self.curve(sub):
                rows.append(f"{sub},{i:.6g},{f:.6g}")
        return "\n".join(rows)

    @staticmethod
    def _raster(series: Sequence[tuple[str, Sequence[tuple[float, float]]]],
                point_marks: Sequence[tuple[str, float, float]],
                width: int, height: int) -> list[str]:
        """Shared log-log rasterizer: draw each (char, curve) series then
        each (char, I, F) marker onto one grid, returning the bordered
        rows. Axes scale to the union of everything drawn."""
        xs = [math.log2(i) for _, pts in series for i, _ in pts]
        ys = [math.log2(max(f, 1.0)) for _, pts in series for _, f in pts]
        xs += [math.log2(i) for _, i, _ in point_marks]
        ys += [math.log2(max(f, 1.0)) for _, _, f in point_marks]
        x0, x1 = min(xs), max(xs)
        y0, y1 = min(ys), max(ys)
        grid = [[" "] * width for _ in range(height)]

        def put(x: float, y: float, ch: str):
            cx = int((x - x0) / max(x1 - x0, 1e-9) * (width - 1))
            cy = int((y - y0) / max(y1 - y0, 1e-9) * (height - 1))
            grid[height - 1 - cy][cx] = ch

        for ch, pts in series:
            for i, f in pts:
                put(math.log2(i), math.log2(max(f, 1.0)), ch)
        for ch, mi, mf in point_marks:
            put(math.log2(mi), math.log2(max(mf, 1.0)), ch)
        return ["|" + "".join(r) + "|" for r in grid]

    def ascii_plot(self, subsystem: str, width: int = 64, height: int = 16,
                   marks: Sequence[tuple[str, float, float]] = ()) -> str:
        """Log-log ASCII roofline of one subsystem with optional
        (label, I, F) markers (drawn as the label's first letter)."""
        rows = self._raster([("*", self.curve(subsystem))],
                            [(label[0].upper(), mi, mf)
                             for label, mi, mf in marks], width, height)
        header = (f"roofline[{self.machine.name}/{subsystem}] "
                  f"x=log2(I), y=log2(FLOP/s)")
        return "\n".join([header] + rows)

    _CURVE_CHARS = "*+x#o@"

    @staticmethod
    def _mark_chars(labels: Sequence[str]) -> list[str]:
        """One distinct uppercase character per mark: the first unused
        alphanumeric of the label, falling back to any unused letter/digit
        (two 'triad:*' marks must not both render as 'T')."""
        used: set[str] = set()
        out = []
        for label in labels:
            candidates = [c for c in label.upper() if c.isalnum()]
            candidates += list("ABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789")
            ch = next((c for c in candidates if c not in used), "?")
            used.add(ch)
            out.append(ch)
        return out

    def dashboard(self, marks: Sequence[tuple[str, float, float]] = (),
                  width: int = 64, height: int = 16) -> str:
        """Every memory subsystem's roof on one log-log ASCII grid, with
        achieved-kernel markers drawn on top (each marker gets its own
        character, derived from its label)."""
        series = [(self._CURVE_CHARS[k % len(self._CURVE_CHARS)],
                   self.curve(sub))
                  for k, sub in enumerate(self.machine.mem_bandwidths)]
        mark_chars = self._mark_chars([label for label, _, _ in marks])
        point_marks = [(ch, mi, mf)
                       for (_, mi, mf), ch in zip(marks, mark_chars)]
        legend = [f"{ch}={sub}" for (ch, _), sub
                  in zip(series, self.machine.mem_bandwidths)]
        legend += [f"{ch}={label}"
                   for (label, _, _), ch in zip(marks, mark_chars)]
        header = (f"roofline[{self.machine.name}] "
                  f"x=log2(I), y=log2(FLOP/s)")
        lines = ([header]
                 + self._raster(series, point_marks, width, height)
                 + ["legend: " + "  ".join(legend)])
        return "\n".join(lines)


def from_measurements(name: str, measured_peak_flops: float,
                      measured_bandwidths: Mapping[str, float],
                      chips: int = 1) -> RooflineModel:
    """Assemble the empirical model from the tuner's benchmark outputs —
    the paper's end product."""
    return RooflineModel(MachineSpec(
        name=name, peak_flops=measured_peak_flops,
        mem_bandwidths=dict(measured_bandwidths), chips=chips))


def operational_intensity(flops: float, bytes_moved: float) -> float:
    """I = W / Q — paper Eq. 1."""
    if bytes_moved <= 0:
        return math.inf
    return flops / bytes_moved


TRIAD_INTENSITY = 2.0 / 24.0  # paper Sec. III-B: 2 FLOP per 24 bytes = 1/12

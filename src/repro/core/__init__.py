"""Core contribution of the paper: CI-pruned autotuning benchmarking.

Public API re-exports. See DESIGN.md §2 for the layer map.
"""

from .cache import (AUTO_LEDGER, CACHE_VERSION, BoundCache, CachedTrial,
                    TrialCache, TuningSession, config_key,
                    hardware_fingerprint, iter_trials, load_trials,
                    settings_key)
from .confidence import (Interval, ReservoirBootstrap, ci_mean,
                         median_of_means, normal_quantile,
                         sign_test_median_ci, spearman, t_quantile)
from .evaluator import (BatchCalibration, ClockCalibration, EvalResult,
                        EvaluationSettings, Evaluator, InvocationResult,
                        TimingResolutionWarning, calibrate_batch,
                        calibrate_clock, steady_sampler, timed_sampler)
from .exec_cache import (CompilePipeline, ExecCacheStats, ExecutableCache,
                         default_cache)
from .executor import (Batch, BatchStats, ExecutionBackend, ExecutionStats,
                       IncumbentCell, ProcessPoolBackend, SerialBackend,
                       SimulatedShardedBackend, ThreadPoolBackend,
                       TrialOutcome)
from .profiling import (PhaseProfiler, PhaseStats, phase, profiler,
                        record_phase, trace_instant, trace_sink, trace_span)
from .report import (FingerprintReport, IncumbentTrial, build_reports,
                     dgemm_config_intensity, extract_incumbent,
                     group_by_fingerprint, pooled_state, render_csv,
                     render_markdown, trials_from_result, triad_subsystems)
from .roofline import (TPU_V5E, MachineSpec, RooflineModel, TRIAD_INTENSITY,
                       attainable, from_measurements, operational_intensity,
                       ridge_point)
from .searchspace import (Config, Param, SearchSpace, doubling_from, grid,
                          param, powers_of_two)
from .stop_conditions import (CIConverged, Direction, EvalContext, MaxCount,
                              MaxTime, StopCondition, StopDecision,
                              UpperBoundPrune)
from .strategy import (ExhaustiveStrategy, NeighborhoodStrategy,
                       RandomSearchStrategy, SearchStrategy,
                       SuccessiveHalvingStrategy)
from .tuner import (BenchmarkFactory, EvaluateTask, TrialRecord, Tuner,
                    TuningResult, compare_techniques, standard_techniques,
                    tune_successive_halving)
from .welford import WelfordState, from_samples, init, merge, tree_merge, update

__all__ = [
    "AUTO_LEDGER", "BoundCache", "CACHE_VERSION", "CachedTrial", "TrialCache",
    "TuningSession", "config_key", "hardware_fingerprint", "iter_trials",
    "load_trials", "settings_key",
    "Interval", "ReservoirBootstrap", "ci_mean", "median_of_means",
    "normal_quantile", "sign_test_median_ci", "spearman", "t_quantile",
    "FingerprintReport", "IncumbentTrial", "build_reports",
    "dgemm_config_intensity", "extract_incumbent", "group_by_fingerprint",
    "pooled_state", "render_csv", "render_markdown", "trials_from_result",
    "triad_subsystems",
    "BatchCalibration", "ClockCalibration", "EvalResult",
    "EvaluationSettings", "Evaluator", "InvocationResult",
    "TimingResolutionWarning", "calibrate_batch", "calibrate_clock",
    "steady_sampler", "timed_sampler",
    "CompilePipeline", "ExecCacheStats", "ExecutableCache", "default_cache",
    "PhaseProfiler", "PhaseStats", "phase", "profiler",
    "record_phase", "trace_instant", "trace_sink", "trace_span",
    "Batch", "BatchStats", "ExecutionBackend", "ExecutionStats",
    "IncumbentCell", "ProcessPoolBackend", "SerialBackend",
    "SimulatedShardedBackend", "ThreadPoolBackend", "TrialOutcome",
    "TPU_V5E", "MachineSpec", "RooflineModel", "TRIAD_INTENSITY", "attainable",
    "from_measurements", "operational_intensity", "ridge_point",
    "Config", "Param", "SearchSpace", "doubling_from", "grid", "param",
    "powers_of_two",
    "CIConverged", "Direction", "EvalContext", "MaxCount", "MaxTime",
    "StopCondition", "StopDecision", "UpperBoundPrune",
    "ExhaustiveStrategy", "NeighborhoodStrategy", "RandomSearchStrategy",
    "SearchStrategy", "SuccessiveHalvingStrategy",
    "BenchmarkFactory", "EvaluateTask", "TrialRecord", "Tuner",
    "TuningResult", "compare_techniques", "standard_techniques",
    "tune_successive_halving",
    "WelfordState", "from_samples", "init", "merge", "tree_merge", "update",
]

"""The paper's four stop conditions (Sec. III-C) as composable objects.

Each condition inspects an :class:`EvalContext` snapshot after every sample
and may return a :class:`StopDecision`. The evaluator runs the conditions in
order and stops at the first decision.

  1. ``MaxTime``       — accumulated-time budget cap (``-t`` flag).
  2. ``MaxCount``      — iteration-count cap (escape hatch for high-variance
                         configurations whose CI converges slowly).
  3. ``CIConverged``   — "Confidence"/C: stop when the ``confidence`` CI of
                         the mean is within ``rel_margin`` of the mean.
  4. ``UpperBoundPrune`` — "Inner"/"Outer" (I/O): stop when the CI bound
                         facing the incumbent shows the current configuration
                         is very unlikely to beat the best-so-far
                         (paper Listing 1: ``if mean + marg < best: break``).
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Sequence

from . import confidence as confidence_mod
from .welford import WelfordState


class Direction(enum.Enum):
    """Whether larger or smaller metric values are better.

    The paper maximizes GFLOP/s; tuning on wall-time minimizes. All CI logic
    is direction-aware so the same machinery serves both.
    """

    MAXIMIZE = "maximize"
    MINIMIZE = "minimize"

    def better(self, a: float, b: float) -> bool:
        """True iff ``a`` is strictly better than ``b``."""
        return a > b if self is Direction.MAXIMIZE else a < b


@dataclasses.dataclass(frozen=True)
class EvalContext:
    """Snapshot the evaluator hands to each stop condition.

    ``ci_fn`` optionally overrides how the confidence interval is computed
    (paper §VII future work: bootstrap / median-based statistics — see
    ``EvaluationSettings.ci_method``). Default: normal/t CI from the
    Welford moments, as in the paper.
    """

    welford: WelfordState
    elapsed_s: float
    count: int
    incumbent: Optional[float]  # best score seen across configurations
    direction: Direction
    ci_fn: Optional[object] = None  # Callable[[float, bool], Interval]

    def interval(self, confidence: float, use_t: bool):
        if self.ci_fn is not None:
            return self.ci_fn(confidence, use_t)
        return confidence_mod.ci_mean(self.welford, confidence, use_t)


@dataclasses.dataclass(frozen=True)
class StopDecision:
    reason: str
    pruned: bool = False  # True iff stopped because it cannot win (cond. 4)


class StopCondition:
    name: str = "base"

    def check(self, ctx: EvalContext) -> Optional[StopDecision]:
        raise NotImplementedError


@dataclasses.dataclass
class MaxTime(StopCondition):
    """Stop condition 1: total measured time exceeds ``max_seconds``."""

    max_seconds: float
    name: str = "max_time"

    def check(self, ctx: EvalContext) -> Optional[StopDecision]:
        if ctx.elapsed_s >= self.max_seconds:
            return StopDecision(reason=f"max_time({self.max_seconds}s)")
        return None


@dataclasses.dataclass
class MaxCount(StopCondition):
    """Stop condition 2: sample count exceeds ``max_count``."""

    max_count: int
    name: str = "max_count"

    def check(self, ctx: EvalContext) -> Optional[StopDecision]:
        if ctx.count >= self.max_count:
            return StopDecision(reason=f"max_count({self.max_count})")
        return None


@dataclasses.dataclass
class CIConverged(StopCondition):
    """Stop condition 3 ("Confidence"): CI half-width within ``rel_margin``
    of the mean at ``confidence`` level. Paper defaults: 99% / 1%."""

    confidence: float = 0.99
    rel_margin: float = 0.01
    min_count: int = 5
    use_t: bool = True
    name: str = "ci_converged"

    def check(self, ctx: EvalContext) -> Optional[StopDecision]:
        if ctx.count < self.min_count:
            return None
        interval = ctx.interval(self.confidence, self.use_t)
        if interval.relative_margin <= self.rel_margin:
            return StopDecision(
                reason=f"ci_converged(±{interval.relative_margin:.3%})")
        return None


@dataclasses.dataclass
class UpperBoundPrune(StopCondition):
    """Stop condition 4: CI bound facing the incumbent cannot beat it.

    For MAXIMIZE this is the paper's Listing 1 literally:
        if mean + marg < best: break
    For MINIMIZE the mirrored test is ``mean - marg > best``.

    ``min_count`` is the paper's guard for configurations whose performance
    climbs during evaluation (2695v4 needed min_count=100 to avoid discarding
    the true optimum).
    """

    confidence: float = 0.99
    min_count: int = 2
    use_t: bool = True
    name: str = "upper_bound_prune"

    def check(self, ctx: EvalContext) -> Optional[StopDecision]:
        if ctx.incumbent is None or ctx.count < self.min_count:
            return None
        interval = ctx.interval(self.confidence, self.use_t)
        marg = interval.margin
        if not math.isfinite(marg):
            return None
        if ctx.direction is Direction.MAXIMIZE:
            doomed = interval.mean + marg < ctx.incumbent
        else:
            doomed = interval.mean - marg > ctx.incumbent
        if doomed:
            return StopDecision(
                reason=f"upper_bound_prune(bound={interval.mean:+.4g}±{marg:.4g} "
                       f"vs incumbent={ctx.incumbent:.4g})",
                pruned=True)
        return None


def first_decision(conditions: Sequence[StopCondition],
                   ctx: EvalContext) -> Optional[StopDecision]:
    for cond in conditions:
        decision = cond.check(ctx)
        if decision is not None:
            return decision
    return None

"""AOT executable cache + pipelined background compilation.

JAX compilation dominates short trials: the pre-PR invocation factories
re-entered ``jax.jit`` on every outer-loop invocation, so a four-invocation
trial paid tracing/compile-dispatch four times for one kernel. This module
makes compilation a *once per (kernel, config, shape, dtype, device)* cost:

  * :class:`ExecutableCache` — lowers + compiles a kernel once via
    ``jax.jit(fn).lower(*args).compile()`` and serves the compiled
    executable to every subsequent invocation. Keys combine the kernel's
    identity, the static (config) arguments, every operand's
    shape/dtype, and the hardware fingerprint — a shape or dtype change
    is a different executable, exactly like the trial cache's keying.
    Thread-safe with per-key in-flight deduplication: two threads racing
    on the same key produce exactly one compile (the loser waits).
  * :class:`CompilePipeline` — a background compile worker. The engine
    feeds it the strategy's pending batch, so trial k+1's executable
    compiles while trial k runs — compile latency overlaps measurement
    on the serial and thread backends instead of extending the critical
    path.

Also-jitted callables (``jax.jit``-wrapped functions, which already carry
``.lower``) are lowered directly — their declared ``static_argnames`` are
honored — so the Pallas kernel wrappers route through the same cache.

jax is imported lazily (first ``compile`` call), keeping ``repro.core``
importable without initializing a backend.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Mapping, Optional, Sequence

from .profiling import phase, trace_instant

__all__ = ["CompilePipeline", "ExecCacheStats", "ExecutableCache",
           "default_cache"]


def _arg_key(a: Any) -> tuple:
    """Shape/dtype key of one operand (array or ShapeDtypeStruct); plain
    Python scalars key on their type (jax types them by class)."""
    shape = getattr(a, "shape", None)
    dtype = getattr(a, "dtype", None)
    if shape is not None and dtype is not None:
        return (tuple(shape), str(dtype))
    return ("pytype", type(a).__name__)


def _static_key(static: Optional[Mapping[str, Any]]) -> tuple:
    if not static:
        return ()
    return tuple(sorted((k, repr(v)) for k, v in static.items()))


class ExecCacheStats:
    """Point-in-time snapshot of an :class:`ExecutableCache`'s counters."""

    __slots__ = ("hits", "misses", "compiles", "evictions", "compile_time_s",
                 "size")

    def __init__(self, hits: int, misses: int, compiles: int,
                 evictions: int, compile_time_s: float, size: int):
        self.hits = hits
        self.misses = misses
        self.compiles = compiles
        self.evictions = evictions
        self.compile_time_s = compile_time_s
        self.size = size

    def to_json(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "compiles": self.compiles, "evictions": self.evictions,
                "compile_time_s": self.compile_time_s, "size": self.size}

    def delta(self, since: "ExecCacheStats") -> "ExecCacheStats":
        """Counter movement between two snapshots of the *same* cache.

        ``size`` stays absolute (it is a level, not a counter).  This is
        how sessions report per-session cache activity without resetting
        the process-global cache under concurrent sessions: snapshot at
        ``tune()`` entry, ``stats.delta(entry_snapshot)`` at exit.
        """
        return ExecCacheStats(
            hits=self.hits - since.hits,
            misses=self.misses - since.misses,
            compiles=self.compiles - since.compiles,
            evictions=self.evictions - since.evictions,
            compile_time_s=self.compile_time_s - since.compile_time_s,
            size=self.size)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"ExecCacheStats(hits={self.hits}, misses={self.misses}, "
                f"compiles={self.compiles}, evictions={self.evictions}, "
                f"size={self.size})")


class _Entry:
    """One cache slot; ``ready`` gates waiters while the owner compiles."""

    __slots__ = ("ready", "executable", "error", "fn")

    def __init__(self, fn: Callable):
        self.ready = threading.Event()
        self.executable = None
        self.error: Optional[BaseException] = None
        self.fn = fn         # strong ref: keeps id(fn) stable while cached


class ExecutableCache:
    """LRU cache of AOT-compiled executables (see module docstring).

    ``capacity`` bounds the number of live executables — compiled code
    for large spaces is not free, and an unbounded cache would grow with
    every (config, shape) a campaign touches. Eviction is
    least-recently-used and never evicts an entry still compiling.
    """

    def __init__(self, capacity: int = 256,
                 fingerprint: Optional[str] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._fingerprint = fingerprint
        self._lock = threading.Lock()
        self._entries: "OrderedDict[tuple, _Entry]" = OrderedDict()
        self._hits = 0
        self._misses = 0
        self._compiles = 0
        self._evictions = 0
        self._compile_time_s = 0.0

    # -- keying ---------------------------------------------------------------
    def _device_fingerprint(self) -> str:
        if self._fingerprint is None:
            from .cache import hardware_fingerprint
            self._fingerprint = hardware_fingerprint()
        return self._fingerprint

    def key_for(self, fn: Callable, args: Sequence[Any],
                static: Optional[Mapping[str, Any]] = None) -> tuple:
        """The cache key: kernel identity x static config x operand
        shapes/dtypes x device fingerprint."""
        ident = (getattr(fn, "__module__", ""),
                 getattr(fn, "__qualname__", repr(fn)), id(fn))
        return (ident, _static_key(static),
                tuple(_arg_key(a) for a in args),
                self._device_fingerprint())

    # -- the cache ------------------------------------------------------------
    def compile(self, fn: Callable, args: Sequence[Any],
                static: Optional[Mapping[str, Any]] = None):
        """Compiled executable for ``fn`` at these operands.

        ``args`` are example operands — concrete arrays or
        ``jax.ShapeDtypeStruct``s (nothing is executed, only lowered).
        ``static`` holds config keywords compiled into the executable
        (tile sizes, flags); for an already-jitted ``fn`` they must be
        declared in its ``static_argnames``. The first call per key
        compiles; every later call (any thread) returns the same
        executable.
        """
        key = self.key_for(fn, args, static)
        owner = False
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
                self._hits += 1
            else:
                entry = _Entry(fn)
                self._entries[key] = entry
                self._misses += 1
                owner = True
        if not owner:
            if entry.ready.is_set():
                trace_instant("exec_cache_hit",
                              fn=getattr(fn, "__qualname__", repr(fn)))
            else:                  # racing a compile in flight: dedup-wait
                trace_instant("exec_cache_dedup",
                              fn=getattr(fn, "__qualname__", repr(fn)))
            entry.ready.wait()     # hit, possibly still compiling elsewhere
            if entry.error is not None:
                raise entry.error
            return entry.executable
        try:
            with phase("compile"):
                t0 = time.perf_counter()
                entry.executable = self._lower_and_compile(fn, args, static)
                dt = time.perf_counter() - t0
            with self._lock:
                self._compiles += 1
                self._compile_time_s += dt
        except BaseException as e:
            entry.error = e
            with self._lock:
                self._entries.pop(key, None)   # failed keys retry next time
            raise
        finally:
            entry.ready.set()
        self._evict()
        return entry.executable

    @staticmethod
    def _lower_and_compile(fn: Callable, args: Sequence[Any],
                           static: Optional[Mapping[str, Any]]):
        import jax
        kw = dict(static) if static else {}
        if hasattr(fn, "lower"):          # already jitted (Pallas wrappers)
            lowered = fn.lower(*args, **kw)
        else:
            lowered = jax.jit(fn, static_argnames=tuple(kw)).lower(*args,
                                                                   **kw)
        return lowered.compile()

    def _evict(self) -> None:
        with self._lock:
            while len(self._entries) > self.capacity:
                victim = None
                for k, e in self._entries.items():
                    if e.ready.is_set():
                        victim = k
                        break
                if victim is None:        # everything still compiling
                    break
                del self._entries[victim]
                self._evictions += 1

    # -- introspection --------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: tuple) -> bool:
        with self._lock:
            return key in self._entries

    @property
    def stats(self) -> ExecCacheStats:
        with self._lock:
            return ExecCacheStats(self._hits, self._misses, self._compiles,
                                  self._evictions, self._compile_time_s,
                                  len(self._entries))

    def clear(self) -> None:
        """Drop every executable (counters survive — they are totals)."""
        with self._lock:
            self._entries.clear()


_DEFAULT: Optional[ExecutableCache] = None
_DEFAULT_LOCK = threading.Lock()


def default_cache() -> ExecutableCache:
    """The process-wide shared cache the benchmark factories use, so every
    session in one process reuses each other's executables."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = ExecutableCache()
        return _DEFAULT


class CompilePipeline:
    """Background compile worker overlapping compilation with measurement.

    The engine submits one zero-arg *precompile task* per pending trial
    (derived from the benchmark's ``precompile(config)`` hook, which
    warms the :class:`ExecutableCache` from ``ShapeDtypeStruct``s — no
    data is allocated). A single daemon worker drains the queue in
    proposal order, so while trial k runs on the measurement thread,
    trial k+1's executable is already compiling. The cache's in-flight
    deduplication guarantees a trial that overtakes the worker waits on
    — rather than duplicates — its compile.

    Task failures are recorded, not raised: a broken precompile surfaces
    on the trial itself with full context.
    """

    def __init__(self, name: str = "compile-pipeline"):
        self.name = name
        self._queue: list[Callable[[], None]] = []
        self._cv = threading.Condition()
        self._submitted = 0
        self._completed = 0
        self._failed = 0
        self._closed = False
        self._thread: Optional[threading.Thread] = None

    def _ensure_worker(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self._run,
                                            name=self.name, daemon=True)
            self._thread.start()

    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait()
                if not self._queue and self._closed:
                    return
                task = self._queue.pop(0)
            try:
                task()
            except Exception:
                with self._cv:
                    self._failed += 1
            else:
                with self._cv:
                    self._completed += 1
            with self._cv:
                self._cv.notify_all()

    def submit(self, task: Callable[[], None]) -> None:
        """Enqueue one precompile task (FIFO)."""
        with self._cv:
            if self._closed:
                raise RuntimeError("pipeline is closed")
            self._queue.append(task)
            self._submitted += 1
            self._cv.notify_all()
        self._ensure_worker()

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted task finished; False on timeout."""
        with self._cv:
            return self._cv.wait_for(
                lambda: self._completed + self._failed == self._submitted,
                timeout=timeout)

    def close(self, wait: bool = True) -> None:
        """Stop accepting tasks; optionally wait for the queue to drain."""
        with self._cv:
            self._closed = True
            if not wait:
                self._queue.clear()
            self._cv.notify_all()
        if wait and self._thread is not None and self._thread.is_alive():
            self._thread.join()

    @property
    def counts(self) -> tuple[int, int, int]:
        """(submitted, completed, failed)."""
        with self._cv:
            return self._submitted, self._completed, self._failed

    def __enter__(self) -> "CompilePipeline":
        return self

    def __exit__(self, *exc) -> bool:
        self.close(wait=True)
        return False

"""First-class search strategies: a propose/observe (ask/tell) layer.

The paper's contribution is the *search procedure* — state-space reduction
plus CI-pruned exhaustive evaluation — but exhaustive visiting is only one
policy. Benchmarking-suite work (*Towards a Benchmarking Suite for Kernel
Tuners*, arXiv:2303.08976) argues tuners should expose interchangeable
search strategies over one evaluation harness, and GEMM landscapes are
rugged enough that adaptive orderings matter. This module is that layer:

  * :class:`SearchStrategy` — the protocol. ``reset(space, settings,
    seeds)`` initializes a run, ``ask(n)`` proposes the next
    :class:`~repro.core.executor.Batch` (``n`` is the backend's preferred
    parallel width, a hint), ``tell(config, result)`` feeds an outcome
    back. The engine guarantees every outcome of a batch is told before
    the next ``ask`` — round-synchronized backends all-reduce the
    incumbent exactly at those boundaries.
  * :class:`ExhaustiveStrategy` — the paper's loop: canonical, reversed
    ("+R"), or seeded-random visit order over the whole space.
  * :class:`SuccessiveHalvingStrategy` — the former
    ``tune_successive_halving`` ported onto the protocol, so it now runs
    on every backend with caching, warm-start, and pruning accounting.
    Rungs raise the iteration budget by ``eta`` via per-batch settings
    overrides; CI-aware promotion is unchanged.
  * :class:`RandomSearchStrategy` — budgeted sampling without
    replacement, for spaces too large to exhaust.
  * :class:`NeighborhoodStrategy` — greedy steepest-ascent hill climbing
    over ``Param``-adjacent configurations, exploiting the ordered domains
    :class:`~repro.core.searchspace.SearchSpace` already declares.

Transfer tuning: every strategy accepts warm-start ``seeds`` — in-space
configurations (the engine projects foreign ones via
``SearchSpace.project``), typically another benchmark's cached incumbents
from ``TrialCache.suggest_seeds``. Exhaustive/random front-load them;
neighborhood starts its climb from the best of them.

Strategy instances are reusable (``reset`` reinitializes) but not
concurrently shareable: one instance drives one ``Tuner.tune`` at a time.
"""

from __future__ import annotations

import dataclasses
import random as _random
from typing import Optional, Sequence

from .cache import config_key
from .evaluator import EvalResult, EvaluationSettings
from .executor import Batch
from .searchspace import Config, SearchSpace
from .stop_conditions import Direction

__all__ = ["ExhaustiveStrategy", "NeighborhoodStrategy",
           "RandomSearchStrategy", "SearchStrategy",
           "SuccessiveHalvingStrategy"]


def _seeded_front(seeds: Sequence[Config],
                  rest: Sequence[Config]) -> list[Config]:
    """Seeds first (deduplicated), then the remaining configs in order."""
    seen = set()
    out: list[Config] = []
    for cfg in list(seeds) + list(rest):
        key = config_key(cfg)
        if key not in seen:
            seen.add(key)
            out.append(cfg)
    return out


class SearchStrategy:
    """Propose/observe search policy driven by the :class:`Tuner` engine.

    The engine calls ``reset`` once per run, then alternates ``ask`` /
    ``tell`` until ``ask`` returns ``None``. ``ask(n)`` receives the
    executing backend's round width — its all-reduce batch size — or
    ``None`` when the backend imposes no round structure (serial, thread
    pool), in which case the strategy should propose its full natural
    unit (remaining queue, current rung, neighbor round) so unconstrained
    backends never barrier mid-unit. The returned batch size is the
    strategy's choice either way; an empty batch is treated as
    exhaustion. Results served from the trial cache are told like fresh
    ones.
    """

    name: str = "base"

    @staticmethod
    def _cap(n: Optional[int], remaining: int) -> int:
        """Batch size for a round width of ``n`` (``None``/0 — take all)."""
        return max(1, min(n, remaining)) if n else remaining

    @property
    def order_label(self) -> str:
        """Search-order tag recorded on :class:`TuningResult` (the paper's
        table rows key on it; only the exhaustive strategy varies it)."""
        return self.name

    def reset(self, space: SearchSpace, settings: EvaluationSettings,
              seeds: Sequence[Config] = ()) -> None:
        raise NotImplementedError

    def ask(self, n: int) -> Optional[Batch]:
        raise NotImplementedError

    def tell(self, config: Config, result: EvalResult) -> None:
        pass


class QueueStrategy(SearchStrategy):
    """Shared machinery for strategies that drain a pre-planned queue:
    ``reset`` fills ``_queue`` via :meth:`_plan`, ``ask`` slices it."""

    def __init__(self):
        self._queue: list[Config] = []
        self._pos = 0

    def _plan(self, space: SearchSpace,
              seeds: Sequence[Config]) -> list[Config]:
        raise NotImplementedError

    def reset(self, space, settings, seeds=()):
        self._queue = self._plan(space, seeds)
        self._pos = 0

    def ask(self, n):
        if self._pos >= len(self._queue):
            return None
        take = self._cap(n, len(self._queue) - self._pos)
        batch = self._queue[self._pos:self._pos + take]
        self._pos += len(batch)
        return Batch(tuple(batch))


class ExhaustiveStrategy(QueueStrategy):
    """The paper's search: visit every configuration once, in canonical,
    reversed ("+R" ablation), or seeded-random order. Warm-start seeds are
    moved to the front of the queue so a transferred incumbent is measured
    (and starts pruning) first."""

    name = "exhaustive"

    def __init__(self, order: str = "exhaustive", seed: Optional[int] = None):
        super().__init__()
        if order not in ("exhaustive", "reverse", "random"):
            raise ValueError(f"unknown order {order!r}")
        self.order = order
        self.seed = seed

    @property
    def order_label(self) -> str:
        return self.order

    def _plan(self, space, seeds):
        return _seeded_front(seeds, space.ordered(self.order, seed=self.seed))


class RandomSearchStrategy(QueueStrategy):
    """Budgeted random sampling without replacement — for spaces too large
    to exhaust. With a budget, the sample is drawn by reservoir over the
    constraint-filtered enumeration (O(budget) memory, no materialized
    space); seeds are evaluated first and count against the budget."""

    name = "random"

    def __init__(self, budget: Optional[int] = None, seed: Optional[int] = None):
        super().__init__()
        if budget is not None and budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        self.budget = budget
        self.seed = seed

    def _plan(self, space, seeds):
        if self.budget is None:
            return _seeded_front(seeds, space.ordered("random",
                                                      seed=self.seed))
        rng = _random.Random(self.seed if self.seed is not None else 0)
        reservoir: list[Config] = []
        for i, cfg in enumerate(space.configs()):
            if len(reservoir) < self.budget:
                reservoir.append(cfg)
            else:
                j = rng.randrange(i + 1)
                if j < self.budget:
                    reservoir[j] = cfg
        rng.shuffle(reservoir)          # visit order independent of draw
        return _seeded_front(seeds, reservoir)[:self.budget]


class SuccessiveHalvingStrategy(SearchStrategy):
    """Successive halving with CI-informed promotion (DESIGN.md §8.3),
    ported from the former ``tune_successive_halving`` loop.

    Rung *r* evaluates the survivors with an iteration budget that grows
    by ``eta`` per rung (a per-batch settings override:
    ``max_invocations=1, max_iterations=budget``); only the top ``1/eta``
    advance, where a configuration survives if its CI bound facing the
    cutoff still reaches it (the paper's Listing-1 logic as a promoter).
    Stop condition 4 still prunes doomed configs inside a rung — against
    the engine's shared incumbent cell, so on concurrent backends the
    pruning reference is the live (or round-frozen) global best.

    Because rung budgets differ from the tuner's base settings, rung
    evaluations are never *served* from the trial cache (they would
    truncate deeper rungs) and never seed a future session's incumbent
    (warm-start demands settings parity). They are still persisted under
    their own settings fingerprint — coexisting with, never shadowing,
    full-budget records of the same configs — feeding the dashboards and
    ``suggest_seeds`` transfer hints.
    """

    name = "halving"

    def __init__(self, eta: int = 3, min_iterations: int = 4):
        if eta < 2:
            raise ValueError(f"eta must be >= 2, got {eta}")
        if min_iterations < 1:
            raise ValueError(
                f"min_iterations must be >= 1, got {min_iterations}")
        self.eta = eta
        self.min_iterations = min_iterations

    def reset(self, space, settings, seeds=()):
        self._base = settings
        self._direction = settings.direction
        self._budget = self.min_iterations
        self._done = False
        self._start_rung(_seeded_front(seeds, space.ordered("exhaustive")))

    def _start_rung(self, survivors: list[Config]) -> None:
        self._pending = list(survivors)
        self._awaiting = len(survivors)
        self._scored: list[tuple[Config, EvalResult]] = []
        self._rung_settings = dataclasses.replace(
            self._base, max_invocations=1, max_iterations=self._budget)

    def ask(self, n):
        if self._done or not self._pending:
            return None
        batch = self._pending[:self._cap(n, len(self._pending))]
        del self._pending[:len(batch)]
        return Batch(tuple(batch), settings=self._rung_settings)

    def tell(self, config, result):
        if self._done:
            return
        if not result.pruned:
            self._scored.append((config, result))
        self._awaiting -= 1
        if self._awaiting == 0 and not self._pending:
            self._close_rung()

    def _close_rung(self) -> None:
        from .confidence import ci_mean
        from .welford import WelfordState

        direction = self._direction
        scored = self._scored
        if len(scored) <= 1:
            self._done = True
            return
        scored.sort(key=lambda cr: cr[1].score,
                    reverse=(direction is Direction.MAXIMIZE))
        keep = max(1, len(scored) // self.eta)
        cutoff = scored[keep - 1][1].score
        kept = []
        for cfg, res in scored:
            # CI-aware promotion: survive if the CI bound facing the cutoff
            # still reaches it
            state = WelfordState(count=float(res.total_samples),
                                 mean=res.score,
                                 m2=sum(i.m2 for i in res.invocations))
            interval = ci_mean(state, self._base.confidence)
            bound = interval.hi if direction is Direction.MAXIMIZE \
                else interval.lo
            if direction.better(bound, cutoff) or bound == cutoff or \
                    res.score == cutoff or direction.better(res.score,
                                                            cutoff):
                kept.append(cfg)
        survivors = kept[:max(1, len(scored) // self.eta)] \
            if len(kept) > len(scored) // self.eta else kept
        if len(survivors) <= 1:
            self._done = True
            return
        self._budget *= self.eta
        self._start_rung(survivors)


class NeighborhoodStrategy(SearchStrategy):
    """Greedy steepest-ascent hill climbing over ``Param``-adjacent
    configurations.

    Each round evaluates the unvisited neighbors of the current center —
    configurations differing by one step along one parameter's ordered
    domain — and moves to the best improving one; the climb stops at a
    local optimum or when ``budget`` evaluations have been proposed. The
    first round evaluates the starting point(s): the warm-start seeds when
    given (transfer tuning starts the climb at a related benchmark's
    incumbent), else the space's canonical first configuration. Pruned
    results carry truncated scores and never become the center.
    """

    name = "neighborhood"

    def __init__(self, budget: Optional[int] = None):
        if budget is not None and budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        self.budget = budget

    def reset(self, space, settings, seeds=()):
        self._space = space
        self._direction = settings.direction
        self._visited: set[str] = set()
        self._center: Optional[Config] = None
        self._center_score: Optional[float] = None
        self._round: list[tuple[Config, EvalResult]] = []
        self._awaiting = 0
        self._proposed = 0
        self._done = False
        starts = list(seeds)
        if not starts:
            first = next(space.configs(), None)
            if first is not None:
                starts = [first]
        self._pending = _seeded_front(starts, ())
        if not self._pending:
            self._done = True

    def _remaining_budget(self) -> Optional[int]:
        if self.budget is None:
            return None
        return self.budget - self._proposed

    def ask(self, n):
        if self._done or not self._pending:
            return None
        limit = self._cap(n, len(self._pending))
        remaining = self._remaining_budget()
        if remaining is not None:
            if remaining <= 0:
                self._done = True
                return None
            limit = min(limit, remaining)
        batch = self._pending[:limit]
        del self._pending[:len(batch)]
        for cfg in batch:
            self._visited.add(config_key(cfg))
        self._proposed += len(batch)
        self._awaiting += len(batch)
        return Batch(tuple(batch))

    def tell(self, config, result):
        if self._done:
            return
        self._visited.add(config_key(config))
        self._round.append((config, result))
        self._awaiting -= 1
        budget_left = self._remaining_budget()
        exhausted = budget_left is not None and budget_left <= 0
        if self._awaiting == 0 and (not self._pending or exhausted):
            self._close_round()

    def _best_of_round(self) -> Optional[tuple[Config, float]]:
        best: Optional[tuple[Config, float]] = None
        for cfg, res in self._round:
            if res.pruned:
                continue
            if best is None or self._direction.better(res.score, best[1]):
                best = (cfg, res.score)
        return best

    def _close_round(self) -> None:
        candidate = self._best_of_round()
        self._round = []
        improved = candidate is not None and (
            self._center_score is None
            or self._direction.better(candidate[1], self._center_score))
        budget_left = self._remaining_budget()
        if not improved or (budget_left is not None and budget_left <= 0):
            self._done = True
            return
        self._center, self._center_score = candidate
        self._pending = self._neighbors(self._center)
        if not self._pending:
            self._done = True

    def _neighbors(self, center: Config) -> list[Config]:
        out: list[Config] = []
        for p in self._space.params:
            try:
                idx = p.values.index(center[p.name])
            except (KeyError, ValueError):
                continue
            for step in (-1, 1):
                j = idx + step
                if not 0 <= j < len(p.values):
                    continue
                cfg = dict(center)
                cfg[p.name] = p.values[j]
                if config_key(cfg) in self._visited:
                    continue
                if not self._space._satisfies(cfg):
                    continue
                out.append(cfg)
        return out

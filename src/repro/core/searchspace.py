"""Declarative search spaces with constraint specification (paper Sec. IV).

The paper stresses that "the definition and reduction of the search space is
critical for autotuning" and walks through an explicit cardinality reduction
for DGEMM: |S| = 7*7*11 = 539 (powers of two) -> narrowed ranges ->
4*4*6 = 96, with leading dimensions adjusted to multiples of 2 (500, 1000,
2000, 4000) per Intel's MKL guidance. This module makes those manipulations
first-class: spaces are declarative, constraints are explicit predicates, and
cardinality is always reportable.
"""

from __future__ import annotations

import dataclasses
import itertools
import numbers
import random as _random
from typing import Any, Callable, Iterator, Mapping, Optional, Sequence

Config = dict[str, Any]
Constraint = Callable[[Config], bool]


@dataclasses.dataclass(frozen=True)
class Param:
    """One discrete tunable with an ordered value domain."""

    name: str
    values: tuple

    def __post_init__(self):
        if len(self.values) == 0:
            raise ValueError(f"param {self.name!r} has an empty domain")
        if len(set(self.values)) != len(self.values):
            raise ValueError(f"param {self.name!r} has duplicate values")

    @property
    def cardinality(self) -> int:
        return len(self.values)


def param(name: str, values: Sequence) -> Param:
    return Param(name=name, values=tuple(values))


def powers_of_two(lo: int, hi: int) -> tuple[int, ...]:
    """Inclusive power-of-two ladder, e.g. (64, 128, ..., 4096)."""
    out = []
    v = 1
    while v < lo:
        v *= 2
    while v <= hi:
        out.append(v)
        v *= 2
    return tuple(out)


def doubling_from(start: int, hi: int) -> tuple[int, ...]:
    """Doubling ladder from an arbitrary start: 500, 1000, 2000, 4000 — the
    paper's multiple-of-2 leading-dimension adjustment."""
    out = []
    v = start
    while v <= hi:
        out.append(v)
        v *= 2
    return tuple(out)


class SearchSpace:
    """Cartesian product of :class:`Param` domains filtered by constraints."""

    def __init__(self, params: Sequence[Param],
                 constraints: Sequence[Constraint] = ()):
        names = [p.name for p in params]
        if len(set(names)) != len(names):
            raise ValueError("duplicate parameter names")
        self.params = tuple(params)
        self.constraints = tuple(constraints)
        self._cardinality: Optional[int] = None   # filtered-count cache

    # -- construction helpers -------------------------------------------------
    def constrain(self, *constraints: Constraint) -> "SearchSpace":
        """Return a new space with additional constraints (paper's
        'constraint specification')."""
        return SearchSpace(self.params, self.constraints + tuple(constraints))

    def narrow(self, **bounds: tuple) -> "SearchSpace":
        """Return a new space with some parameter domains replaced — the
        paper's range-narrowing reduction (e.g. n: 64..4096 -> 512..4096)."""
        by_name = {p.name: p for p in self.params}
        for name, values in bounds.items():
            if name not in by_name:
                raise KeyError(name)
            by_name[name] = param(name, values)
        return SearchSpace(tuple(by_name.values()), self.constraints)

    # -- enumeration ----------------------------------------------------------
    @property
    def names(self) -> tuple[str, ...]:
        """Parameter names in declaration order."""
        return tuple(p.name for p in self.params)

    @property
    def raw_cardinality(self) -> int:
        """|S| before constraint filtering (the paper's Eq. 8 number)."""
        n = 1
        for p in self.params:
            n *= p.cardinality
        return n

    @property
    def cardinality(self) -> int:
        """|S| after constraint filtering. Enumerative — the paper's premise
        is that autotuning-benchmark spaces are deliberately low-cardinality
        — but computed once: params/constraints are immutable, and reports
        read this per render."""
        if self._cardinality is None:
            self._cardinality = sum(1 for _ in self.configs())
        return self._cardinality

    def _satisfies(self, cfg: Config) -> bool:
        return all(c(cfg) for c in self.constraints)

    def satisfies(self, cfg: Config) -> bool:
        """True iff ``cfg`` passes every constraint predicate (domain
        membership is *not* checked; see ``__contains__`` for both)."""
        return self._satisfies(cfg)

    def __contains__(self, cfg: object) -> bool:
        """True iff ``cfg`` assigns every parameter a value from its domain
        and satisfies all constraints."""
        if not isinstance(cfg, Mapping):
            return False
        if set(cfg) != {p.name for p in self.params}:
            return False
        if any(cfg[p.name] not in p.values for p in self.params):
            return False
        return self._satisfies(dict(cfg))

    def project(self, cfg: Mapping) -> Optional[Config]:
        """Nearest in-space configuration — the transfer-tuning seed
        projection. Parameters present in ``cfg`` keep their value when it
        is in the domain, snap to the numerically nearest domain value
        otherwise; missing or non-numeric mismatches fall back to the
        domain's first value. Returns ``None`` when the projection
        violates a constraint (the seed is unusable here)."""
        out: Config = {}
        for p in self.params:
            v = cfg.get(p.name)
            if v in p.values:
                out[p.name] = v
                continue
            numeric = (isinstance(v, numbers.Real)
                       and not isinstance(v, bool)
                       and all(isinstance(d, numbers.Real)
                               and not isinstance(d, bool)
                               for d in p.values))
            out[p.name] = min(p.values, key=lambda d: abs(d - v)) \
                if numeric else p.values[0]
        return out if self._satisfies(out) else None

    def configs(self) -> Iterator[Config]:
        """Canonical (row-major) enumeration order."""
        names = [p.name for p in self.params]
        for combo in itertools.product(*[p.values for p in self.params]):
            cfg = dict(zip(names, combo))
            if self._satisfies(cfg):
                yield cfg

    def ordered(self, order: str = "exhaustive",
                seed: Optional[int] = None) -> list[Config]:
        """Materialized search order.

        ``exhaustive``: canonical order; ``reverse``: the paper's "R"
        ablation (large/slow configurations first — stresses how pruning
        effectiveness depends on when a good incumbent is found);
        ``random``: seeded shuffle.
        """
        cfgs = list(self.configs())
        if order == "exhaustive":
            return cfgs
        if order == "reverse":
            return cfgs[::-1]
        if order == "random":
            rng = _random.Random(seed if seed is not None else 0)
            rng.shuffle(cfgs)
            return cfgs
        raise ValueError(f"unknown order {order!r}")

    def __repr__(self) -> str:
        doms = ", ".join(f"{p.name}[{p.cardinality}]" for p in self.params)
        return (f"SearchSpace({doms}, raw={self.raw_cardinality}, "
                f"constraints={len(self.constraints)})")


def grid(**domains: Sequence) -> SearchSpace:
    """Shorthand: ``grid(n=(1, 2), m=(3, 4))``."""
    return SearchSpace([param(k, v) for k, v in domains.items()])

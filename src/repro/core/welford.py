"""Welford online moment accumulation (paper Eq. 5-7) + parallel merge.

The paper (Sec. III-C.3) uses Welford's online algorithm [Welford 1962] to
track the running mean and corrected sum of squares of benchmark samples
without storing them, so that a confidence interval can be computed after
every sample and the evaluation loop terminated as early as possible.

We provide:
  * ``WelfordState`` — an immutable snapshot (n, mean, m2) usable from plain
    Python and inside jitted JAX code (it is a pytree).
  * ``update``      — one-sample Welford step (Eq. 6/7).
  * ``merge``       — exact pairwise combination of two partial streams
    (Chan, Golub & LeVeque 1979). This is the beyond-paper piece that lets a
    fleet of workers benchmark shards of a search space and reduce their
    moment statistics exactly (see ``repro.distributed.tuner``).
  * ``from_samples`` — bulk construction (two-pass, for tests/oracles).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WelfordState:
    """Running moments of a scalar sample stream.

    Attributes:
      count: number of samples accumulated (float so it jits cleanly).
      mean:  running sample mean  (paper Eq. 6).
      m2:    corrected sum of squares C_n = sum (x_i - mean)^2 (paper Eq. 7).
    """

    count: jax.Array | float
    mean: jax.Array | float
    m2: jax.Array | float

    # ---- derived quantities -------------------------------------------------
    @property
    def variance(self):
        """Unbiased sample variance S^2 = C / (n - 1) (paper Eq. 5)."""
        n = self.count
        if isinstance(n, (int, float)):
            return self.m2 / (n - 1.0) if n > 1 else 0.0
        return jnp.where(n > 1, self.m2 / jnp.maximum(n - 1.0, 1.0), 0.0)

    @property
    def std(self):
        v = self.variance
        if isinstance(v, (int, float)):
            return math.sqrt(max(v, 0.0))
        return jnp.sqrt(jnp.maximum(v, 0.0))

    @property
    def sem(self):
        """Standard error of the mean."""
        n = self.count
        if isinstance(n, (int, float)):
            return self.std / math.sqrt(n) if n > 0 else float("inf")
        return jnp.where(n > 0, self.std / jnp.sqrt(jnp.maximum(n, 1.0)), jnp.inf)

    @property
    def cov(self):
        """Coefficient of variation (Georges et al. steady-state detector)."""
        m = self.mean
        if isinstance(m, (int, float)):
            return self.std / abs(m) if m != 0 else float("inf")
        return jnp.where(m != 0, self.std / jnp.abs(m), jnp.inf)


def init() -> WelfordState:
    """Empty accumulator (base case of paper Eq. 6/7: C_1 = 0, m_1 = x_1)."""
    return WelfordState(count=0.0, mean=0.0, m2=0.0)


def update(state: WelfordState, x) -> WelfordState:
    """One Welford step: fold sample ``x`` into ``state``.

    Implements the recurrences (paper Eq. 6 and Eq. 7):
        m_n = m_{n-1} + (x_n - m_{n-1}) / n
        C_n = C_{n-1} + (n-1)/n * (x_n - m_{n-1})^2
    Works both on Python floats and traced JAX scalars.
    """
    n = state.count + 1.0
    delta = x - state.mean
    mean = state.mean + delta / n
    # (n-1)/n * delta^2  ==  delta * (x - new_mean)
    m2 = state.m2 + delta * (x - mean)
    return WelfordState(count=n, mean=mean, m2=m2)


def merge(a: WelfordState, b: WelfordState) -> WelfordState:
    """Exactly combine two partial Welford streams (Chan et al. 1979).

    n   = n_a + n_b
    mu  = (n_a mu_a + n_b mu_b) / n
    M2  = M2_a + M2_b + delta^2 * n_a n_b / n

    This is associative and commutative up to fp error, so it is a valid
    operand for tree reductions and ``jax.lax`` collectives — the basis of the
    distributed tuner.
    """
    na, nb = a.count, b.count
    n = na + nb
    if isinstance(n, (int, float)) and n == 0:
        return init()
    delta = b.mean - a.mean
    safe_n = n if isinstance(n, (int, float)) else jnp.maximum(n, 1.0)
    mean = a.mean + delta * (nb / safe_n)
    m2 = a.m2 + b.m2 + delta * delta * (na * nb / safe_n)
    if not isinstance(n, (int, float)):
        # Guard the n == 0 case under tracing.
        mean = jnp.where(n > 0, mean, 0.0)
        m2 = jnp.where(n > 0, m2, 0.0)
    return WelfordState(count=n, mean=mean, m2=m2)


def from_samples(samples: Iterable[float]) -> WelfordState:
    """Fold an iterable of samples (reference path; used by tests as oracle)."""
    state = init()
    for x in samples:
        state = update(state, float(x))
    return state


# ---- vectorized JAX variants -----------------------------------------------


def update_jax(state: WelfordState, x: jax.Array) -> WelfordState:
    """Alias of :func:`update`; provided for call-site clarity inside jit."""
    return update(state, x)


def batch_state(samples: jax.Array) -> WelfordState:
    """Welford state of a whole array of samples, via ``lax.scan`` (jittable)."""

    def body(carry, x):
        return update(carry, x), None

    zero = WelfordState(count=jnp.zeros(()), mean=jnp.zeros(()), m2=jnp.zeros(()))
    out, _ = jax.lax.scan(body, zero, samples.astype(jnp.float32))
    return out


def tree_merge(states: list[WelfordState]) -> WelfordState:
    """Pairwise tree reduction of many partial states (numerically preferred
    over a left fold when the partials have very different counts)."""
    if not states:
        return init()
    layer = list(states)
    while len(layer) > 1:
        nxt = [merge(layer[i], layer[i + 1]) for i in range(0, len(layer) - 1, 2)]
        if len(layer) % 2:
            nxt.append(layer[-1])
        layer = nxt
    return layer[0]

"""Sweep campaigns: tune a benchmark family across a grid of shapes.

The paper tunes each benchmark shape in isolation; "From Roofline to
Ruggedness" shows adjacent GEMM shapes can differ enough that per-shape
tuning is mandatory, and exhaustive per-shape search cannot scale. A
:class:`SweepCampaign` walks a *shape grid* (itself a
:class:`~repro.core.searchspace.SearchSpace` — same declarative layer as
config spaces) and tunes each shape through a full
:class:`~repro.core.cache.TuningSession`, so the existing machinery does
all the heavy lifting:

  * every shape gets its own benchmark namespace
    (``"<base>@<shape_key>"``, :mod:`repro.sweep.shapes`) in **one shared
    cache file** — resumable per shape, reportable as one campaign;
  * every completed shape appends a ledger record (strategy ``"sweep"``,
    ``campaign=<name>``), so history dashboards grow one trend series per
    shape;
  * each shape's :class:`~repro.sweep.strategy.SweepStrategy` is warmed
    with **per-fingerprint priors**: all cached trials of sibling shapes
    under this machine's hardware fingerprint, encoded with their own
    shape features. The first shape explores; later shapes start from the
    joint model and spend their budget refining.

After (or during) a campaign, :meth:`SweepCampaign.oracle` builds the
dispatch-time :class:`~repro.sweep.oracle.ConfigOracle` over the
campaign's cache.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
from pathlib import Path
from typing import Callable, Optional, Sequence

from repro.core.cache import AUTO_LEDGER, TrialCache, TuningSession
from repro.core.evaluator import EvaluationSettings
from repro.core.profiling import trace_span
from repro.core.searchspace import Config, SearchSpace
from repro.core.tuner import TrialRecord, Tuner, TuningResult

from .oracle import ConfigOracle
from .shapes import SHAPE_SEP, shape_benchmark_name, shape_key, \
    split_benchmark_name
from .strategy import Prior, SweepStrategy

__all__ = ["CampaignResult", "ShapeOutcome", "SweepCampaign"]

#: a benchmark family: shape → benchmark factory (config → invocation factory)
BenchmarkFamily = Callable[[Config], Callable]


@dataclasses.dataclass(frozen=True)
class ShapeOutcome:
    """One swept shape's tuning outcome."""

    shape: Config
    benchmark: str          # cache/ledger namespace ("<base>@<shape_key>")
    result: object          # the session's TuningResult

    @property
    def n_trials(self) -> int:
        return len(self.result.trials)


@dataclasses.dataclass(frozen=True)
class CampaignResult:
    """Outcome of one :meth:`SweepCampaign.run`."""

    name: str
    base: str
    outcomes: tuple[ShapeOutcome, ...]
    trace_path: Optional[str] = None   # campaign trace JSONL, when traced

    @property
    def total_trials(self) -> int:
        """Trials across all swept shapes (including cache-served ones)."""
        return sum(o.n_trials for o in self.outcomes)

    def outcome_for(self, shape: Config) -> Optional[ShapeOutcome]:
        want = shape_key(shape)
        for o in self.outcomes:
            if shape_key(o.shape) == want:
                return o
        return None


class SweepCampaign:
    """Tunes ``family`` over every shape of ``shape_space``.

    ``family`` maps a shape to a benchmark factory (the shape-specialized
    objective); ``config_space`` is shared by all shapes. ``name`` is the
    session/cache name (one ``<cache_dir>/<name>.jsonl`` holds the whole
    campaign) and the ledger's campaign stamp; ``base`` (default: the
    campaign name) prefixes per-shape benchmark names.
    ``budget_per_shape`` caps each shape's proposals — the whole point of
    the sweep layer is that this can sit far below the config space's
    cardinality once priors kick in. Campaigns are resumable exactly like
    sessions: a killed ``run()`` re-serves finished shapes from cache.
    """

    def __init__(self, config_space: SearchSpace, shape_space: SearchSpace,
                 family: BenchmarkFamily, settings: EvaluationSettings,
                 name: str = "sweep", base: Optional[str] = None,
                 cache_dir: str | os.PathLike = ".tuning_sessions",
                 budget_per_shape: Optional[int] = None,
                 model: str = "ridge", acquisition: str = "ei",
                 seed: Optional[int] = 0,
                 fingerprint: Optional[str] = None,
                 ledger=AUTO_LEDGER, validate: str = "warn"):
        if base is not None and SHAPE_SEP in base:
            raise ValueError(f"base name {base!r} contains {SHAPE_SEP!r}")
        self.config_space = config_space
        self.shape_space = shape_space
        self.family = family
        self.settings = settings
        self.name = name
        self.base = base or name
        self.cache_dir = Path(cache_dir)
        self.budget_per_shape = budget_per_shape
        self.model = model
        self.acquisition = acquisition
        self.seed = seed
        self.fingerprint = fingerprint
        self.ledger = ledger
        self.validate = validate

    @property
    def cache_path(self) -> Path:
        return self.cache_dir / f"{self.name}.jsonl"

    def _cache(self) -> TrialCache:
        return TrialCache(self.cache_path, fingerprint=self.fingerprint)

    def priors(self, exclude: Optional[Config] = None) -> list[Prior]:
        """(shape, config, score) triples from every cached sibling trial
        under this machine's fingerprint — what warms each shape's
        surrogate. Pruned trials are included (truncated means are noisier
        but unbiased; see ``SurrogateStrategy.tell``); ``exclude`` drops
        one shape's own trials (its session serves those from cache
        directly)."""
        cache = self._cache()
        skip = shape_key(exclude) if exclude is not None else None
        out: list[Prior] = []
        for bench in cache.benchmarks(prefix=self.base + SHAPE_SEP):
            _, shape = split_benchmark_name(bench)
            if shape is None or shape_key(shape) == skip:
                continue
            for _, cfg, res in cache.items(bench):
                out.append((shape, cfg, float(res.score)))
        return out

    def session_for(self, shape: Config, priors: Sequence[Prior] = (),
                    seed_offset: int = 0) -> TuningSession:
        """The :class:`TuningSession` that tunes one shape — exposed so a
        caller can drive shapes manually (distributed campaigns)."""
        strategy = SweepStrategy(
            shape, self.shape_space, priors=priors,
            budget=self.budget_per_shape, model=self.model,
            acquisition=self.acquisition,
            seed=None if self.seed is None else self.seed + seed_offset)
        tuner = Tuner(self.config_space, self.settings, strategy=strategy)
        return TuningSession(
            self.name, tuner, self.family(shape),
            cache_dir=self.cache_dir,
            benchmark_name=shape_benchmark_name(self.base, shape),
            fingerprint=self.fingerprint, ledger=self.ledger,
            campaign=self.name)

    def _finished_result(self, benchmark: str,
                         cache: TrialCache) -> Optional[TuningResult]:
        """A budget-complete shape's outcome, served straight from cache.
        Proposals are prior-dependent, so a resumed campaign re-running a
        finished shape would propose under a *richer* prior set than the
        original run and spend fresh trials on a diverged sequence —
        instead, a shape whose cached trial count already meets
        ``budget_per_shape`` is replayed without touching the tuner (and
        without appending a duplicate ledger record)."""
        if self.budget_per_shape is None:
            return None
        rows = cache.items(benchmark)
        if len(rows) < self.budget_per_shape:
            return None
        if any(cfg not in self.config_space for _, cfg, _ in rows):
            # the namespace holds another config space's trials (e.g. a
            # cache reused across benchmark families) — tune normally and
            # let the session layer serve only matching config keys
            return None
        direction = self.settings.direction
        trials = tuple(TrialRecord(config=cfg, result=res, cached=True)
                       for _, cfg, res in rows)
        best = None
        for t in trials:
            if t.result.pruned:
                continue
            if best is None or direction.better(t.result.score,
                                                best.result.score):
                best = t
        return TuningResult(
            best_config=None if best is None else dict(best.config),
            best_score=None if best is None else float(best.result.score),
            trials=trials,
            total_time_s=0.0,
            total_samples=sum(t.result.total_samples for t in trials),
            n_pruned=sum(1 for t in trials if t.result.pruned),
            settings_label=self.settings.label(),
            order=SweepStrategy.name,
            n_cached=len(trials),
            strategy=SweepStrategy.name,
        )

    def run(self, shapes: Optional[Sequence[Config]] = None,
            holdout: Sequence[Config] = (), backend=None,
            timestamp: Optional[float] = None,
            progress=None,
            trace: "bool | str | os.PathLike" = False) -> CampaignResult:
        """Tune every shape (grid order), skipping ``holdout`` shapes —
        the oracle-evaluation protocol tunes the grid minus one shape and
        asks the oracle about the one it never saw. ``backend``,
        ``timestamp`` and ``progress`` are forwarded to each session's
        ``run``; priors are re-collected from the shared cache before
        each shape, so shape *i* benefits from shapes 0..i-1 (and from
        any earlier campaign run into the same cache).

        ``trace`` records the whole campaign into one span trace
        (``True`` → ``<cache_dir>/<name>.trace.jsonl``, or pass a path):
        a ``campaign`` root span with one ``shape`` span per tuned shape,
        each enclosing that shape's session/trial spans. If a recorder is
        already installed (an enclosing harness), it is reused and the
        flag only adds the campaign/shape spans."""
        held = {shape_key(s) for s in holdout}
        todo = [s for s in (shapes if shapes is not None
                            else self.shape_space.ordered("exhaustive"))
                if shape_key(s) not in held]
        outcomes: list[ShapeOutcome] = []
        trace_path: Optional[str] = None
        with contextlib.ExitStack() as stack:
            from repro.obs.trace import TraceRecorder, recorder
            if trace and recorder() is None:
                path = (self.cache_dir / f"{self.name}.trace.jsonl"
                        if trace is True else Path(trace))
                stack.enter_context(
                    TraceRecorder(path, session=self.name,
                                  meta={"campaign": self.name,
                                        "base": self.base}))
            active = recorder()
            if active is not None and getattr(active, "path", None):
                trace_path = str(active.path)
            with trace_span("campaign", cat="session", context=True,
                            campaign=self.name, base=self.base,
                            n_shapes=len(todo)) as cspan:
                for j, shape in enumerate(todo):
                    bench = shape_benchmark_name(self.base, shape)
                    result = self._finished_result(bench, self._cache())
                    with trace_span("shape", cat="shape", context=True,
                                    shape=dict(shape),
                                    benchmark=bench) as sspan:
                        if result is None:
                            session = self.session_for(
                                shape, priors=self.priors(exclude=shape),
                                seed_offset=j)
                            result = session.run(backend=backend,
                                                 timestamp=timestamp,
                                                 progress=progress,
                                                 validate=self.validate)
                        else:
                            sspan.set(served_from_cache=True)
                        sspan.set(n_trials=len(result.trials),
                                  best_score=result.best_score)
                    outcomes.append(ShapeOutcome(shape=dict(shape),
                                                 benchmark=bench,
                                                 result=result))
                cspan.set(total_trials=sum(len(o.result.trials)
                                           for o in outcomes))
        return CampaignResult(name=self.name, base=self.base,
                              outcomes=tuple(outcomes),
                              trace_path=trace_path)

    def oracle(self, model: Optional[str] = None,
               min_shapes: int = 2) -> ConfigOracle:
        """The dispatch-time config oracle over this campaign's cache."""
        return ConfigOracle(self.config_space, self.shape_space,
                            self._cache(), base=self.base,
                            direction=self.settings.direction,
                            model=model or self.model,
                            min_shapes=min_shapes)

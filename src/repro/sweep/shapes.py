"""Canonical shape keys: how a problem shape names its cache namespace.

A sweep campaign tunes one benchmark *family* across a grid of problem
shapes. Each shape gets its own benchmark name in the shared trial cache
and run ledger — ``"<base>@<shape_key>"`` — so per-shape warm starts,
incumbents, and history series stay isolated (the cache already keys
everything by benchmark name) while one file still holds the whole
campaign. The key is a sorted ``name=value`` join, order-insensitive like
:func:`repro.core.cache.config_key` but readable in dashboards:
``dgemm@m=512,n=1024``.

Values round-trip through ``int`` → ``float`` → ``str`` on parse, which
covers every domain the search-space layer produces; string values must
not contain the separators (enforced at key time, not parse time).
"""

from __future__ import annotations

from typing import Optional

from repro.core.searchspace import Config

__all__ = ["SHAPE_SEP", "parse_shape_key", "shape_benchmark_name",
           "shape_key", "split_benchmark_name"]

#: separates the family base name from the shape key in benchmark names
SHAPE_SEP = "@"


def shape_key(shape: Config) -> str:
    """Canonical, order-insensitive key of one shape: ``"k=64,m=512"``."""
    parts = []
    for name in sorted(shape):
        v = shape[name]
        text = f"{v}"
        if any(sep in f"{name}{text}" for sep in (",", "=", SHAPE_SEP)):
            raise ValueError(f"shape entry {name}={v!r} contains a "
                             "reserved separator")
        parts.append(f"{name}={text}")
    if not parts:
        raise ValueError("empty shape")
    return ",".join(parts)


def _parse_value(raw: str):
    for cast in (int, float):
        try:
            return cast(raw)
        except ValueError:
            continue
    return raw


def parse_shape_key(key: str) -> Config:
    """Inverse of :func:`shape_key` (up to numeric formatting)."""
    shape: Config = {}
    for part in key.split(","):
        name, sep, raw = part.partition("=")
        if not sep or not name:
            raise ValueError(f"malformed shape key {key!r}")
        shape[name] = _parse_value(raw)
    return shape


def shape_benchmark_name(base: str, shape: Config) -> str:
    """The cache/ledger benchmark name of one swept shape."""
    if SHAPE_SEP in base:
        raise ValueError(f"base name {base!r} contains {SHAPE_SEP!r}")
    return f"{base}{SHAPE_SEP}{shape_key(shape)}"


def split_benchmark_name(name: str) -> tuple[str, Optional[Config]]:
    """(base, shape) of a benchmark name; shape is ``None`` for plain
    (non-sweep) names."""
    base, sep, key = name.partition(SHAPE_SEP)
    if not sep:
        return name, None
    return base, parse_shape_key(key)

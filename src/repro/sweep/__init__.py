"""Shape-sweep campaigns and the dispatch-time config oracle.

The paper tunes fixed benchmark shapes; production GEMMs arrive with
whatever (M, N, K) the workload dictates, and adjacent shapes can differ
enough that one tuned config does not fit all ("From Roofline to
Ruggedness"). This package turns the single-shape tuner into a
shape-generalizing service:

  * :mod:`~repro.sweep.shapes` — canonical shape keys: each swept shape
    owns a ``"<base>@<shape_key>"`` namespace in the shared trial cache
    and run ledger;
  * :mod:`~repro.sweep.strategy` — :class:`SweepStrategy`, the surrogate
    strategy over the *joint* shape×config feature space, warmed with
    per-fingerprint priors from sibling shapes' cached trials;
  * :mod:`~repro.sweep.campaign` — :class:`SweepCampaign`, one
    :class:`~repro.core.cache.TuningSession` per grid shape into one
    cache file and one ledger (strategy ``"sweep"``, stamped with the
    campaign name);
  * :mod:`~repro.sweep.oracle` — :class:`ConfigOracle`, answering "best
    config for an *unseen* shape" by surrogate interpolation over the
    cache, falling back to the nearest tuned shape's incumbent
    (Spearman/distance-ranked, mirroring ``TrialCache.rank_donors``)
    while the model is cold.

CLI: ``scripts/sweep.py``. Format and semantics: ``docs/sweeps.md``.
"""

from .campaign import CampaignResult, ShapeOutcome, SweepCampaign
from .oracle import ConfigOracle, OracleAnswer
from .shapes import (SHAPE_SEP, parse_shape_key, shape_benchmark_name,
                     shape_key, split_benchmark_name)
from .strategy import SweepStrategy

__all__ = [
    "CampaignResult", "ConfigOracle", "OracleAnswer", "SHAPE_SEP",
    "ShapeOutcome", "SweepCampaign", "SweepStrategy", "parse_shape_key",
    "shape_benchmark_name", "shape_key", "split_benchmark_name",
]

"""Dispatch-time config oracle: "best config for a shape nobody tuned".

After a sweep campaign, a dispatch site holds a concrete problem shape —
usually *not* one of the tuned grid points — and needs a configuration
now, without measuring anything. :class:`ConfigOracle` answers from the
campaign's trial cache with two regimes:

  * **warm** (``source="model"``): a surrogate fit on every cached trial,
    jointly encoded as shape×config features, is evaluated over the whole
    config space *at the query shape's features*; the best predicted mean
    wins. Because numeric shape features are continuous (log-position in
    the tuned range, :class:`~repro.surrogate.encoding.SpaceEncoder`),
    an unseen shape between tuned grid points genuinely interpolates.
  * **cold** (``source="nearest:<shape_key>"``): with too little data to
    trust a joint fit, the oracle returns the incumbent of the most
    trustworthy tuned shape. Trustworthiness mirrors the transfer-tuning
    donor ranking (``TrialCache.rank_donors``): tuned shapes whose scores
    *rank* shared configs the way the query shape's own cached trials do
    (if it has any) are Spearman-ordered first; the rest order by
    shape-feature distance — nearest tuned shape wins.

Both regimes answer from cache only: the oracle never measures.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Union

import numpy as np

from repro.core.cache import CachedTrial, TrialCache, config_key
from repro.core.confidence import spearman
from repro.core.evaluator import EvalResult
from repro.core.searchspace import Config, SearchSpace
from repro.core.stop_conditions import Direction
from repro.surrogate.encoding import SpaceEncoder
from repro.surrogate.model import make_surrogate, poly_dim

from .shapes import SHAPE_SEP, shape_key, split_benchmark_name

__all__ = ["ConfigOracle", "OracleAnswer"]


@dataclasses.dataclass(frozen=True)
class OracleAnswer:
    """One dispatch decision and where it came from."""

    shape: Config
    config: Config
    source: str                    # "model" | "nearest:<shape_key>"
    predicted: Optional[float]     # model: predicted mean at (shape, config);
                                   # nearest: the donor incumbent's score
    donor: Optional[Config] = None  # the tuned shape answering a cold query

    @property
    def cold(self) -> bool:
        return self.source != "model"


class ConfigOracle:
    """Answers ``best_for(shape)`` from a sweep campaign's trial cache.

    ``cache`` is a fingerprint-filtered :class:`~repro.core.cache.TrialCache`
    (scores never transfer across machines) or an iterable of
    :class:`~repro.core.cache.CachedTrial` — tests and offline analysis
    feed trial lists directly. Only benchmarks named
    ``"<base>@<shape_key>"`` participate. ``min_shapes`` gates the warm
    regime: a joint surface fit on a single tuned shape has no shape
    gradient to interpolate with, so at least two distinct shapes (and,
    for the ridge model, at least ``poly_dim(dim)`` trials) are required
    before the model answers; anything less falls back to the nearest
    tuned incumbent.
    """

    def __init__(self, config_space: SearchSpace, shape_space: SearchSpace,
                 cache: Union[TrialCache, Iterable[CachedTrial]],
                 base: str, direction: Direction = Direction.MAXIMIZE,
                 model: str = "ridge", min_shapes: int = 2):
        if min_shapes < 1:
            raise ValueError(f"min_shapes must be >= 1, got {min_shapes}")
        self.config_space = config_space
        self.shape_space = shape_space
        self.base = base
        self.direction = direction
        self.model = model
        self.min_shapes = min_shapes
        self.encoder = SpaceEncoder(config_space, shape_space=shape_space)
        self._configs = config_space.ordered("exhaustive")
        trials = cache.trials() if isinstance(cache, TrialCache) else cache
        prefix = base + SHAPE_SEP
        self._shapes: dict[str, Config] = {}
        self._by_shape: dict[str, list[tuple[Config, EvalResult]]] = {}
        self.n_trials = 0
        for t in trials:
            if not t.benchmark.startswith(prefix):
                continue
            _, shape = split_benchmark_name(t.benchmark)
            if shape is None:
                continue
            key = shape_key(shape)
            self._shapes.setdefault(key, shape)
            self._by_shape.setdefault(key, []).append((t.config, t.result))
            self.n_trials += 1
        self._surrogate = None

    # -- warm regime ---------------------------------------------------------
    @property
    def tuned_shapes(self) -> list[Config]:
        """Shapes with at least one cached trial, key order."""
        return [self._shapes[k] for k in sorted(self._shapes)]

    def is_warm(self) -> bool:
        """True when the joint model has enough data to answer."""
        if len(self._shapes) < self.min_shapes:
            return False
        if self.model == "ridge":
            return self.n_trials >= poly_dim(self.encoder.dim)
        return self.n_trials > 0

    def _fit(self):
        if self._surrogate is None:
            surrogate = make_surrogate(self.model, self.encoder.dim,
                                       len(self._configs))
            # pruned trials feed the fit too — truncated means are
            # unbiased, and dropping them would starve the model exactly
            # where stop-condition-4 campaigns produce the most records
            for key, pool in sorted(self._by_shape.items()):
                shape = self._shapes[key]
                for cfg, res in pool:
                    surrogate.observe(self.encoder.encode(cfg, shape=shape),
                                      float(res.score))
            self._surrogate = surrogate
        return self._surrogate

    def predict(self, shape: Config) -> list[tuple[Config, float]]:
        """Every config's predicted mean at ``shape``, best first —
        the warm regime's full ranking (for dashboards/CLI)."""
        X = self.encoder.encode_all(self._configs, shape=shape)
        mean, _ = self._fit().predict(X)
        order = np.lexsort((np.arange(len(mean)),
                            -mean if self.direction is Direction.MAXIMIZE
                            else mean))
        return [(self._configs[int(i)], float(mean[int(i)])) for i in order]

    # -- cold regime ---------------------------------------------------------
    def rank_shapes(self, shape: Config, min_overlap: int = 3,
                    ) -> list[tuple[str, Optional[float]]]:
        """Tuned shapes as fallback donors, most trustworthy first —
        the donor-ranking rule of ``TrialCache.rank_donors`` transplanted
        from fingerprints to shapes. Donors sharing at least
        ``min_overlap`` unpruned configs with the query shape's *own*
        cached trials (a partially-tuned query) are Spearman-ordered by
        shared-config score correlation; the rest order by distance in
        normalized shape-feature space. Returns ``(shape_key, rho)``
        pairs, ``rho=None`` for the distance-ordered tail."""
        own_key = shape_key(shape)
        own = {config_key(cfg): float(res.score)
               for cfg, res in self._by_shape.get(own_key, ())
               if not res.pruned}
        target = self.encoder.shape_features(shape)
        correlated: list[tuple[str, float, float]] = []
        uncorrelated: list[tuple[str, float]] = []
        for key in sorted(self._shapes):
            if key == own_key:
                continue
            donor = {config_key(cfg): float(res.score)
                     for cfg, res in self._by_shape[key]
                     if not res.pruned}
            dist = float(np.linalg.norm(
                self.encoder.shape_features(self._shapes[key]) - target))
            shared = sorted(set(donor) & set(own))
            rho = (spearman([own[k] for k in shared],
                            [donor[k] for k in shared])
                   if len(shared) >= min_overlap else None)
            if rho is None:
                uncorrelated.append((key, dist))
            else:
                correlated.append((key, rho, dist))
        correlated.sort(key=lambda krd: (-krd[1], krd[2], krd[0]))
        uncorrelated.sort(key=lambda kd: (kd[1], kd[0]))
        return ([(k, rho) for k, rho, _ in correlated]
                + [(k, None) for k, _ in uncorrelated])

    def _incumbent(self, key: str) -> Optional[tuple[Config, float]]:
        best: Optional[tuple[Config, float]] = None
        for cfg, res in self._by_shape.get(key, ()):
            if res.pruned:
                continue
            if best is None or self.direction.better(res.score, best[1]):
                best = (cfg, float(res.score))
        return best

    # -- the dispatch call ---------------------------------------------------
    def best_for(self, shape: Config) -> OracleAnswer:
        """The configuration to dispatch for ``shape``. Warm: joint-model
        argbest. Cold: nearest tuned shape's incumbent. Raises
        ``LookupError`` when the cache holds nothing usable."""
        missing = [p.name for p in self.shape_space.params
                   if p.name not in shape]
        if missing:
            raise KeyError(f"shape {dict(shape)!r} missing parameters "
                           f"{missing}")
        if self.is_warm():
            ranked = self.predict(shape)
            cfg, mean = ranked[0]
            return OracleAnswer(shape=dict(shape), config=dict(cfg),
                                source="model", predicted=mean)
        # cold: a directly-tuned query shape answers with its own
        # incumbent (distance zero beats every donor), then donors in
        # trust order
        own = shape_key(shape)
        for key, _rho in [(own, None)] + self.rank_shapes(shape):
            inc = self._incumbent(key)
            if inc is not None:
                return OracleAnswer(shape=dict(shape), config=dict(inc[0]),
                                    source=f"nearest:{key}",
                                    predicted=inc[1],
                                    donor=dict(self._shapes[key]))
        raise LookupError(f"no unpruned trials under base {self.base!r} — "
                          "run a campaign first")

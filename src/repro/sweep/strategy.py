"""Shape-conditioned surrogate search: one model, many shapes.

:class:`SweepStrategy` is :class:`~repro.surrogate.strategy.SurrogateStrategy`
pointed at the joint shape×config surface. Three things change, all through
the base class's subclass hooks — the ask/tell mechanics, acquisition, and
pruning-aware incumbent tracking are inherited untouched:

  * the encoder is built over ``(config_space, shape_space)``, so every
    feature vector carries the shape being tuned (a fixed block within one
    run) next to the config levels;
  * cached trials of *sibling* shapes — same campaign, same hardware
    fingerprint — are fed to the surrogate as prior observations at reset,
    so the model starts already knowing the surface's shape-trend and the
    default initial design shrinks from space-filling to a two-point
    anchor;
  * the default surrogate is ``"ridge"`` rather than ``"auto"``: the
    quadratic feature expansion carries shape×config cross terms, which is
    what lets knowledge transfer across shapes (k-NN would need the tiny
    per-shape pool to stand alone).

Attribution: ``name = "sweep"``, so every trial record in the cache and
every ledger record carries ``strategy="sweep"``.
"""

from __future__ import annotations

from typing import Iterable, Optional

from repro.core.searchspace import Config, SearchSpace
from repro.surrogate.encoding import SpaceEncoder
from repro.surrogate.strategy import SurrogateStrategy

__all__ = ["SweepStrategy"]

#: one prior observation: (shape, config, score) of a cached sibling trial
Prior = tuple[Config, Config, float]


class SweepStrategy(SurrogateStrategy):
    """Surrogate search over one shape of a sweep campaign.

    ``shape`` is the fixed problem shape this run tunes (its features are
    appended to every encoded config); ``shape_space`` declares the
    campaign grid the features normalize against. ``priors`` are
    ``(shape, config, score)`` triples from sibling shapes' cached trials
    — pass trials measured under the *same hardware fingerprint* only
    (scores never transfer across machines; the campaign runner reads
    them from a fingerprint-filtered :class:`~repro.core.cache.TrialCache`).
    Remaining arguments are inherited from
    :class:`~repro.surrogate.strategy.SurrogateStrategy`.
    """

    name = "sweep"

    def __init__(self, shape: Config, shape_space: SearchSpace,
                 priors: Iterable[Prior] = (),
                 budget: Optional[int] = None,
                 n_init: Optional[int] = None,
                 batch: Optional[int] = None,
                 model: str = "ridge", acquisition: str = "ei",
                 seed: Optional[int] = None):
        super().__init__(budget=budget, n_init=n_init, batch=batch,
                         model=model, acquisition=acquisition, seed=seed)
        missing = [p.name for p in shape_space.params if p.name not in shape]
        if missing:
            raise KeyError(f"shape {dict(shape)!r} missing parameters "
                           f"{missing}")
        self.shape = dict(shape)
        self.shape_space = shape_space
        self.priors = tuple(priors)

    def _make_encoder(self, space: SearchSpace) -> SpaceEncoder:
        return SpaceEncoder(space, shape_space=self.shape_space)

    def _encode(self, config: Config):
        return self._encoder.encode(config, shape=self.shape)

    def _prior_observations(self):
        for shape, config, score in self.priors:
            try:
                x = self._encoder.encode(config, shape=shape)
            except KeyError:
                # a sibling trial from outside this config space (e.g. the
                # campaign's space was narrowed since) cannot be encoded —
                # drop it rather than poison the model
                continue
            yield x, float(score)

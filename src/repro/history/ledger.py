"""Append-only JSONL run ledger: one record per completed tuning session.

The trial cache (:mod:`repro.core.cache`) remembers every *trial*; the
ledger remembers every *run* — the distilled outcome of one tuning session
on one benchmark × hardware fingerprint. That is the unit longitudinal
analysis wants: "has this machine's measured DGEMM peak drifted since last
week?" is a question about a sequence of incumbents, not about the 96
trials behind each one.

Records carry the incumbent configuration, its exact pooled Welford
moments ``(count, mean, m2)`` (merged from the stored per-invocation
moments with the Chan et al. combiner, so report-time CIs equal the
evaluator's), the per-invocation means (the low-n bootstrap fallback in
:mod:`~repro.history.regression` resamples these), the producing strategy
and ``settings_key``, and a **monotonically-assigned run index** per
(benchmark, fingerprint) series. Timestamps are supplied by callers and
never read inside core — the ledger itself is clock-free and fully
deterministic, which keeps golden-file tests and resumed sessions honest.

Ledger records deliberately do **not** carry the trial cache's
``"version"`` key (they use ``"ledger_version"``), so a ledger file
sitting next to session caches is silently skipped by
:func:`repro.core.cache.iter_trials` instead of crashing it — and vice
versa: cache records lack ``"ledger_version"`` and are skipped here.

On-disk format: ``docs/history.md``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import threading
from pathlib import Path
from typing import Iterator, Optional

from repro.core import welford
from repro.core.cache import config_key
from repro.obs.metrics import metrics as obs_metrics
from repro.core.searchspace import Config
from repro.core.stop_conditions import Direction
from repro.core.welford import WelfordState

__all__ = ["LEDGER_VERSION", "BoundLedger", "RunLedger", "RunRecord",
           "iter_runs", "record_from_result"]

LEDGER_VERSION = 1


@dataclasses.dataclass(frozen=True)
class RunRecord:
    """One completed tuning session's distilled outcome."""

    benchmark: str
    fingerprint: str
    run: int                       # monotonic index within the series
    config: Config                 # the incumbent configuration
    score: float                   # incumbent score (mean of invocation means)
    count: float                   # pooled Welford moments of the incumbent's
    mean: float                    # sample stream (exact merge of the stored
    m2: float                      # per-invocation moments)
    invocation_means: tuple[float, ...] = ()   # low-n bootstrap fallback input
    strategy: Optional[str] = None
    settings_key: Optional[str] = None
    direction: str = Direction.MAXIMIZE.value
    n_trials: int = 0              # trials the session evaluated (incl. cached)
    total_samples: int = 0         # samples across the whole session
    session: Optional[str] = None  # TuningSession name, when one ran it
    campaign: Optional[str] = None  # sweep campaign name, when one ran it
    timestamp: Optional[float] = None   # caller-supplied epoch seconds

    @property
    def state(self) -> WelfordState:
        """The incumbent's pooled sample moments as a WelfordState."""
        return WelfordState(count=self.count, mean=self.mean, m2=self.m2)

    @property
    def key(self) -> tuple[str, str]:
        return (self.benchmark, self.fingerprint)


def record_from_result(benchmark: str, fingerprint: str, result,
                       settings_key: Optional[str] = None,
                       session: Optional[str] = None,
                       timestamp: Optional[float] = None,
                       direction: Direction = Direction.MAXIMIZE,
                       campaign: Optional[str] = None,
                       ) -> Optional[RunRecord]:
    """Distill a :class:`~repro.core.tuner.TuningResult` into a run record
    (run index 0 — :meth:`RunLedger.append` assigns the real one).

    Returns ``None`` when the result has no incumbent, or when the
    incumbent's trial record cannot be found (nothing to pool moments
    from) — a run with nothing to remember is not recorded.
    """
    if result.best_config is None:
        return None
    want = config_key(result.best_config)
    trial = None
    for t in result.trials:
        if config_key(t.config) == want:
            trial = t   # last evaluation of the incumbent config wins
    if trial is None:
        return None
    pooled = welford.tree_merge([
        WelfordState(count=float(i.count), mean=i.mean, m2=i.m2)
        for i in trial.result.invocations])
    return RunRecord(
        benchmark=benchmark, fingerprint=fingerprint, run=0,
        config=result.best_config, score=result.best_score,
        count=float(pooled.count), mean=float(pooled.mean),
        m2=float(pooled.m2),
        invocation_means=tuple(i.mean for i in trial.result.invocations),
        strategy=getattr(result, "strategy", None),
        settings_key=settings_key,
        direction=direction.value,
        n_trials=len(result.trials),
        total_samples=result.total_samples,
        session=session, campaign=campaign, timestamp=timestamp)


def _record_to_json(rec: RunRecord) -> dict:
    d = {"ledger_version": LEDGER_VERSION,
         "benchmark": rec.benchmark, "fingerprint": rec.fingerprint,
         "run": rec.run, "config": rec.config, "score": rec.score,
         "count": rec.count, "mean": rec.mean, "m2": rec.m2,
         "invocation_means": list(rec.invocation_means),
         "direction": rec.direction,
         "n_trials": rec.n_trials, "total_samples": rec.total_samples}
    for field in ("strategy", "settings_key", "session", "campaign",
                  "timestamp"):
        value = getattr(rec, field)
        if value is not None:
            d[field] = value
    return d


def _record_from_json(d: dict) -> RunRecord:
    return RunRecord(
        benchmark=d["benchmark"], fingerprint=d["fingerprint"],
        run=int(d["run"]), config=d["config"], score=d["score"],
        count=float(d["count"]), mean=float(d["mean"]), m2=float(d["m2"]),
        invocation_means=tuple(d.get("invocation_means", ())),
        strategy=d.get("strategy"), settings_key=d.get("settings_key"),
        direction=d.get("direction", Direction.MAXIMIZE.value),
        n_trials=int(d.get("n_trials", 0)),
        total_samples=int(d.get("total_samples", 0)),
        session=d.get("session"), campaign=d.get("campaign"),
        timestamp=d.get("timestamp"))


def iter_runs(path: str | os.PathLike) -> Iterator[RunRecord]:
    """Yield every readable run record in a ledger file, in file order.

    Tolerates a torn trailing line; skips records whose
    ``ledger_version`` mismatches (including trial-cache records, which
    carry no ``ledger_version`` at all).
    """
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue   # torn trailing write from a killed run
            if rec.get("ledger_version") != LEDGER_VERSION:
                continue
            yield _record_from_json(rec)


class RunLedger:
    """Thread-safe append-only JSONL store of completed runs.

    Run indices are assigned at append time: the next integer after the
    highest existing index of the record's (benchmark, fingerprint)
    series — monotone per series regardless of interleaving with other
    series in the same file.
    """

    def __init__(self, path: str | os.PathLike):
        self.path = Path(path)
        self._lock = threading.Lock()
        self._series: dict[tuple[str, str], list[RunRecord]] = {}
        if self.path.exists():
            for rec in iter_runs(self.path):
                self._series.setdefault(rec.key, []).append(rec)
            for runs in self._series.values():
                runs.sort(key=lambda r: r.run)

    def __len__(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._series.values())

    def keys(self) -> list[tuple[str, str]]:
        """Every (benchmark, fingerprint) series with at least one run."""
        with self._lock:
            return sorted(self._series)

    def series(self, benchmark: str, fingerprint: str) -> list[RunRecord]:
        """All runs of one series, run-index order."""
        with self._lock:
            return list(self._series.get((benchmark, fingerprint), ()))

    def append(self, record: RunRecord) -> RunRecord:
        """Persist a record, assigning the series' next run index (the
        caller's ``run`` field is ignored). Returns the stored record.

        The index is the successor of the highest one visible in memory
        *or on disk*: the file is re-read here (appends are rare — one
        per completed session) under an exclusive advisory ``flock`` held
        across the read **and** the write, so two processes sharing a
        ledger cannot both observe index N and append N+1. On platforms
        without ``fcntl`` the lock degrades to read-then-append, which
        still heals stale in-process snapshots but leaves a narrow
        cross-process race.
        """
        try:
            import fcntl
        except ImportError:              # pragma: no cover - non-POSIX
            fcntl = None
        with self._lock:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            with self._locked_file(fcntl) as f:
                try:
                    runs = self._series.setdefault(record.key, [])
                    last = runs[-1].run if runs else -1
                    f.seek(0)
                    for line in f:
                        line = line.strip()
                        if not line:
                            continue
                        try:
                            rec = json.loads(line)
                        except json.JSONDecodeError:
                            continue
                        if rec.get("ledger_version") != LEDGER_VERSION:
                            continue
                        if (rec.get("benchmark"), rec.get("fingerprint")) \
                                == record.key:
                            last = max(last, int(rec.get("run", -1)))
                    record = dataclasses.replace(record, run=last + 1)
                    f.seek(0, os.SEEK_END)
                    f.write(json.dumps(_record_to_json(record), default=str)
                            + "\n")
                    f.flush()
                finally:
                    if fcntl is not None:
                        fcntl.flock(f.fileno(), fcntl.LOCK_UN)
            runs.append(record)
        obs_metrics().inc("ledger.appends")
        return record

    @contextlib.contextmanager
    def _locked_file(self, fcntl):
        """Open the ledger ``a+`` holding the exclusive advisory flock.

        After acquiring the lock the inode is re-checked against the
        path: a concurrent :meth:`compact` may have atomically replaced
        the file between our ``open`` and ``flock``, and appending to the
        orphaned inode would silently lose the record. Stale handles are
        re-opened until the lock is held on the live file."""
        while True:
            f = open(self.path, "a+", encoding="utf-8")
            if fcntl is None:            # pragma: no cover - non-POSIX
                break
            fcntl.flock(f.fileno(), fcntl.LOCK_EX)
            try:
                if os.fstat(f.fileno()).st_ino == os.stat(self.path).st_ino:
                    break
            except OSError:
                pass                     # path vanished mid-race: reopen
            fcntl.flock(f.fileno(), fcntl.LOCK_UN)
            f.close()
        try:
            yield f
        finally:
            f.close()

    def compact(self, keep_last: int = 20) -> int:
        """Drop superseded non-best runs past a per-series cap.

        Multi-year deployments append one record per completed session
        forever; most of those records are neither recent (trend
        dashboards window them out) nor the series' best (the regression
        baseline). For every (benchmark, fingerprint) series this keeps
        the most recent ``keep_last`` runs **plus the best run ever**
        (by each record's own recorded direction — the baseline
        ``detect_regressions`` compares against must survive) and drops
        the rest. Run indices are preserved, never renumbered, so a
        later ``append`` continues the series where it left off and
        trend x-axes stay stable across compactions. Foreign lines
        (other ledger versions, torn writes) are preserved verbatim.

        The rewrite is atomic under the same exclusive ``flock`` that
        serializes :meth:`append`: the survivors are written to a temp
        file in the ledger's directory, fsynced, and ``os.replace``d
        over the ledger while the lock is held — a crash mid-compaction
        leaves the original file intact, and a concurrent appender
        re-checks its inode after locking so it never writes to the
        orphaned file. Returns the number of run records dropped.
        """
        if keep_last < 1:
            raise ValueError(f"keep_last must be >= 1, got {keep_last}")
        try:
            import fcntl
        except ImportError:              # pragma: no cover - non-POSIX
            fcntl = None
        with self._lock:
            if not self.path.exists():
                return 0
            with self._locked_file(fcntl) as f:
                f.seek(0)
                lines = f.read().splitlines()
                parsed: list[tuple[str, Optional[RunRecord]]] = []
                series: dict[tuple[str, str], list[RunRecord]] = {}
                for line in lines:
                    if not line.strip():
                        continue
                    rec = None
                    try:
                        d = json.loads(line)
                        if d.get("ledger_version") == LEDGER_VERSION:
                            rec = _record_from_json(d)
                    except (json.JSONDecodeError, KeyError, TypeError,
                            ValueError):
                        rec = None       # foreign/torn: preserved verbatim
                    parsed.append((line, rec))
                    if rec is not None:
                        series.setdefault(rec.key, []).append(rec)
                keep: set[int] = set()
                for runs in series.values():
                    runs.sort(key=lambda r: r.run)
                    best = runs[0]
                    for r in runs[1:]:
                        direction = Direction(r.direction)
                        if direction.better(r.score, best.score):
                            best = r
                    chosen = {id(r) for r in runs[-keep_last:]}
                    chosen.add(id(best))
                    keep.update(chosen)
                survivors = [(line, rec) for line, rec in parsed
                             if rec is None or id(rec) in keep]
                dropped = len(parsed) - len(survivors)
                if dropped:
                    tmp = self.path.with_name(self.path.name + ".compact")
                    with open(tmp, "w", encoding="utf-8") as out:
                        out.write("".join(line + "\n"
                                          for line, _ in survivors))
                        out.flush()
                        os.fsync(out.fileno())
                    os.replace(tmp, self.path)
                self._series = {
                    key: sorted((r for r in runs
                                 if id(r) in keep), key=lambda r: r.run)
                    for key, runs in series.items()}
                self._series = {k: v for k, v in self._series.items() if v}
                if fcntl is not None:
                    fcntl.flock(f.fileno(), fcntl.LOCK_UN)
            return dropped

    def record_result(self, benchmark: str, fingerprint: str, result,
                      settings_key: Optional[str] = None,
                      session: Optional[str] = None,
                      timestamp: Optional[float] = None,
                      direction: Direction = Direction.MAXIMIZE,
                      campaign: Optional[str] = None,
                      ) -> Optional[RunRecord]:
        """Distill and append a :class:`TuningResult`; returns the stored
        record, or ``None`` when the result has no incumbent."""
        rec = record_from_result(benchmark, fingerprint, result,
                                 settings_key=settings_key,
                                 session=session, timestamp=timestamp,
                                 direction=direction, campaign=campaign)
        return self.append(rec) if rec is not None else None

    def backfill(self, cache, session: Optional[str] = None,
                 direction: Direction = Direction.MAXIMIZE,
                 ) -> list[RunRecord]:
        """Seed the ledger from an existing trial cache: one run per
        (benchmark, fingerprint) the cache holds unpruned trials for —
        its incumbent, selected exactly like ``TrialCache.best`` under
        ``direction`` (the cache itself does not record which way its
        scores point, so minimize-direction archives must say so here) —
        but only for series the ledger has **no** runs of yet
        (idempotent: a second backfill of the same cache appends nothing).

        ``cache`` is a :class:`~repro.core.cache.TrialCache`, a cache
        file path, or a directory of session caches.
        """
        from repro.core.cache import TrialCache, load_trials
        if isinstance(cache, TrialCache):
            trials = cache.trials()
        else:
            trials = load_trials(cache)
        best: dict[tuple[str, str], object] = {}
        for t in trials:
            if t.result.pruned:
                continue
            prev = best.get((t.benchmark, t.fingerprint))
            if prev is None or direction.better(t.result.score,
                                                prev.result.score):
                best[(t.benchmark, t.fingerprint)] = t
        added = []
        for (bench, fp), t in sorted(best.items()):
            if self.series(bench, fp):
                continue
            pooled = welford.tree_merge([
                WelfordState(count=float(i.count), mean=i.mean, m2=i.m2)
                for i in t.result.invocations])
            added.append(self.append(RunRecord(
                benchmark=bench, fingerprint=fp, run=0, config=t.config,
                score=t.result.score, count=float(pooled.count),
                mean=float(pooled.mean), m2=float(pooled.m2),
                invocation_means=tuple(i.mean
                                       for i in t.result.invocations),
                strategy=t.strategy, direction=direction.value, n_trials=0,
                total_samples=t.result.total_samples, session=session)))
        return added

    def bound(self, benchmark: str, fingerprint: str,
              session: Optional[str] = None,
              campaign: Optional[str] = None) -> "BoundLedger":
        return BoundLedger(self, benchmark, fingerprint, session=session,
                           campaign=campaign)


class BoundLedger:
    """A :class:`RunLedger` view fixed to one (benchmark, fingerprint)
    series — the shape ``Tuner.tune(ledger=...)`` consumes (mirroring
    ``BoundCache``)."""

    def __init__(self, ledger: RunLedger, benchmark: str, fingerprint: str,
                 session: Optional[str] = None,
                 campaign: Optional[str] = None):
        self.ledger = ledger
        self.benchmark = benchmark
        self.fingerprint = fingerprint
        self.session = session
        self.campaign = campaign

    def record(self, result, settings_key: Optional[str] = None,
               timestamp: Optional[float] = None,
               direction: Direction = Direction.MAXIMIZE,
               ) -> Optional[RunRecord]:
        return self.ledger.record_result(
            self.benchmark, self.fingerprint, result,
            settings_key=settings_key, session=self.session,
            timestamp=timestamp, direction=direction,
            campaign=self.campaign)

    def series(self) -> list[RunRecord]:
        return self.ledger.series(self.benchmark, self.fingerprint)

"""Performance history: run ledger, regression gating, trend dashboards.

The tuning engine measures a machine's roofline *once*; this package makes
the repo longitudinally self-aware across runs. Three layers:

  * :mod:`~repro.history.ledger` — an append-only JSONL **run ledger**:
    one record per completed tuning session (benchmark × hardware
    fingerprint), carrying the incumbent config, its exact pooled Welford
    moments, and a monotonically-assigned run index. Populated
    automatically by ``TuningSession``/``Tuner.tune(ledger=...)``, and
    backfillable from an existing trial cache.
  * :mod:`~repro.history.regression` — statistical drift detection: the
    newest run's incumbent mean against the best historical run, via a
    Welch CI on the difference of means (``ReservoirBootstrap`` fallback
    at low sample counts), classified improved / flat / regressed with
    the same error discipline the paper applies to early termination.
    ``scripts/perf_gate.py`` turns the verdicts into a CI exit code.
  * :mod:`~repro.history.render` — self-contained single-file HTML
    dashboards (inline CSS/JS/SVG, no external deps) with per-series
    trend lines, CI bands, roofline summaries and verdicts, plus ASCII
    sparklines for terminals.

Ledger format and gate semantics: ``docs/history.md``.
"""

from .ledger import (LEDGER_VERSION, BoundLedger, RunLedger, RunRecord,
                     record_from_result)
from .regression import (RegressionReport, RunComparison, SeriesVerdict,
                         compare_runs, detect_regressions, welch_interval)
from .render import (ascii_sparkline, render_html, render_trend_text,
                     write_dashboard)

__all__ = [
    "LEDGER_VERSION", "BoundLedger", "RunLedger", "RunRecord",
    "record_from_result",
    "RegressionReport", "RunComparison", "SeriesVerdict", "compare_runs",
    "detect_regressions", "welch_interval",
    "ascii_sparkline", "render_html", "render_trend_text",
    "write_dashboard",
]

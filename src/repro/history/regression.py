"""Statistical drift detection over ledger series (regression gating).

The paper trusts a measured peak only when its confidence interval says
so; this module applies the same discipline *across* runs. For each
(benchmark, fingerprint) series in a :class:`~repro.history.ledger.RunLedger`,
the newest run's incumbent mean is compared against the **best historical
run** (not merely the previous one — a slow decay must not hide behind a
chain of individually-insignificant steps):

  * **Welch CI on the difference of means** — the default. Both runs'
    pooled Welford moments give a two-sample t interval with
    Welch–Satterthwaite degrees of freedom, built on the same quantile
    machinery as :mod:`repro.core.confidence` (no scipy).
  * **Reservoir-bootstrap fallback** — when either run pooled fewer than
    ``min_count`` samples the t approximation is shaky, so the stored
    per-invocation means are resampled with
    :class:`~repro.core.confidence.ReservoirBootstrap` and the verdict
    comes from percentile-CI overlap.

A drift is only *confirmed* (verdict ``regressed`` / ``improved``) when
the CI excludes zero **and** the effect exceeds ``min_effect`` (default
2%, the paper's early-termination error budget) — statistically
significant noise below that threshold is classified ``flat``. Verdicts
aggregate into a :class:`RegressionReport`, which ``scripts/perf_gate.py``
turns into a CI exit code.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.core.confidence import Interval, ReservoirBootstrap, t_quantile
from repro.core.stop_conditions import Direction
from repro.core.welford import WelfordState

from .ledger import RunLedger, RunRecord

__all__ = ["RegressionReport", "RunComparison", "SeriesVerdict",
           "compare_runs", "detect_regressions", "welch_interval"]

#: Minimum pooled sample count per run for the Welch path; below it the
#: reservoir-bootstrap fallback takes over (when invocation means exist).
MIN_COUNT_WELCH = 5

#: Confirmed drifts must exceed this relative effect size — the paper's
#: <2% error discipline for early termination, applied to gating.
MIN_EFFECT = 0.02


def welch_interval(a: WelfordState, b: WelfordState,
                   confidence: float = 0.99) -> Interval:
    """CI for the difference of means ``b - a`` from two Welford states
    (Welch's t interval, Welch–Satterthwaite degrees of freedom).

    Degenerate inputs fall back conservatively: with fewer than two
    samples on either side the interval is infinite; with zero variance
    on both sides it collapses to the exact difference.
    """
    na, nb = float(a.count), float(b.count)
    delta = float(b.mean) - float(a.mean)
    if na < 2 or nb < 2:
        return Interval(lo=-math.inf, hi=math.inf, mean=delta)
    va, vb = float(a.variance), float(b.variance)
    se2 = va / na + vb / nb
    if se2 <= 0.0:
        return Interval(lo=delta, hi=delta, mean=delta)
    # Welch–Satterthwaite: df of the combined variance estimate
    df = se2 * se2 / ((va / na) ** 2 / (na - 1.0)
                      + (vb / nb) ** 2 / (nb - 1.0))
    crit = t_quantile(1.0 - (1.0 - confidence) / 2.0, max(df, 1.0))
    half = crit * math.sqrt(se2)
    return Interval(lo=delta - half, hi=delta + half, mean=delta)


@dataclasses.dataclass(frozen=True)
class RunComparison:
    """Outcome of comparing a candidate run against a baseline run."""

    baseline: RunRecord
    candidate: RunRecord
    delta: float                   # candidate.mean - baseline.mean
    rel_delta: float               # delta / |baseline.mean|
    interval: Interval             # CI of the difference (welch) or of the
                                   # candidate (bootstrap overlap test)
    verdict: str                   # "improved" | "flat" | "regressed"
    method: str                    # "welch" | "bootstrap"
    confidence: float


def _bootstrap_ci(means: Sequence[float], confidence: float,
                  seed: int) -> Interval:
    boot = ReservoirBootstrap(seed=seed)
    for x in means:
        boot.update(float(x))
    return boot.ci_mean(confidence)


def compare_runs(baseline: RunRecord, candidate: RunRecord,
                 confidence: float = 0.99,
                 direction: Optional[Direction] = None,
                 min_effect: float = MIN_EFFECT,
                 min_count: int = MIN_COUNT_WELCH) -> RunComparison:
    """Classify ``candidate`` against ``baseline``.

    ``direction`` defaults to the direction stamped on the candidate
    record. The verdict is direction-aware: under MINIMIZE a significant
    *increase* of the mean is the regression.
    """
    if direction is None:
        direction = Direction(candidate.direction)
    delta = candidate.mean - baseline.mean
    rel = delta / abs(baseline.mean) if baseline.mean else math.inf
    small_n = (baseline.count < min_count or candidate.count < min_count)
    if small_n and len(baseline.invocation_means) >= 2 \
            and len(candidate.invocation_means) >= 2:
        # percentile-CI overlap over the stored invocation means; seeds
        # derive from the run indices so reruns reproduce the verdict
        ca = _bootstrap_ci(baseline.invocation_means, confidence,
                           seed=baseline.run + 1)
        cb = _bootstrap_ci(candidate.invocation_means, confidence,
                           seed=candidate.run + 1)
        separated_up = cb.lo > ca.hi
        separated_down = cb.hi < ca.lo
        method, interval = "bootstrap", cb
    else:
        interval = welch_interval(baseline.state, candidate.state, confidence)
        separated_up = interval.lo > 0.0
        separated_down = interval.hi < 0.0
        method = "welch"
    confirmed = (separated_up or separated_down) and abs(rel) >= min_effect
    if not confirmed:
        verdict = "flat"
    else:
        got_better = direction.better(candidate.mean, baseline.mean)
        verdict = "improved" if got_better else "regressed"
    return RunComparison(baseline=baseline, candidate=candidate, delta=delta,
                         rel_delta=rel, interval=interval, verdict=verdict,
                         method=method, confidence=confidence)


@dataclasses.dataclass(frozen=True)
class SeriesVerdict:
    """One (benchmark, fingerprint) series' drift classification."""

    benchmark: str
    fingerprint: str
    runs: tuple[RunRecord, ...]
    comparison: Optional[RunComparison]   # None: single-run series

    @property
    def verdict(self) -> str:
        """"baseline" for single-run series, else the comparison's."""
        return self.comparison.verdict if self.comparison else "baseline"

    @property
    def scores(self) -> tuple[float, ...]:
        return tuple(r.score for r in self.runs)


@dataclasses.dataclass(frozen=True)
class RegressionReport:
    """Every series' verdict; ``ok`` is the gate's pass/fail."""

    series: tuple[SeriesVerdict, ...]
    confidence: float
    min_effect: float

    @property
    def regressions(self) -> tuple[SeriesVerdict, ...]:
        return tuple(s for s in self.series if s.verdict == "regressed")

    @property
    def ok(self) -> bool:
        return not self.regressions

    def render_text(self) -> str:
        """Terminal rendering: one sparkline-annotated line per series,
        then the gate verdict."""
        from .render import ascii_sparkline
        if not self.series:
            return "perf-gate: ledger has no runs — nothing to gate.\n"
        lines = []
        width = max(len(f"{s.benchmark} @ {s.fingerprint}")
                    for s in self.series)
        for s in self.series:
            name = f"{s.benchmark} @ {s.fingerprint}".ljust(width)
            spark = ascii_sparkline(s.scores)
            if s.comparison is None:
                lines.append(f"  {name}  {spark}  baseline "
                             f"({s.runs[-1].score:.4g}, 1 run)")
                continue
            c = s.comparison
            tag = s.verdict.upper() if s.verdict == "regressed" else s.verdict
            lines.append(
                f"  {name}  {spark}  {tag}  "
                f"run {c.candidate.run}: {c.candidate.mean:.4g} vs best "
                f"run {c.baseline.run}: {c.baseline.mean:.4g} "
                f"({c.rel_delta:+.2%}, {c.method}, "
                f"{c.confidence * 100:g}% CI "
                f"[{c.interval.lo:.4g}, {c.interval.hi:.4g}])")
        n_reg = len(self.regressions)
        head = (f"perf-gate: {len(self.series)} series, "
                f"{n_reg} confirmed regression(s) "
                f"(confidence={self.confidence:g}, "
                f"min_effect={self.min_effect:.0%})")
        return "\n".join([head, *lines]) + "\n"


def detect_regressions(ledger: RunLedger,
                       benchmark: Optional[str] = None,
                       fingerprint: Optional[str] = None,
                       confidence: float = 0.99,
                       direction: Optional[Direction] = None,
                       min_effect: float = MIN_EFFECT,
                       min_count: int = MIN_COUNT_WELCH) -> RegressionReport:
    """Compare every series' newest run against its best historical run.

    The baseline is the direction-best run among all *earlier* runs, so a
    gradual drift cannot hide: run N is always held to the series' high-
    water mark, not to run N-1. Single-run series classify ``baseline``
    and never gate.
    """
    out = []
    for bench, fp in ledger.keys():
        if benchmark is not None and bench != benchmark:
            continue
        if fingerprint is not None and fp != fingerprint:
            continue
        runs = tuple(ledger.series(bench, fp))
        if len(runs) < 2:
            out.append(SeriesVerdict(bench, fp, runs, None))
            continue
        candidate = runs[-1]
        d = direction or Direction(candidate.direction)
        baseline = runs[0]
        for r in runs[1:-1]:
            if d.better(r.mean, baseline.mean):
                baseline = r
        cmp = compare_runs(baseline, candidate, confidence=confidence,
                           direction=d, min_effect=min_effect,
                           min_count=min_count)
        out.append(SeriesVerdict(bench, fp, runs, cmp))
    return RegressionReport(series=tuple(out), confidence=confidence,
                            min_effect=min_effect)

"""Trend rendering: single-file HTML dashboards and ASCII sparklines.

ROADMAP asked for "HTML/plot output beyond markdown/ASCII"; this module
supplies it without adding a single dependency. The dashboard is **one
self-contained HTML file** — inline CSS, inline vanilla JS, inline SVG
generated here in Python with fixed-precision coordinates — so it can be
attached to a CI run, mailed, or opened from ``file://`` with no network
access, and so a golden-file test can pin its structure byte-for-byte
(the CARM tool's automatically-rendered comparisons, done the
zero-infrastructure way).

Inputs are the other layers' outputs, all optional and composable:

  * :class:`~repro.core.report.FingerprintReport` rows — per-fingerprint
    measured-roofline summaries with an SVG roofline plot;
  * :class:`~repro.history.ledger.RunRecord` series — per-series trend
    lines with CI bands recovered from the stored Welford moments;
  * a :class:`~repro.history.regression.RegressionReport` — the verdict
    table, colored.

``ascii_sparkline`` / ``render_trend_text`` are the terminal counterparts
used by ``scripts/tune.py --history`` and ``scripts/perf_gate.py``.
"""

from __future__ import annotations

import html
import math
import string
from datetime import datetime, timezone
from pathlib import Path
from typing import Optional, Sequence

from repro.core.cache import config_key
from repro.core.confidence import ci_mean

from .ledger import RunLedger, RunRecord
from .regression import RegressionReport, detect_regressions

__all__ = ["ascii_sparkline", "render_html", "render_trend_text",
           "write_dashboard"]

_TEMPLATE_PATH = Path(__file__).parent / "templates" / "dashboard.html"

_SPARK_LEVELS = "▁▂▃▄▅▆▇█"

#: categorical palette for roofline subsystem curves (color-blind safe)
_CURVE_COLORS = ("#4269d0", "#efb118", "#ff725c", "#6cc5b0", "#3ca951",
                 "#a463f2")


def ascii_sparkline(values: Sequence[float]) -> str:
    """One block-character column per value, scaled to the series range
    (a constant series renders mid-scale). Empty input renders empty."""
    vals = [float(v) for v in values]
    if not vals:
        return ""
    lo, hi = min(vals), max(vals)
    if hi - lo <= 0:
        return _SPARK_LEVELS[3] * len(vals)
    out = []
    for v in vals:
        idx = int((v - lo) / (hi - lo) * (len(_SPARK_LEVELS) - 1))
        out.append(_SPARK_LEVELS[idx])
    return "".join(out)


def render_trend_text(runs: Sequence[RunRecord],
                      confidence: float = 0.99) -> str:
    """Terminal trend view of one series: sparkline plus one line per run
    with its CI margin — what ``scripts/tune.py --history`` prints."""
    if not runs:
        return "(no history yet)"
    lines = [f"history   : {ascii_sparkline([r.score for r in runs])}  "
             f"({len(runs)} run(s))"]
    for r in runs:
        iv = ci_mean(r.state, confidence)
        margin = "n/a" if math.isinf(iv.margin) else f"±{iv.margin:.3g}"
        via = f"  via {r.strategy}" if r.strategy else ""
        sess = f"  [{r.session}]" if r.session else ""
        lines.append(f"  run {r.run:3d}: {r.score:10.4g} {margin:>10s}  "
                     f"n={int(r.count)}{via}{sess}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# SVG generation (deterministic: every coordinate is rounded)
# ---------------------------------------------------------------------------


def _fmt(x: float) -> str:
    return f"{x:.1f}"


def _trend_svg(runs: Sequence[RunRecord], confidence: float,
               width: int = 560, height: int = 150) -> str:
    """Score-vs-run-index line with a CI band from the stored moments."""
    pad_l, pad_r, pad_t, pad_b = 56, 14, 10, 22
    iw, ih = width - pad_l - pad_r, height - pad_t - pad_b
    points = []
    for k, r in enumerate(runs):
        iv = ci_mean(r.state, confidence)
        lo = iv.lo if not math.isinf(iv.lo) else r.score
        hi = iv.hi if not math.isinf(iv.hi) else r.score
        points.append((k, r.score, lo, hi))
    y_lo = min(p[2] for p in points)
    y_hi = max(p[3] for p in points)
    if y_hi - y_lo <= 0:
        y_lo, y_hi = y_lo - 1.0, y_hi + 1.0
    span = y_hi - y_lo
    y_lo, y_hi = y_lo - 0.08 * span, y_hi + 0.08 * span

    def sx(k: float) -> float:
        denom = max(len(points) - 1, 1)
        return pad_l + k / denom * iw

    def sy(v: float) -> float:
        return pad_t + (1.0 - (v - y_lo) / (y_hi - y_lo)) * ih

    band_pts = [f"{_fmt(sx(k))},{_fmt(sy(hi))}" for k, _, _, hi in points]
    band_pts += [f"{_fmt(sx(k))},{_fmt(sy(lo))}"
                 for k, _, lo, _ in reversed(points)]
    line_pts = " ".join(f"{_fmt(sx(k))},{_fmt(sy(s))}"
                        for k, s, _, _ in points)
    dots = "".join(
        f'<circle class="trend-dot" cx="{_fmt(sx(k))}" cy="{_fmt(sy(s))}" '
        f'r="3"><title>run {runs[k].run}: {s:.4g} '
        f'[{lo:.4g}, {hi:.4g}]</title></circle>'
        for k, s, lo, hi in points)
    x_labels = "".join(
        f'<text x="{_fmt(sx(k))}" y="{height - 6}" text-anchor="middle">'
        f'{runs[k].run}</text>'
        for k, *_ in points)
    parts = [
        f'<svg viewBox="0 0 {width} {height}" width="{width}" '
        f'height="{height}" role="img">',
        f'<line class="axis" x1="{pad_l}" y1="{pad_t}" x2="{pad_l}" '
        f'y2="{height - pad_b}"/>',
        f'<line class="axis" x1="{pad_l}" y1="{height - pad_b}" '
        f'x2="{width - pad_r}" y2="{height - pad_b}"/>',
        f'<text x="{pad_l - 6}" y="{pad_t + 10}" text-anchor="end">'
        f'{y_hi:.4g}</text>',
        f'<text x="{pad_l - 6}" y="{height - pad_b}" text-anchor="end">'
        f'{y_lo:.4g}</text>',
        f'<polygon class="trend-band" points="{" ".join(band_pts)}"/>',
        f'<polyline class="trend-line" points="{line_pts}"/>',
        dots, x_labels, "</svg>"]
    return "".join(parts)


def _roofline_svg(report, width: int = 560, height: int = 220) -> str:
    """Log-log roofline of one FingerprintReport: each subsystem's roof
    curve plus achieved-kernel markers."""
    pad_l, pad_r, pad_t, pad_b = 56, 14, 10, 24
    iw, ih = width - pad_l - pad_r, height - pad_t - pad_b
    curves = [(name, report.model.curve(name))
              for name, _ in report.bandwidths]
    xs = [math.log2(i) for _, pts in curves for i, _ in pts]
    ys = [math.log2(max(f, 1.0)) for _, pts in curves for _, f in pts]
    xs += [math.log2(mi) for _, mi, _ in report.marks]
    ys += [math.log2(max(mf, 1.0)) for _, _, mf in report.marks]
    x0, x1 = min(xs), max(xs)
    y0, y1 = min(ys), max(ys)

    def sx(v: float) -> float:
        return pad_l + (v - x0) / max(x1 - x0, 1e-9) * iw

    def sy(v: float) -> float:
        return pad_t + (1.0 - (v - y0) / max(y1 - y0, 1e-9)) * ih

    body = []
    for k, (name, pts) in enumerate(curves):
        color = _CURVE_COLORS[k % len(_CURVE_COLORS)]
        line = " ".join(
            f"{_fmt(sx(math.log2(i)))},{_fmt(sy(math.log2(max(f, 1.0))))}"
            for i, f in pts)
        body.append(f'<polyline class="roof-curve" stroke="{color}" '
                    f'points="{line}"><title>{html.escape(name)}</title>'
                    f'</polyline>')
    for label, mi, mf in report.marks:
        cx = _fmt(sx(math.log2(mi)))
        cy = _fmt(sy(math.log2(max(mf, 1.0))))
        body.append(f'<circle class="roof-mark" cx="{cx}" cy="{cy}" r="4">'
                    f'<title>{html.escape(label)}: I={mi:.4g}, '
                    f'F={mf:.4g}</title></circle>')
    legend = "".join(
        f'<text x="{pad_l + 8 + 130 * k}" y="{pad_t + 12}" '
        f'fill="{_CURVE_COLORS[k % len(_CURVE_COLORS)]}">'
        f'{html.escape(name)}</text>'
        for k, (name, _) in enumerate(curves))
    parts = [
        f'<svg viewBox="0 0 {width} {height}" width="{width}" '
        f'height="{height}" role="img">',
        f'<line class="axis" x1="{pad_l}" y1="{pad_t}" x2="{pad_l}" '
        f'y2="{height - pad_b}"/>',
        f'<line class="axis" x1="{pad_l}" y1="{height - pad_b}" '
        f'x2="{width - pad_r}" y2="{height - pad_b}"/>',
        f'<text x="{pad_l - 6}" y="{pad_t + 10}" text-anchor="end">'
        f'2^{y1:.1f}</text>',
        f'<text x="{pad_l - 6}" y="{height - pad_b}" text-anchor="end">'
        f'2^{y0:.1f}</text>',
        f'<text x="{width - pad_r}" y="{height - 6}" text-anchor="end">'
        f'log2(I), FLOP/B</text>',
        *body, legend, "</svg>"]
    return "".join(parts)


# ---------------------------------------------------------------------------
# HTML assembly
# ---------------------------------------------------------------------------


def _esc(s: object) -> str:
    return html.escape(str(s))


def _table(header: Sequence[str], rows: Sequence[Sequence[str]]) -> str:
    """Rows are pre-escaped/pre-formatted HTML cell strings."""
    head = "".join(f"<th>{h}</th>" for h in header)
    body = "".join("<tr>" + "".join(f"<td>{c}</td>" for c in row) + "</tr>"
                   for row in rows)
    return f"<table><thead><tr>{head}</tr></thead><tbody>{body}</tbody></table>"


def _stamp(ts: Optional[float]) -> str:
    if ts is None:
        return "—"
    return datetime.fromtimestamp(ts, tz=timezone.utc).strftime(
        "%Y-%m-%d %H:%M UTC")


def _roofline_section(report) -> str:
    conf_pct = f"{report.confidence * 100:g}%"
    rows = []
    iv = report.dgemm.interval(report.confidence)
    margin = "n/a" if math.isinf(iv.margin) else f"±{iv.margin:.3g}"
    rows.append(["peak compute F_p (dgemm)", f"{report.dgemm.score:.4g}",
                 margin, f"<code>{_esc(config_key(report.dgemm.config))}</code>",
                 str(report.dgemm.total_samples)])
    for name, inc in report.bandwidths:
        iv = inc.interval(report.confidence)
        margin = "n/a" if math.isinf(iv.margin) else f"±{iv.margin:.3g}"
        rows.append([f"bandwidth B_a {_esc(name)} (triad)",
                     f"{inc.score:.4g}", margin,
                     f"<code>{_esc(config_key(inc.config))}</code>",
                     str(inc.total_samples)])
    gap_rows = [[_esc(g["kernel"]), _esc(g["subsystem"]),
                 f"{g['intensity_flop_per_byte']:.4g}",
                 f"{g['achieved_flops']:.4g}", f"{g['attainable_flops']:.4g}",
                 f"{g['pct_of_roof']:.1f}%", _esc(g["bound"])]
                for g in report.gap_rows()]
    return "\n".join([
        f"<h2>Roofline — <code>{_esc(report.fingerprint)}</code></h2>",
        f"<p class=\"meta\">{report.n_trials} cached trials, "
        f"{conf_pct} confidence intervals.</p>",
        _table(["quantity", "value", f"{conf_pct} CI", "incumbent config",
                "samples"], rows),
        _roofline_svg(report),
        "<h3>Model vs measured (% of roof)</h3>",
        _table(["kernel", "subsystem", "I (FLOP/B)", "achieved",
                "attainable", "% of roof", "bound"], gap_rows),
    ])


def _trend_section(benchmark: str, fingerprint: str,
                   runs: Sequence[RunRecord], confidence: float) -> str:
    rows = []
    for r in runs:
        iv = ci_mean(r.state, confidence)
        margin = "n/a" if math.isinf(iv.margin) else f"±{iv.margin:.3g}"
        rows.append([str(r.run), f"{r.score:.4g}", margin,
                     str(int(r.count)),
                     f"<code>{_esc(config_key(r.config))}</code>",
                     _esc(r.strategy or "—"), _esc(r.session or "—"),
                     _stamp(r.timestamp)])
    spark = ascii_sparkline([r.score for r in runs])
    return "\n".join([
        f"<h2>Trend — {_esc(benchmark)} @ "
        f"<code>{_esc(fingerprint)}</code></h2>",
        f"<p class=\"meta\"><span class=\"spark\">{_esc(spark)}</span> "
        f"{len(runs)} run(s)</p>",
        _trend_svg(runs, confidence),
        _table(["run", "score", f"{confidence * 100:g}% CI", "n",
                "incumbent config", "strategy", "session", "timestamp"],
               rows),
    ])


def _verdict_section(report: RegressionReport) -> str:
    rows = []
    for s in report.series:
        spark = f"<span class=\"spark\">{_esc(ascii_sparkline(s.scores))}</span>"
        if s.comparison is None:
            rows.append([_esc(s.benchmark), f"<code>{_esc(s.fingerprint)}</code>",
                         spark, f"<span class=\"verdict-baseline\">baseline"
                         "</span>", f"{s.runs[-1].score:.4g}", "—", "—", "—"])
            continue
        c = s.comparison
        rows.append([
            _esc(s.benchmark), f"<code>{_esc(s.fingerprint)}</code>", spark,
            f"<span class=\"verdict-{_esc(s.verdict)}\">{_esc(s.verdict)}"
            "</span>",
            f"{c.candidate.mean:.4g}", f"{c.baseline.mean:.4g}",
            f"{c.rel_delta:+.2%}",
            f"{c.method}, [{c.interval.lo:.4g}, {c.interval.hi:.4g}]"])
    status = ("all clear" if report.ok
              else f"{len(report.regressions)} confirmed regression(s)")
    return "\n".join([
        "<h2>Regression verdicts</h2>",
        f"<p class=\"meta\">{len(report.series)} series — {status} "
        f"(confidence {report.confidence:g}, min effect "
        f"{report.min_effect:.0%}).</p>",
        _table(["benchmark", "fingerprint", "trend", "verdict", "newest",
                "best prior", "Δ rel", f"{report.confidence * 100:g}% CI "
                "of Δ / candidate"], rows),
    ])


#: per-op rows shown in the attribution table before eliding
_MAX_ATTRIBUTION_ROWS = 30


def _stacked_bar_svg(shares: Sequence[tuple[str, float]],
                     width: int = 560, height: int = 72) -> str:
    """One horizontal stacked bar of (label, seconds) shares with an
    inline legend — the where-does-the-time-go view of an attribution."""
    total = sum(max(s, 0.0) for _, s in shares)
    bar_h, pad = 26, 4
    body = []
    x = 0.0
    for k, (label, secs) in enumerate(shares):
        frac = (max(secs, 0.0) / total) if total > 0 else 0.0
        w = frac * width
        color = _CURVE_COLORS[k % len(_CURVE_COLORS)]
        if label == "unattributed":
            color = "#9498a0"
        if w > 0:
            body.append(
                f'<rect class="attr-bar" x="{_fmt(x)}" y="{pad}" '
                f'width="{_fmt(w)}" height="{bar_h}" fill="{color}">'
                f'<title>{_esc(label)}: {secs * 1e6:.3g}µs '
                f'({frac * 100:.1f}%)</title></rect>')
        x += w
    legend = "".join(
        f'<text x="{8 + 170 * k}" y="{pad + bar_h + 18}" '
        f'fill="{"#9498a0" if label == "unattributed" else _CURVE_COLORS[k % len(_CURVE_COLORS)]}">'
        f'{_esc(label)} {100.0 * max(secs, 0.0) / total if total else 0.0:.1f}%</text>'
        for k, (label, secs) in enumerate(shares))
    return (f'<svg viewBox="0 0 {width} {height}" width="{width}" '
            f'height="{height}" role="img">{"".join(body)}{legend}</svg>')


def _attribution_section(report) -> str:
    """Per-op roofline placement of one workload: cost/time table plus a
    stacked subsystem-share bar. ``report`` is an
    :class:`~repro.obs.attribution.AttributionReport`."""
    rows = []
    for op in report.top_ops(_MAX_ATTRIBUTION_ROWS):
        intensity = ("∞" if math.isinf(op.intensity)
                     else f"{op.intensity:.3g}")
        rows.append([
            f"<code>{_esc(op.name)}</code>", _esc(op.kind),
            f"{op.flops:.4g}", f"{op.bytes_accessed:.4g}", intensity,
            ("—" if op.time_s is None else f"{op.time_s * 1e6:.3g}"),
            _esc(op.subsystem), _esc(op.bound),
            ("—" if op.pct_of_roof is None else f"{op.pct_of_roof:.1f}%")])
    elided = len(report.ops) - min(len(report.ops), _MAX_ATTRIBUTION_ROWS)
    shares = sorted(report.subsystem_seconds.items())
    shares.append(("unattributed", report.unattributed_s))
    if report.mode == "measured":
        basis = (f"device total {report.device_total_s * 1e6:.3g}µs, "
                 f"unattributed {report.unattributed_frac * 100:.1f}%")
    else:
        basis = ("static HLO attribution (no device tracks): op time is "
                 "the roofline lower bound, remainder exactly 0")
    roofs = report.roofs
    roof_txt = ("no roofs recovered — ops unclassified" if roofs is None
                else f"F_p={roofs.peak_flops:.4g} FLOP/s vs "
                     + ", ".join(f"{k}={v:.4g} B/s"
                                 for k, v in sorted(roofs.bandwidths.items())))
    return "\n".join([
        f"<h2>Attribution — <code>{_esc(report.workload)}</code> "
        f"({_esc(report.mode)})</h2>",
        f"<p class=\"meta\">{len(report.ops)} HLO ops, "
        f"{report.total_flops:.4g} FLOPs, {report.total_bytes:.4g} bytes; "
        f"{_esc(basis)}. Roofs: {_esc(roof_txt)}.</p>",
        _stacked_bar_svg(shares),
        _table(["op", "kind", "FLOPs", "bytes", "I (FLOP/B)", "time µs",
                "subsystem", "bound", "% of roof"], rows),
        (f"<p class=\"meta\">{elided} further op(s) elided.</p>"
         if elided else ""),
    ])


#: default drill-down row cap — campaigns can trace thousands of trials;
#: the dashboard shows the first N and says how many it dropped
#: (``roofline_report.py --max-trial-rows`` overrides per render)
_MAX_TRIAL_ROWS = 200


def _flags(row: dict) -> str:
    out = []
    if row.get("improved"):
        out.append('<span class="trial-improved" title="new incumbent">★'
                   "</span>")
    if row.get("pruned"):
        out.append("pruned")
    if row.get("cached"):
        out.append("cached")
    return " ".join(out) or "—"


def _trials_section(trials: Sequence[dict],
                    max_rows: int = _MAX_TRIAL_ROWS) -> str:
    """Per-trial drill-down from a trace's ``trial_summaries`` rows."""
    shown = list(trials)[:max(max_rows, 0)]
    rows = []
    for r in shown:
        phases = ", ".join(f"{_esc(k)} {v * 1e3:.2f}ms"
                           for k, v in (r.get("phases") or {}).items())
        cfg = config_key(r["config"]) if r.get("config") else "—"
        score = "—" if r.get("score") is None else f"{r['score']:.4g}"
        worker = "—" if r.get("worker") is None else str(r["worker"])
        rows.append([
            "—" if r.get("index") is None else str(r["index"]),
            f"<code>{_esc(cfg)}</code>", score,
            "—" if r.get("samples") is None else str(r["samples"]),
            str(r.get("invocations", 0)),
            _esc(r.get("stop_reason") or "—"),
            f"{r.get('dur_s', 0.0) * 1e3:.2f}",
            worker, phases or "—", _flags(r)])
    dropped = len(trials) - len(shown)
    note = f" (first {len(shown)} of {len(trials)})" if dropped else ""
    return "\n".join([
        "<h2>Trial drill-down</h2>",
        f"<p class=\"meta\">{len(trials)} traced trial(s){note}.</p>",
        _table(["trial", "config", "score", "samples", "invocations",
                "stop", "wall ms", "worker", "phases", "flags"], rows),
    ])


def render_html(reports: Sequence = (), skipped: Sequence[tuple[str, str]] = (),
                ledger: Optional[RunLedger] = None,
                regression: Optional[RegressionReport] = None,
                title: str = "Performance history dashboard",
                subtitle: Optional[str] = None,
                confidence: float = 0.99,
                trials: Sequence[dict] = (),
                attribution=None,
                max_trial_rows: int = _MAX_TRIAL_ROWS) -> str:
    """Assemble the self-contained dashboard.

    Every argument is optional: a cache-only call renders roofline
    summaries, a ledger-only call renders trends (and verdicts when a
    ``regression`` report is supplied). ``trials`` is a sequence of
    ``repro.obs.export.trial_summaries`` rows rendered as a per-trial
    drill-down table, capped at ``max_trial_rows``. ``attribution`` is
    an :class:`~repro.obs.attribution.AttributionReport` rendered as a
    per-op roofline placement section. ``subtitle`` is caller-supplied
    display text (e.g. a generation timestamp) — this function itself
    never reads a clock, so output is deterministic for golden tests.
    """
    sections: list[str] = []
    if regression is not None:
        sections.append(_verdict_section(regression))
    for report in reports:
        sections.append(_roofline_section(report))
    if attribution is not None:
        sections.append(_attribution_section(attribution))
    if ledger is not None:
        for benchmark, fingerprint in ledger.keys():
            runs = ledger.series(benchmark, fingerprint)
            if runs:
                sections.append(_trend_section(benchmark, fingerprint, runs,
                                               confidence))
    if trials:
        sections.append(_trials_section(list(trials), max_trial_rows))
    if skipped:
        items = "".join(f"<li><code>{_esc(fp)}</code>: {_esc(reason)}</li>"
                        for fp, reason in skipped)
        sections.append(f"<h2>Skipped fingerprints</h2><ul>{items}</ul>")
    if not sections:
        sections.append("<p>Nothing to render: no reports, ledger series, "
                        "or verdicts supplied.</p>")
    n_series = len(ledger.keys()) if ledger is not None else 0
    default_sub = (f"{len(list(reports))} fingerprint report(s), "
                   f"{n_series} ledger series.")
    template = string.Template(_TEMPLATE_PATH.read_text(encoding="utf-8"))
    return template.substitute(title=_esc(title),
                               subtitle=_esc(subtitle or default_sub),
                               body="\n".join(sections))


def write_dashboard(path, reports: Sequence = (),
                    skipped: Sequence[tuple[str, str]] = (),
                    ledger: Optional[RunLedger] = None,
                    title: str = "Performance history dashboard",
                    subtitle: Optional[str] = None,
                    confidence: float = 0.99,
                    trials: Sequence[dict] = (),
                    attribution=None,
                    max_trial_rows: int = _MAX_TRIAL_ROWS) -> Path:
    """The CLI recipe shared by ``roofline_report.py --html`` and
    ``benchmarks/run.py --html``: detect regressions over the ledger
    (when one is given), render, write. Returns the written path."""
    regression = (detect_regressions(ledger, confidence=confidence)
                  if ledger is not None else None)
    html = render_html(reports, skipped, ledger=ledger,
                       regression=regression, title=title,
                       subtitle=subtitle, confidence=confidence,
                       trials=trials, attribution=attribution,
                       max_trial_rows=max_trial_rows)
    out = Path(path)
    out.write_text(html, encoding="utf-8")
    return out

"""Synthetic LM data pipeline.

Production properties this substrate actually provides:
  * **Determinism & resumability** — batch ``i`` is a pure function of
    (seed, i); restart from any step reproduces the exact stream with no
    state files (the checkpoint only needs the step counter).
  * **Sharding awareness** — ``make_batch_sharded`` materializes each
    device's batch slice locally (no host-side global batch), the pattern
    that scales to thousands of hosts.
  * **Structured tokens** — a tiny k-order Markov construction instead of
    iid noise, so the LM loss actually decreases during the training
    example (learnable bigram structure).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np



@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32000
    structure: float = 0.8      # probability of following the Markov chain


class SyntheticLM:
    """Deterministic synthetic token stream: batch(i) is pure in (seed, i)."""

    def __init__(self, cfg: DataConfig, batch: int, seq_len: int):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        # fixed random bigram successor table: token t -> succ(t)
        rng = np.random.default_rng(cfg.seed)
        self._succ = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=cfg.vocab_size),
            jnp.int32)

    def batch_at(self, step: int) -> dict:
        """Global batch for one step (pure function of step)."""
        key = jax.random.fold_in(jax.random.key(self.cfg.seed), step)
        return {"tokens": self._tokens(key, self.batch)}

    def _tokens(self, key, rows: int) -> jax.Array:
        k_init, k_noise, k_mask = jax.random.split(key, 3)
        first = jax.random.randint(k_init, (rows, 1), 0,
                                   self.cfg.vocab_size)
        noise = jax.random.randint(k_noise, (rows, self.seq_len), 0,
                                   self.cfg.vocab_size)
        follow = jax.random.bernoulli(k_mask, self.cfg.structure,
                                      (rows, self.seq_len))

        def step_fn(prev, xs):
            nz, fl = xs
            nxt = jnp.where(fl, jnp.take(self._succ, prev), nz)
            return nxt, nxt

        _, toks = jax.lax.scan(
            step_fn, first[:, 0],
            (jnp.moveaxis(noise, 1, 0), jnp.moveaxis(follow, 1, 0)))
        return jnp.moveaxis(toks, 0, 1)


def make_batch_sharded(pipeline: SyntheticLM, step: int, mesh, spec) -> dict:
    """Materialize the step's batch directly with the target sharding via
    per-shard callbacks — each host/device generates only its slice."""
    from jax.sharding import NamedSharding

    shape = (pipeline.batch, pipeline.seq_len)
    sharding = NamedSharding(mesh, spec)

    def per_shard(index):
        rows = index[0]
        start = rows.start or 0
        stop = rows.stop if rows.stop is not None else pipeline.batch
        key = jax.random.fold_in(jax.random.key(pipeline.cfg.seed), step)
        # fold the row-range so each shard's stream is independent but
        # deterministic
        key = jax.random.fold_in(key, start)
        toks = pipeline._tokens(key, stop - start)
        cols = index[1] if len(index) > 1 else slice(None)
        return np.asarray(toks)[:, cols]

    tokens = jax.make_array_from_callback(shape, sharding, per_shard)
    return {"tokens": tokens}

"""Deterministic synthetic data pipeline (sharding-aware, resumable)."""

from .pipeline import DataConfig, SyntheticLM, make_batch_sharded

__all__ = ["DataConfig", "SyntheticLM", "make_batch_sharded"]

"""Optimizer substrate: AdamW + LR schedules (cosine, MiniCPM's WSD)."""

from .adamw import (AdamWConfig, adamw_init, adamw_update, global_norm,
                    opt_state_defs)
from .schedules import cosine_schedule, make_schedule, wsd_schedule

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "make_schedule", "opt_state_defs", "wsd_schedule"]

"""AdamW with f32 moments, global-norm clipping, decoupled weight decay.

Moment tensors reuse the parameter ParamDef tree (same shapes + logical
axes) so they shard identically to the params — with the FSDP "embed" rule
this is ZeRO-sharded optimizer state for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..models import params as params_lib
from ..models.params import ParamDef


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def opt_state_defs(defs: Any) -> dict:
    """ParamDef tree for the optimizer state (m, v in f32, + step count)."""

    def f32_def(_, d: ParamDef) -> ParamDef:
        return ParamDef(shape=d.shape, logical=d.logical, init="zeros",
                        dtype=jnp.float32)

    return {
        "m": params_lib._map_tree(f32_def, defs),
        "v": params_lib._map_tree(f32_def, defs),
        "count": ParamDef(shape=(), logical=(), init="zeros",
                          dtype=jnp.float32),
    }


def adamw_init(defs: Any) -> dict:
    return params_lib.materialize(jax.random.key(0), opt_state_defs(defs))


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params: Any, grads: Any, opt_state: dict, lr: jax.Array,
                 cfg: AdamWConfig = AdamWConfig()) -> tuple[Any, dict, dict]:
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    count = opt_state["count"] + 1.0
    b1c = 1.0 - cfg.b1 ** count
    b2c = 1.0 - cfg.b2 ** count

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        update = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        pn, mn, vn = upd(p, g, m, v)
        new_p.append(pn)
        new_m.append(mn)
        new_v.append(vn)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return (jax.tree.unflatten(treedef, new_p),
            {"m": jax.tree.unflatten(treedef, new_m),
             "v": jax.tree.unflatten(treedef, new_v),
             "count": count},
            metrics)

"""Learning-rate schedules.

``wsd_schedule`` is the MiniCPM Warmup-Stable-Decay schedule
(arXiv:2404.06395): linear warmup, long flat stable phase, short
exponential-ish decay tail — assigned to minicpm-2b.
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def cosine_schedule(peak_lr: float, total_steps: int,
                    warmup_steps: int = 100,
                    min_ratio: float = 0.1) -> Schedule:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        frac = (step - warmup_steps) / jnp.maximum(
            total_steps - warmup_steps, 1)
        frac = jnp.clip(frac, 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return peak_lr * jnp.where(step < warmup_steps, warm, cos)

    return fn


def wsd_schedule(peak_lr: float, total_steps: int, warmup_steps: int = 100,
                 decay_frac: float = 0.1, min_ratio: float = 0.01) -> Schedule:
    """Warmup -> stable plateau -> fast decay over the last ``decay_frac``."""

    decay_steps = max(1, int(total_steps * decay_frac))
    decay_start = total_steps - decay_steps

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        decay_t = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
        # exponential decay to min_ratio over the tail
        decay = jnp.exp(jnp.log(min_ratio) * decay_t)
        val = jnp.where(step < warmup_steps, warm,
                        jnp.where(step < decay_start, 1.0, decay))
        return peak_lr * val

    return fn


def make_schedule(kind: str, peak_lr: float, total_steps: int,
                  warmup_steps: int = 100) -> Schedule:
    if kind == "wsd":
        return wsd_schedule(peak_lr, total_steps, warmup_steps)
    return cosine_schedule(peak_lr, total_steps, warmup_steps)

"""Version compatibility shims for the installed JAX.

The repo targets the Pallas/TPU surface that keeps moving between JAX
releases. Everything that touches a renamed or not-yet-existing API goes
through this module so kernels and launch code stay version-agnostic:

  * ``tpu_compiler_params(...)`` — jax >= 0.5 renamed
    ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and 0.4.x has
    only the former). Resolves whichever exists.
  * ``make_mesh(shape, names)`` — ``jax.sharding.AxisType`` and the
    ``axis_types=`` kwarg of ``jax.make_mesh`` only exist on jax >= 0.5;
    on 0.4.x meshes are constructed without them (all axes default to
    auto sharding there, which is the behavior we request anyway).
"""

from __future__ import annotations

import inspect
from typing import Sequence

import jax
from jax.experimental.pallas import tpu as pltpu

__all__ = ["tpu_compiler_params", "make_mesh"]

# Resolved once at import: the TPU compiler-params class under its current
# name (jax >= 0.5: CompilerParams; jax 0.4.x: TPUCompilerParams).
_TPU_COMPILER_PARAMS_CLS = getattr(
    pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams", None)


def tpu_compiler_params(**kwargs):
    """Construct Pallas TPU compiler params under whatever name this JAX
    exposes them (e.g. ``tpu_compiler_params(dimension_semantics=(...))``)."""
    if _TPU_COMPILER_PARAMS_CLS is None:
        raise RuntimeError(
            "this JAX exposes neither pltpu.CompilerParams nor "
            "pltpu.TPUCompilerParams")
    return _TPU_COMPILER_PARAMS_CLS(**kwargs)


_MAKE_MESH_HAS_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters
    and hasattr(jax.sharding, "AxisType"))


def make_mesh(axis_shapes: Sequence[int],
              axis_names: Sequence[str]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with all axes auto-sharded, on any supported JAX."""
    if _MAKE_MESH_HAS_AXIS_TYPES:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(axis_names)))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))

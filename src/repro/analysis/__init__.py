"""Dry-run analysis: HLO collective parsing + roofline terms."""

from .hlo import CollectiveStats, parse_collectives
from .terms import RooflineTerms, analyze_compiled, model_flops

__all__ = ["CollectiveStats", "RooflineTerms", "analyze_compiled",
           "model_flops", "parse_collectives"]

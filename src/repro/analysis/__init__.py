"""Dry-run analysis: HLO collective parsing, cost extraction + roofline terms."""

from .hlo import CollectiveStats, HloCost, parse_collectives, parse_hlo_cost
from .terms import RooflineTerms, analyze_compiled, model_flops

__all__ = ["CollectiveStats", "HloCost", "RooflineTerms", "analyze_compiled",
           "model_flops", "parse_collectives", "parse_hlo_cost"]

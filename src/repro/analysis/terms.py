"""Roofline terms from a compiled dry-run artifact (EXPERIMENTS.md §Roofline).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
    memory term     = HLO_bytes_per_device / HBM_bw               [s]
    collective term = collective_bytes_per_device / link_bw       [s]

``cost_analysis()`` on an SPMD-compiled module reports per-device numbers
(verified empirically: flops == total/chips), so no chip division is needed
beyond what XLA already did. MODEL_FLOPS uses the assignment's convention:
6·N·D for training (fwd+bwd), 2·N·D per token for inference, with N the
active parameter count (MoE discounts inactive experts).
"""

from __future__ import annotations

import dataclasses

from ..core.roofline import TPU_V5E, MachineSpec
from ..models.config import ModelConfig, WorkloadShape
from .hlo import parse_collectives


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_summary: str
    peak_bytes_per_dev: float      # memory_analysis: args+temp+out
    model_flops_total: float       # analytic 6ND / 2ND
    chips: int
    machine: MachineSpec = TPU_V5E

    @property
    def compute_s(self) -> float:
        return self.flops_per_dev / self.machine.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_dev / self.machine.mem_bandwidths["hbm"]

    @property
    def collective_s(self) -> float:
        if self.machine.link_bandwidth <= 0:
            return 0.0
        return self.coll_bytes_per_dev / self.machine.link_bandwidth

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound_s(self) -> float:
        """max of the three terms = perfectly-overlapped lower bound."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): remat/dispatch/padding waste."""
        total_hlo = self.flops_per_dev * self.chips
        if total_hlo <= 0:
            return 0.0
        return self.model_flops_total / total_hlo

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline lower bound (the score the
        perf loop pushes up): MODEL_FLOPS / (chips · peak · max-term)."""
        t = self.step_time_lower_bound_s
        if t <= 0:
            return 0.0
        return self.model_flops_total / (self.chips * self.machine.peak_flops
                                         * t)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_ms": round(self.compute_s * 1e3, 3),
            "memory_ms": round(self.memory_s * 1e3, 3),
            "collective_ms": round(self.collective_s * 1e3, 3),
            "dominant": self.dominant,
            "useful_flops_ratio": round(self.useful_flops_ratio, 3),
            "mfu_bound": round(self.mfu_bound, 3),
            "hbm_gb_per_dev": round(self.peak_bytes_per_dev / 1e9, 2),
            "collectives": self.coll_summary,
        }


def model_flops(cfg: ModelConfig, shape: WorkloadShape) -> float:
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze_compiled(compiled, cfg: ModelConfig, shape: WorkloadShape,
                     mesh_name: str, chips: int) -> RooflineTerms:
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo, chips)
    peak_bytes = 0.0
    if ma is not None:
        peak_bytes = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                      + ma.temp_size_in_bytes)
    return RooflineTerms(
        arch=cfg.name, shape=shape.name, mesh=mesh_name,
        flops_per_dev=float(ca.get("flops", 0.0)),
        bytes_per_dev=float(ca.get("bytes accessed", 0.0)),
        coll_bytes_per_dev=coll.total_bytes,
        coll_summary=coll.summary(),
        peak_bytes_per_dev=peak_bytes,
        model_flops_total=model_flops(cfg, shape),
        chips=chips,
    )

"""Parse collective traffic out of compiled HLO text.

``cost_analysis`` does not report collective bytes, so we scan the compiled
module for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops, take each op's result shape, and convert to
estimated per-device link traffic with the standard ring-algorithm factors:

    all-reduce        2 * bytes * (n-1)/n      (reduce-scatter + all-gather)
    all-gather        bytes * (n-1)/n          (result = gathered size)
    reduce-scatter    bytes * (n-1)            (operand = result * n)
    all-to-all        bytes * (n-1)/n
    collective-permute bytes                   (point-to-point)

where n is the replica-group size parsed from the op's replica_groups.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %ag = bf16[16,1024]{1,0} all-gather(%x), ...
#       ROOT %tuple = (f32[4]{0}, f32[4]{0}) all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(",
)

_SHAPE_RE = re.compile(r"(?P<dtype>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")

_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype = m.group("dtype")
        if dtype not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass(frozen=True)
class CollectiveStats:
    """Per-device collective traffic estimate."""

    bytes_by_op: dict[str, float]
    count_by_op: dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    def summary(self) -> str:
        parts = [f"{op}:{cnt}x/{by/1e6:.1f}MB"
                 for op, (cnt, by) in sorted(
                     {o: (self.count_by_op[o], self.bytes_by_op[o])
                      for o in self.count_by_op}.items())]
        return " ".join(parts) if parts else "none"


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    bytes_by_op: dict[str, float] = defaultdict(float)
    count_by_op: dict[str, int] = defaultdict(int)
    seen_start: set[str] = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        # avoid double counting async -start/-done pairs: count -start, skip
        # -done (its result repeats the -start shape)
        if "-done(" in line:
            continue
        op = m.group("op")
        size = _shape_bytes(m.group("shape"))
        if "-start(" in line and op == "all-reduce":
            # all-reduce-start result is the final tensor shape; fine.
            pass
        n = _group_size(line, n_devices)
        if n <= 1:
            continue
        frac = (n - 1) / n
        if op == "all-reduce":
            traffic = 2.0 * size * frac
        elif op == "all-gather":
            traffic = size * frac
        elif op == "reduce-scatter":
            traffic = size * (n - 1)
        elif op == "all-to-all":
            traffic = size * frac
        else:  # collective-permute
            traffic = float(size)
        bytes_by_op[op] += traffic
        count_by_op[op] += 1
    return CollectiveStats(bytes_by_op=dict(bytes_by_op),
                           count_by_op=dict(count_by_op))

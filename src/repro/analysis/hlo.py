"""Parse collective traffic and compute/memory cost out of compiled HLO text.

``cost_analysis`` does not report collective bytes, so we scan the compiled
module for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops, take each op's result shape, and convert to
estimated per-device link traffic with the standard ring-algorithm factors:

    all-reduce        2 * bytes * (n-1)/n      (reduce-scatter + all-gather)
    all-gather        bytes * (n-1)/n          (result = gathered size)
    reduce-scatter    bytes * (n-1)            (operand = result * n)
    all-to-all        bytes * (n-1)/n
    collective-permute bytes                   (point-to-point)

where n is the replica-group size parsed from the op's replica_groups.

:func:`parse_hlo_cost` is the text-level sibling for compute cost: it
re-derives FLOP and byte counts for dot / elementwise / copy-like ops from
the module text alone. The measurement-soundness linter
(:mod:`repro.lint.workload`) cross-checks a benchmark's *declared* work
term against this traced cost (falling back to it when the backend's
``cost_analysis`` reports nothing), so a DGEMM that silently stopped doing
2·n·m·k FLOPs is caught before the tuner spends hours timing it.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    # fp8 family (one byte each) — a quantized op must not land in the
    # unhandled tally and silently undercount traffic
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e8m0fnu": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %ag = bf16[16,1024]{1,0} all-gather(%x), ...
#       ROOT %tuple = (f32[4]{0}, f32[4]{0}) all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(",
)

_SHAPE_RE = re.compile(r"(?P<dtype>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")

_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype = m.group("dtype")
        if dtype not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass(frozen=True)
class CollectiveStats:
    """Per-device collective traffic estimate."""

    bytes_by_op: dict[str, float]
    count_by_op: dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    def summary(self) -> str:
        parts = [f"{op}:{cnt}x/{by/1e6:.1f}MB"
                 for op, (cnt, by) in sorted(
                     {o: (self.count_by_op[o], self.bytes_by_op[o])
                      for o in self.count_by_op}.items())]
        return " ".join(parts) if parts else "none"


# ---------------------------------------------------------------------------
# Compute/memory cost extraction (measurement-soundness audit, pass 1)
# ---------------------------------------------------------------------------

# ops whose FLOP count is one op per result element
_ELEMENTWISE_OPS = frozenset({
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "remainder", "atan2", "compare", "and", "or", "xor", "not", "negate",
    "abs", "sign", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "sqrt", "rsqrt", "cbrt", "cosine", "sine", "tan", "logistic", "erf",
    "clamp", "select",
})

# pure data movement: no FLOPs, operand + result bytes count as traffic
_COPY_OPS = frozenset({
    "copy", "transpose", "reshape", "broadcast", "concatenate", "slice",
    "dynamic-slice", "dynamic-update-slice", "pad", "reverse", "gather",
    "convert", "iota",
})

# structural ops that move no data themselves (fusion bodies are separate
# computations in the same text, so their inner ops are already counted)
_SKIP_OPS = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "fusion", "call",
    "bitcast", "bitcast-convert", "after-all", "partition-id", "replica-id",
    "custom-call", "while", "conditional", "domain", "opt-barrier",
}) | set(_COLLECTIVES)

# reductions: one FLOP per *input* element folded into the result
# (reduce of N elements to M results performs ~N combiner applications)
_REDUCE_OPS = frozenset({"reduce", "reduce-window"})

# generic "name = shape op(" — the op token is the word before the operand
# list; versioned names (%add.0) carry the version after the paren match
_COST_OP_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>[a-z][a-z0-9-]*)\(",
)

_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_numel(shape_str: str) -> int:
    """Total element count over every sub-shape of ``shape_str`` (tuples
    sum; a scalar ``f32[]`` counts 1; unknown dtypes still count)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        n = 1
        dims = m.group("dims")
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _operand_text(line: str, start: int) -> str:
    """The text between the op's opening paren at ``start`` and its
    balanced closing paren (operand lists never nest in practice, but
    ``fusion(..., calls=...)`` attributes keep this honest)."""
    depth = 0
    for i in range(start, len(line)):
        ch = line[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return line[start + 1:i]
    return line[start + 1:]


@dataclasses.dataclass(frozen=True)
class HloCost:
    """Text-derived compute/memory cost estimate of one HLO module.

    ``flops`` counts dot contractions (2·M·N·K) and one op per result
    element for elementwise ops; ``bytes_accessed`` counts operand plus
    result bytes of every costed op (an upper-bound traffic model: fused
    intermediates are counted even though they never reach memory).
    ``unhandled`` tallies op kinds the model does not cost — nonzero
    entries mean the estimate is partial, not that parsing failed.
    """

    flops: float
    bytes_accessed: float
    flops_by_op: dict[str, float]
    bytes_by_op: dict[str, float]
    unhandled: dict[str, int]

    def summary(self) -> str:
        return (f"flops={self.flops:.3g} bytes={self.bytes_accessed:.3g}"
                + (f" unhandled={sorted(self.unhandled)}"
                   if self.unhandled else ""))


def _op_cost(op: str, shape: str, operands: str,
             line: str) -> "tuple[float, float] | None":
    """(flops, bytes) of one costed instruction, ``None`` when the op kind
    is not modeled. Shared by the module-aggregate and per-op parsers so
    the two views can never disagree on a single instruction."""
    moved = _shape_bytes(shape) + _shape_bytes(operands)
    if op == "dot":
        lhs = _SHAPE_RE.search(operands)
        contract = 1
        cm = _CONTRACT_RE.search(line)
        if lhs is not None and cm is not None and cm.group(1):
            dims = [int(d) for d in lhs.group("dims").split(",")] \
                if lhs.group("dims") else []
            for idx in cm.group(1).split(","):
                i = int(idx)
                if 0 <= i < len(dims):
                    contract *= dims[i]
        return 2.0 * _shape_numel(shape) * contract, float(moved)
    if op in _ELEMENTWISE_OPS:
        return float(_shape_numel(shape)), float(moved)
    if op in _REDUCE_OPS:
        # one combiner application per input element (init scalars are
        # part of the operand list; their O(1) contribution is noise)
        return float(_shape_numel(operands)), float(moved)
    if op in _COPY_OPS:
        return 0.0, float(moved)
    return None


def parse_hlo_cost(hlo_text: str) -> HloCost:
    """Extract FLOP/byte costs for dot / elementwise / reduce / copy ops
    from HLO text (compiled ``.as_text()`` or handwritten fixtures).

    Fusion *bodies* are separate named computations in the same text, so
    counting every line once costs fused ops exactly once; the calling
    ``fusion`` instruction itself is structural and skipped.
    """
    flops_by_op: dict[str, float] = defaultdict(float)
    bytes_by_op: dict[str, float] = defaultdict(float)
    unhandled: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COST_OP_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        shape = m.group("shape")
        if op in _SKIP_OPS or op.endswith("-start") or op.endswith("-done"):
            continue
        operands = _operand_text(line, m.end() - 1)
        cost = _op_cost(op, shape, operands, line)
        if cost is None:
            unhandled[op] += 1
            continue
        flops, moved = cost
        if flops:
            flops_by_op[op] += flops
        bytes_by_op[op] += moved
    return HloCost(flops=sum(flops_by_op.values()),
                   bytes_accessed=sum(bytes_by_op.values()),
                   flops_by_op=dict(flops_by_op),
                   bytes_by_op=dict(bytes_by_op),
                   unhandled=dict(unhandled))


# ---------------------------------------------------------------------------
# Per-op records (roofline attribution, repro.obs.attribution)
# ---------------------------------------------------------------------------

#: structural entry-computation ops that never appear on a device timeline
_STRUCTURAL_OPS = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "domain", "opt-barrier", "partition-id", "replica-id",
})

#: caller ops whose cost is their called computation's total
_CALLER_OPS = frozenset({"fusion", "call"})

# computation header, column 0:  "%fused_computation.1 (p: f32[4]) -> ... {"
# or "ENTRY %main.5 (...) -> ... {"  (handwritten fixtures may omit "%")
_COMP_RE = re.compile(
    r"^(?P<entry>ENTRY\s+)?%?(?P<name>[\w.$-]+)\s*\(")

_INSTR_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.$-]+)\s*=")

_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?(?P<callee>[\w.$-]+)")


@dataclasses.dataclass(frozen=True)
class OpCost:
    """One entry-computation instruction with its text-derived cost.

    ``kind`` is the HLO opcode (fusions keep ``fusion``; their cost is
    the called computation's total). ``modeled`` is False for op kinds
    the cost model does not cover — the record still exists (so measured
    device time can be joined against it) but carries zero cost.
    """

    name: str                    # instruction name, "%" stripped
    kind: str
    flops: float
    bytes_accessed: float
    modeled: bool = True

    @property
    def intensity(self) -> float:
        """Arithmetic intensity I = FLOPs / bytes of this op (paper
        Eq. 1). Zero-traffic compute is ``inf``; zero-work movement 0."""
        if self.bytes_accessed <= 0:
            return float("inf") if self.flops > 0 else 0.0
        return self.flops / self.bytes_accessed


@dataclasses.dataclass(frozen=True)
class ModuleOps:
    """Per-op cost records of one HLO module's entry computation."""

    ops: tuple[OpCost, ...]
    unhandled: dict[str, int]    # op kinds seen but not cost-modeled

    @property
    def flops(self) -> float:
        return sum(o.flops for o in self.ops)

    @property
    def bytes_accessed(self) -> float:
        return sum(o.bytes_accessed for o in self.ops)

    def by_name(self) -> dict[str, OpCost]:
        return {o.name: o for o in self.ops}


def parse_hlo_ops(hlo_text: str) -> ModuleOps:
    """Per-instruction cost records of the ENTRY computation.

    Unlike :func:`parse_hlo_cost` (which flattens every computation into
    module totals), this keeps one record per *entry* instruction — the
    granularity a device timeline reports — and rolls each ``fusion`` /
    ``call`` instruction's cost up from its called computation, so a
    fused elementwise chain is attributed to the one op Perfetto will
    show. ``while``/``conditional`` bodies are counted once (trip counts
    are not in the text); they land in ``unhandled`` to flag the
    estimate as partial.
    """
    # pass 1: bucket instruction lines per computation
    comps: dict[str, list[tuple[str, str, str, str, str]]] = {}
    entry: "str | None" = None
    current = ""
    for line in hlo_text.splitlines():
        if not line.startswith((" ", "\t")):
            cm = _COMP_RE.match(line)
            if cm and line.rstrip().endswith("{") \
                    and ("->" in line or cm.group("entry")):
                current = cm.group("name")
                comps.setdefault(current, [])
                if cm.group("entry"):
                    entry = current
                continue
        m = _COST_OP_RE.search(line)
        if m is None:
            continue
        nm = _INSTR_NAME_RE.match(line)
        name = nm.group("name") if nm else m.group("op")
        operands = _operand_text(line, m.end() - 1)
        comps.setdefault(current, []).append(
            (name, m.group("op"), m.group("shape"), operands, line))
    if entry is None:
        # handwritten fixtures without an ENTRY header: the implicit
        # top-level bucket (or the only computation present)
        entry = "" if comps.get("") else next(iter(comps), "")

    unhandled: dict[str, int] = defaultdict(int)

    def comp_cost(comp: str, seen: frozenset) -> tuple[float, float]:
        """Summed (flops, bytes) of one computation, callees resolved."""
        if comp in seen:  # pragma: no cover - malformed recursive module
            return 0.0, 0.0
        total_f = total_b = 0.0
        for name, op, shape, operands, line in comps.get(comp, ()):
            f, b, _ = record_cost(op, shape, operands, line, seen | {comp})
            total_f += f
            total_b += b
        return total_f, total_b

    def record_cost(op: str, shape: str, operands: str, line: str,
                    seen: frozenset) -> tuple[float, float, bool]:
        """(flops, bytes, modeled) of one instruction."""
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done") or base in _STRUCTURAL_OPS:
            return 0.0, 0.0, True
        if base in _CALLER_OPS or base in ("while", "conditional"):
            cm = _CALLS_RE.search(line)
            if base in ("while", "conditional"):
                unhandled[base] += 1   # body counted once, trips unknown
                cm = re.search(r"body=%?(?P<callee>[\w.$-]+)", line) or cm
            if cm is not None:
                f, b = comp_cost(cm.group("callee"), seen)
                return f, b, True
            return 0.0, 0.0, False
        if base in _COLLECTIVES:
            # traffic only; link bytes are parse_collectives' business
            return (0.0, float(_shape_bytes(shape) + _shape_bytes(operands)),
                    True)
        cost = _op_cost(base, shape, operands, line)
        if cost is None:
            unhandled[base] += 1
            return 0.0, 0.0, False
        return cost[0], cost[1], True

    ops: list[OpCost] = []
    for name, op, shape, operands, line in comps.get(entry, ()):
        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done") or base in _STRUCTURAL_OPS:
            continue
        f, b, modeled = record_cost(op, shape, operands, line, frozenset())
        ops.append(OpCost(name=name, kind=base, flops=f, bytes_accessed=b,
                          modeled=modeled))
    return ModuleOps(ops=tuple(ops), unhandled=dict(unhandled))


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    bytes_by_op: dict[str, float] = defaultdict(float)
    count_by_op: dict[str, int] = defaultdict(int)
    seen_start: set[str] = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        # avoid double counting async -start/-done pairs: count -start, skip
        # -done (its result repeats the -start shape)
        if "-done(" in line:
            continue
        op = m.group("op")
        size = _shape_bytes(m.group("shape"))
        if "-start(" in line and op == "all-reduce":
            # all-reduce-start result is the final tensor shape; fine.
            pass
        n = _group_size(line, n_devices)
        if n <= 1:
            continue
        frac = (n - 1) / n
        if op == "all-reduce":
            traffic = 2.0 * size * frac
        elif op == "all-gather":
            traffic = size * frac
        elif op == "reduce-scatter":
            traffic = size * (n - 1)
        elif op == "all-to-all":
            traffic = size * frac
        else:  # collective-permute
            traffic = float(size)
        bytes_by_op[op] += traffic
        count_by_op[op] += 1
    return CollectiveStats(bytes_by_op=dict(bytes_by_op),
                           count_by_op=dict(count_by_op))

"""minicpm-2b [dense]: 40L d_model=2304 36H (GQA kv=36) d_ff=5760
vocab=122753 — WSD schedule (arch=llama-like). [arXiv:2404.06395; hf]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,              # MHA
    d_ff=5760,
    vocab_size=122753,
    mlp_type="glu",
    act="silu",
    lr_schedule="wsd",          # the MiniCPM warmup-stable-decay schedule
)

SMOKE = ModelConfig(
    name="minicpm-2b-smoke",
    family="dense",
    n_layers=2,
    d_model=72,
    n_heads=6,
    n_kv_heads=6,
    d_ff=180,
    vocab_size=512,
    mlp_type="glu",
    act="silu",
    lr_schedule="wsd",
    dtype="float32",
)

"""Assigned-architecture registry: ``get(name)`` / ``--arch <id>``.

Each module defines ``CONFIG`` (the exact assigned configuration) and
``SMOKE`` (a reduced same-family configuration for CPU tests).
"""

from __future__ import annotations

import importlib

from ..models.config import ModelConfig

ARCH_IDS = [
    "command_r_plus_104b",
    "granite_3_2b",
    "minicpm_2b",
    "gemma_2b",
    "whisper_base",
    "granite_moe_1b_a400m",
    "mixtral_8x22b",
    "llama_3_2_vision_11b",
    "mamba2_130m",
    "zamba2_2_7b",
]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}


def canonical(name: str) -> str:
    name = name.replace("-", "_").replace(".", "_")
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return name


def get(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(name)}", __package__)
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(name)}", __package__)
    return mod.SMOKE


def all_configs() -> dict[str, ModelConfig]:
    return {i: get(i) for i in ARCH_IDS}

"""gemma-2b [dense]: 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=256000 — GeGLU, head_dim=256, MQA on 2b. [arXiv:2403.08295; hf]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,               # MQA
    d_ff=16384,
    vocab_size=256000,
    head_dim=256,
    mlp_type="glu",
    act="gelu",                 # GeGLU
)

SMOKE = ModelConfig(
    name="gemma-2b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=256,
    vocab_size=512,
    head_dim=32,
    mlp_type="glu",
    act="gelu",
    dtype="float32",
)

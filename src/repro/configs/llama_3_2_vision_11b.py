"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attn image layers.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Vision tower is stubbed: ``input_specs`` provides pre-projected patch
embeddings (B, 1600, 4096). Every 5th layer is a cross-attention layer."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    cross_attn_every=5,
    n_image_tokens=1600,
    mlp_type="glu",
    act="silu",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="llama-vision-smoke",
    family="vlm",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    cross_attn_every=2,
    n_image_tokens=16,
    mlp_type="glu",
    act="silu",
    tie_embeddings=False,
    dtype="float32",
)

"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 — GQA. [hf:ibm-granite/granite-3.0-2b-base; hf]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    mlp_type="glu",
    act="silu",
)

SMOKE = ModelConfig(
    name="granite-3-2b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    mlp_type="glu",
    act="silu",
    dtype="float32",
)

"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, SWA. [arXiv:2401.04088; hf]

SWA window 4096 => sub-quadratic decode state; runs the long_500k cell
with a rolling KV cache capped at the window."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,                 # per-expert
    vocab_size=32768,
    head_dim=128,
    n_experts=8,
    top_k=2,
    moe_group_size=1024,
    window=4096,                # sliding-window attention
    mlp_type="glu",
    act="silu",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="mixtral-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=512,
    head_dim=16,
    n_experts=4,
    top_k=2,
    moe_group_size=16,
    window=16,
    mlp_type="glu",
    act="silu",
    tie_embeddings=False,
    dtype="float32",
)

"""whisper-base [audio]: 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865 —
enc-dec, conv frontend (stub). [arXiv:2212.04356; unverified]

The conv frontend is stubbed: ``input_specs`` provides precomputed frame
embeddings (B, 1500, 512). Positional encodings are sinusoidal on both
sides (DESIGN.md §4)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,                 # decoder layers
    n_enc_layers=6,
    d_model=512,
    d_enc=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    n_frames=1500,
    mlp_type="plain",
    act="gelu",
    norm="layernorm",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-base-smoke",
    family="encdec",
    n_layers=2,
    n_enc_layers=2,
    d_model=64,
    d_enc=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    n_frames=24,
    mlp_type="plain",
    act="gelu",
    norm="layernorm",
    tie_embeddings=True,
    dtype="float32",
)

"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    head_dim=128,
    mlp_type="glu",
    act="silu",
    norm="layernorm",           # Cohere uses (bias-free) LayerNorm
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="command-r-plus-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=512,
    head_dim=16,
    mlp_type="glu",
    act="silu",
    norm="layernorm",
    tie_embeddings=True,
    dtype="float32",
)

"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240,
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks.
[arXiv:2411.15242; hf]

One shared attention+MLP block applied after every 6th Mamba2 layer (the
per-use LoRA deltas of the real model are omitted; DESIGN.md §4). The
shared attention uses a 4096 sliding window so the hybrid decode state is
O(1) in context => runs the long_500k cell."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,              # MHA in the shared block
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    attn_every=6,
    window=4096,
    mlp_type="glu",
    act="gelu",
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=8,
    attn_every=2,
    window=16,
    mlp_type="glu",
    act="gelu",
    dtype="float32",
)

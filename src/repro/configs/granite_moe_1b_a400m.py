"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32e top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

Small d_ff + many experts => small MoE dispatch groups (DESIGN.md §5)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,                   # per-expert
    vocab_size=49155,
    n_experts=32,
    top_k=8,
    moe_group_size=256,
    mlp_type="glu",
    act="silu",
)

SMOKE = ModelConfig(
    name="granite-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=32,
    vocab_size=512,
    n_experts=4,
    top_k=2,
    moe_group_size=16,
    mlp_type="glu",
    act="silu",
    dtype="float32",
)

"""mamba2-130m [ssm]: 24L d_model=768 (attn-free) d_ff=0 vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060; unverified]

Attention-free: O(1) decode state => runs the long_500k cell."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=12,                 # unused (attn-free); kept for bookkeeping
    n_kv_heads=12,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    mlp_type="none",
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=8,
    mlp_type="none",
    dtype="float32",
)

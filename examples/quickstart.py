"""Quickstart: CI-pruned autotuning benchmarking in ~40 lines.

Tunes the matmul dimensions for *this* machine with the paper's optimized
stop conditions (C+I+O), prints the winner and the search-cost comparison
against the fixed-budget Default methodology.

  PYTHONPATH=src:. python examples/quickstart.py
"""

import dataclasses
import time

from repro.core import EvaluationSettings, Tuner, grid

from benchmarks.common import dgemm_benchmark

# 1. declare the search space (paper Sec. IV: explicit, low-cardinality)
space = grid(n=(256, 512, 1024), m=(256, 512, 1024), k=(64, 128, 256))
print(f"search space: {space}")

# 2. the paper's two methodologies
default = EvaluationSettings(max_invocations=3, max_iterations=30,
                             max_time_s=1.0)
optimized = dataclasses.replace(default, use_ci_convergence=True,
                                use_inner_prune=True, use_outer_prune=True)

# 3. run both; stop condition 4 prunes configurations whose CI upper bound
#    cannot beat the incumbent best
t0 = time.perf_counter()
slow = Tuner(space, default).tune(dgemm_benchmark)
t_default = time.perf_counter() - t0

t0 = time.perf_counter()
fast = Tuner(space, optimized).tune(dgemm_benchmark)
t_opt = time.perf_counter() - t0

err = abs(fast.best_score - slow.best_score) / slow.best_score
print(f"Default  : {slow.best_score:7.1f} GFLOP/s at {slow.best_config} "
      f"({slow.total_samples} samples, {t_default:.1f}s)")
print(f"C+I+O    : {fast.best_score:7.1f} GFLOP/s at {fast.best_config} "
      f"({fast.total_samples} samples, {t_opt:.1f}s, "
      f"{fast.n_pruned} pruned)")
print(f"speedup  : {t_default / t_opt:.1f}x   result error: {err:.2%} "
      f"(paper criterion: < 2%)")

"""Batched serving example: prefill a prompt batch, decode greedily with a
position-tagged KV cache (rolling window for SWA archs).

  PYTHONPATH=src:. python examples/serve_batched.py --arch mixtral_8x22b
  (uses the reduced smoke config of the chosen architecture)
"""

import argparse

from repro.launch.serve import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    result = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                   gen=args.gen, smoke=True)
    print(f"[serve] prefill {result['prefill_s']*1e3:.0f}ms, "
          f"{result['decode_s_per_token']*1e3:.1f}ms/token")
    print("[serve] generated token ids:")
    print(result["tokens"])


if __name__ == "__main__":
    main()

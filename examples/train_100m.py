"""End-to-end driver: train a ~110M-parameter dense LM for a few hundred
steps on the host mesh, with checkpointing + resume.

  PYTHONPATH=src:. python examples/train_100m.py --steps 200

On CPU expect a few seconds/step; pass --steps 30 for a quick check. The
model is a granite-family GQA transformer scaled to ~110M params; data is
the deterministic structured synthetic stream, so the loss has real bigram
signal to descend on.
"""

import argparse
import dataclasses

import repro.configs.granite_3_2b as granite
from repro import configs
from repro.launch.train import train
from repro.models import api
from repro.models import params as P

MODEL_100M = dataclasses.replace(
    granite.CONFIG,
    name="granite-110m",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=3072,
    vocab_size=32768,
    dtype="float32",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    n = P.n_params(api.param_defs(MODEL_100M))
    print(f"[train_100m] {MODEL_100M.name}: {n/1e6:.1f}M params")

    # register the config under a temporary id so the driver can find it
    configs_mod = configs
    import types
    mod = types.ModuleType("repro.configs.granite_110m")
    mod.CONFIG = MODEL_100M
    mod.SMOKE = MODEL_100M
    import sys
    sys.modules["repro.configs.granite_110m"] = mod
    configs_mod.ARCH_IDS.append("granite_110m")

    result = train("granite_110m", steps=args.steps, batch=args.batch,
                   seq=args.seq, smoke=False, ckpt_dir=args.ckpt_dir,
                   ckpt_every=50, peak_lr=1e-3, log_every=10)
    print(f"[train_100m] loss {result['losses'][0]:.4f} -> "
          f"{result['final_loss']:.4f} over {args.steps} steps "
          f"({result['mean_step_s']:.2f}s/step)")


if __name__ == "__main__":
    main()

"""The paper end-to-end: autotune DGEMM + TRIAD, emit this machine's
empirical Roofline model — no vendor spec sheet required.

  PYTHONPATH=src:. python examples/autotune_roofline.py [--full]
"""

import argparse

from benchmarks import bench_roofline_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper Table I budgets (slow)")
    ap.add_argument("--csv", default=None, help="write roofline curve CSV")
    args = ap.parse_args()
    result = bench_roofline_model.run(quick=not args.full)
    if args.csv:
        with open(args.csv, "w") as f:
            f.write(result["csv"])
        print(f"wrote {args.csv}")


if __name__ == "__main__":
    main()

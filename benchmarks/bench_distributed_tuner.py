"""Beyond-paper: execution backends for the CI-pruned search.

Runs the same DGEMM search under the three execution backends — serial
(the paper's loop), thread-pool (live incumbent sharing), and the
simulated fleet with per-round incumbent all-reduce — and reports each
backend's wall-clock, sample count, and found optimum. (On a shared host
concurrent timing perturbs the measured GFLOP/s, so backends can disagree
on noisy hardware; the deterministic-equivalence guarantee is asserted in
``tests/test_executor.py``.) With a
``cache_dir`` (``benchmarks.run --resume``) every backend's trials persist
to a named session and reruns skip completed configs."""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import (ThreadPoolBackend, TrialCache, Tuner,
                        SimulatedShardedBackend)

from .common import dgemm_benchmark, dgemm_space, emit, paper_settings, print_table


def run(quick: bool = True, cache_dir: Optional[str] = None) -> list[dict]:
    space = dgemm_space(quick)
    settings = dataclasses.replace(paper_settings(quick),
                                   use_ci_convergence=True,
                                   use_inner_prune=True,
                                   use_outer_prune=True)
    backends = [("serial", None),
                ("thread4", ThreadPoolBackend(4)),
                ("simulated4", SimulatedShardedBackend(4)),
                ("simulated16", SimulatedShardedBackend(16))]
    rows = []
    serial_wall = None
    for name, backend in backends:
        cache = None
        if cache_dir is not None:
            # one session per backend variant: resume works per-variant and
            # the backends stay comparable (no cross-variant cache hits)
            cache = TrialCache(f"{cache_dir}/dgemm-{name}.jsonl").bound(
                f"dgemm-{name}")
        result = Tuner(space, settings).tune(dgemm_benchmark,
                                             backend=backend, cache=cache)
        wall = result.parallel_time_s
        # an all-cache-hits replay measures nothing: don't let near-zero
        # walls masquerade as scheduling speedup in the table or CSV stream
        replay = result.n_cached == len(result.trials)
        if serial_wall is None and not replay:
            serial_wall = wall
        if replay:
            speedup = "cached"
        elif serial_wall is None:
            speedup = "-"
        else:
            speedup = f"{serial_wall / max(wall, 1e-9):.2f}x"
        rows.append({
            "backend": name,
            "workers": result.n_workers,
            "best_dims": _d(result.best_config),
            "gflops": round(result.best_score, 1),
            "samples": result.total_samples,
            "cached": result.n_cached,
            "wall_s": round(wall, 2),
            "speedup": speedup,
        })
        emit(f"distributed_tuner/{name}", wall * 1e6,
             f"gflops={result.best_score:.1f};samples={result.total_samples}"
             f";cached={result.n_cached}" + (";replay" if replay else ""))
    print_table("Beyond-paper: execution backends for CI-pruned search",
                rows)
    return rows


def _d(cfg):
    return f"{cfg['n']},{cfg['m']},{cfg['k']}" if cfg else "-"


if __name__ == "__main__":
    run()

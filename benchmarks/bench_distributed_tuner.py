"""Beyond-paper: execution backends and search strategies for the
CI-pruned search.

Part one runs the same exhaustive DGEMM search under the execution
backends — serial (the paper's loop), thread-pool (live incumbent
sharing), process-pool (GIL escape, per-batch incumbent all-reduce), and
the simulated fleet — and reports each backend's wall-clock, sample
count, and found optimum. (On a shared host concurrent timing perturbs
the measured GFLOP/s, so backends can disagree on noisy hardware; the
deterministic-equivalence guarantee is asserted in
``tests/test_strategy.py``.) Part two compares the search *strategies* —
exhaustive, successive halving, budgeted random, neighborhood hill-climb
— through the same engine, reporting how many trials/samples each policy
spends to locate its optimum. With a ``cache_dir``
(``benchmarks.run --resume``) every variant's trials persist to a named
session and reruns skip completed configs — except halving, whose rung
trials carry per-rung settings overrides and are persisted but never
replayed (serving a truncated rung as a full result would corrupt the
budget schedule)."""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import (NeighborhoodStrategy, ProcessPoolBackend,
                        RandomSearchStrategy, SimulatedShardedBackend,
                        SuccessiveHalvingStrategy, ThreadPoolBackend,
                        TrialCache, Tuner)
from repro.surrogate import BanditStrategy, SurrogateStrategy

from .common import dgemm_benchmark, dgemm_space, emit, paper_settings, print_table


def run(quick: bool = True, cache_dir: Optional[str] = None) -> list[dict]:
    space = dgemm_space(quick)
    settings = dataclasses.replace(paper_settings(quick),
                                   use_ci_convergence=True,
                                   use_inner_prune=True,
                                   use_outer_prune=True)
    backends = [("serial", None),
                ("thread4", ThreadPoolBackend(4)),
                ("process4", ProcessPoolBackend(4)),
                ("simulated4", SimulatedShardedBackend(4)),
                ("simulated16", SimulatedShardedBackend(16))]
    rows = []
    serial_result = None
    serial_wall = None
    for name, backend in backends:
        cache = None
        if cache_dir is not None:
            # one session per backend variant: resume works per-variant and
            # the backends stay comparable (no cross-variant cache hits)
            cache = TrialCache(f"{cache_dir}/dgemm-{name}.jsonl").bound(
                f"dgemm-{name}")
        result = Tuner(space, settings).tune(dgemm_benchmark,
                                             backend=backend, cache=cache)
        wall = result.parallel_time_s
        # an all-cache-hits replay measures nothing: don't let near-zero
        # walls masquerade as scheduling speedup in the table or CSV stream
        replay = result.n_cached == len(result.trials)
        if serial_wall is None and not replay:
            serial_wall = wall
        if name == "serial":
            serial_result = result
        if replay:
            speedup = "cached"
        elif serial_wall is None:
            speedup = "-"
        else:
            speedup = f"{serial_wall / max(wall, 1e-9):.2f}x"
        rows.append({
            "backend": name,
            "workers": result.n_workers,
            "best_dims": _d(result.best_config),
            "gflops": round(result.best_score, 1),
            "samples": result.total_samples,
            "cached": result.n_cached,
            "wall_s": round(wall, 2),
            "speedup": speedup,
        })
        emit(f"distributed_tuner/{name}", wall * 1e6,
             f"gflops={result.best_score:.1f};samples={result.total_samples}"
             f";cached={result.n_cached}" + (";replay" if replay else ""))
    print_table("Beyond-paper: execution backends for CI-pruned search",
                rows)
    rows += run_strategies(space, settings, quick=quick, cache_dir=cache_dir,
                           exhaustive=serial_result)
    return rows


def run_strategies(space, settings, quick: bool = True,
                   cache_dir: Optional[str] = None,
                   exhaustive=None) -> list[dict]:
    """Strategy comparison through the shared engine (serial backend, so
    trial/sample counts are scheduling-independent). The exhaustive row
    reuses the backend table's serial run when available. The
    model-guided rows (surrogate, bandit) run at the same proposal budget
    as random search, so the table directly shows what the learned
    proposal order buys over blind sampling."""
    budget = max(4, space.cardinality // 3)
    strategies = [("halving", SuccessiveHalvingStrategy()),
                  ("random", RandomSearchStrategy(budget=budget, seed=0)),
                  ("neighborhood", NeighborhoodStrategy(budget=budget)),
                  ("surrogate", SurrogateStrategy(budget=budget, seed=0)),
                  ("bandit", BanditStrategy(budget=budget, seed=0))]
    rows = []
    if exhaustive is not None:
        rows.append(_strategy_row("exhaustive", exhaustive))
    for name, strategy in strategies:
        cache = None
        if cache_dir is not None:
            cache = TrialCache(f"{cache_dir}/dgemm-strat-{name}.jsonl").bound(
                f"dgemm-strat-{name}")
        result = Tuner(space, settings, strategy=strategy).tune(
            dgemm_benchmark, cache=cache)
        rows.append(_strategy_row(name, result))
        emit(f"distributed_tuner/strategy_{name}",
             result.parallel_time_s * 1e6,
             f"gflops={result.best_score:.1f};trials={len(result.trials)}"
             f";samples={result.total_samples}")
    print_table("Beyond-paper: search strategies through the shared engine",
                rows)
    return rows


def _strategy_row(name, result) -> dict:
    return {
        "strategy": name,
        "best_dims": _d(result.best_config),
        "gflops": round(result.best_score, 1),
        "trials": len(result.trials),
        "rounds": len(result.batches),
        "samples": result.total_samples,
        "pruned": result.n_pruned,
        "wall_s": round(result.parallel_time_s, 2),
    }


def _d(cfg):
    return f"{cfg['n']},{cfg['m']},{cfg['k']}" if cfg else "-"


if __name__ == "__main__":
    run()

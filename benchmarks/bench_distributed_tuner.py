"""Beyond-paper: fleet-sharded CI-pruned search (DESIGN.md §8.1).

Shards the DGEMM search space across simulated workers with per-round
incumbent all-reduce; reports the parallel-time speedup and verifies the
distributed search returns the same optimum as the serial one."""

from __future__ import annotations

import dataclasses

from repro.core import Tuner
from repro.distributed.tuner import DistributedTuner

from .common import dgemm_benchmark, dgemm_space, emit, paper_settings, print_table


def run(quick: bool = True) -> list[dict]:
    space = dgemm_space(quick)
    settings = dataclasses.replace(paper_settings(quick),
                                   use_ci_convergence=True,
                                   use_inner_prune=True,
                                   use_outer_prune=True)
    serial = Tuner(space, settings).tune(dgemm_benchmark)
    rows = [{"workers": 1, "best_dims": _d(serial.best_config),
             "gflops": round(serial.best_score, 1),
             "samples": serial.total_samples,
             "parallel_s": round(serial.total_time_s, 2),
             "speedup": "1.00x"}]
    for w in (4, 16):
        dist = DistributedTuner(space, settings, n_workers=w).tune(
            dgemm_benchmark)
        rows.append({
            "workers": w,
            "best_dims": _d(dist.best_config),
            "gflops": round(dist.best_score, 1),
            "samples": dist.total_samples,
            "parallel_s": round(dist.parallel_time_s, 2),
            "speedup": f"{serial.total_time_s / max(dist.parallel_time_s, 1e-9):.2f}x",
        })
        emit(f"distributed_tuner/w{w}", dist.parallel_time_s * 1e6,
             f"gflops={dist.best_score:.1f};samples={dist.total_samples}")
    print_table("Beyond-paper: distributed CI-pruned search", rows)
    return rows


def _d(cfg):
    return f"{cfg['n']},{cfg['m']},{cfg['k']}" if cfg else "-"


if __name__ == "__main__":
    run()

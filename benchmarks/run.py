"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (via ``common.emit``) plus the
human-readable tables. ``--full`` uses the paper's Table I budgets (slow);
the default quick mode preserves every comparison's structure at CI-scale
budgets.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only <name>]
"""

from __future__ import annotations

import argparse
import inspect
import sys
import time

from . import (bench_distributed_tuner, bench_iteration_counts,
               bench_kernel_autotune, bench_matmul_peak, bench_optimizations,
               bench_roofline_model, bench_size_sweep, bench_triad)
from .common import emit

BENCHES = {
    "matmul_peak": bench_matmul_peak.run,          # Tables IV/V
    "triad": bench_triad.run,                      # Table VI
    "iteration_counts": bench_iteration_counts.run,  # Table VII
    "optimizations": bench_optimizations.run,      # Tables VIII-XI (headline)
    "size_sweep": bench_size_sweep.run,            # Fig. 6
    "roofline_model": bench_roofline_model.run,    # Fig. 1
    "kernel_autotune": bench_kernel_autotune.run,  # beyond-paper
    # beyond-paper: execution backends + search-strategy comparison
    "distributed_tuner": bench_distributed_tuner.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper Table I budgets (minutes -> ~1h)")
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    ap.add_argument("--resume", nargs="?", const=".tuning_sessions",
                    default=None, metavar="DIR",
                    help="persist tuning trials under DIR (default "
                         ".tuning_sessions) and skip configs already "
                         "evaluated by a previous --resume run")
    ap.add_argument("--report", action="store_true",
                    help="after the benches, render the cache-backed "
                         "roofline dashboard from the --resume cache dir")
    ap.add_argument("--html", default=None, metavar="PATH",
                    help="after the benches, write a self-contained HTML "
                         "dashboard (rooflines + run-ledger trends and "
                         "regression verdicts) from the --resume cache dir")
    args = ap.parse_args()
    quick = not args.full

    print("name,us_per_call,derived")
    selected = {args.only: BENCHES[args.only]} if args.only else BENCHES
    for name, fn in selected.items():
        kwargs = {"quick": quick}
        # cache-aware benches opt in by taking a cache_dir kwarg
        if (args.resume is not None
                and "cache_dir" in inspect.signature(fn).parameters):
            kwargs["cache_dir"] = args.resume
        t0 = time.perf_counter()
        try:
            fn(**kwargs)
            emit(f"{name}/total", (time.perf_counter() - t0) * 1e6, "ok")
        except Exception as e:  # noqa: BLE001
            emit(f"{name}/total", (time.perf_counter() - t0) * 1e6,
                 f"FAIL:{type(e).__name__}")
            print(f"[benchmarks] {name} failed: {e}", file=sys.stderr)
            raise

    if args.report or args.html:
        import pathlib

        from repro.core import build_reports, load_trials
        from repro.core.report import render_markdown

        cache_dir = pathlib.Path(args.resume or ".tuning_sessions")
        trials = load_trials(cache_dir) if cache_dir.is_dir() else []
        reports, skipped = build_reports(trials)
        if args.report:
            if reports:
                print()
                print(render_markdown(reports, skipped))
            elif skipped:
                print(f"\n[report] no reportable fingerprint under "
                      f"{cache_dir}/:", file=sys.stderr)
                for fp, reason in skipped:
                    print(f"[report]   {fp}: {reason}", file=sys.stderr)
            else:
                print(f"\n[report] no cached trials under {cache_dir}/ — "
                      "run with --resume so roofline_model persists its "
                      "dgemm/triad sessions first.", file=sys.stderr)
        if args.html:
            from repro.history import RunLedger, write_dashboard

            ledger_path = cache_dir / "history.jsonl"
            ledger = RunLedger(ledger_path) if ledger_path.exists() else None
            stamp = time.strftime("%Y-%m-%d %H:%M UTC", time.gmtime())
            write_dashboard(args.html, reports, skipped, ledger=ledger,
                            title="Benchmark dashboard",
                            subtitle=f"generated {stamp} from "
                                     f"{cache_dir}/")
            print(f"[report] wrote {args.html}")


if __name__ == "__main__":
    main()

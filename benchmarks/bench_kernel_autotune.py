"""Beyond-paper: Pallas tile-size autotuning on the dry-run cost model.

The TPU translation of the paper's DGEMM-dimension search: the tunables are
the (bm, bn, bk) VMEM tile sizes of ``repro.kernels.matmul``. With no TPU
attached, the objective is the zero-hardware cost model (DESIGN.md §8.4):
MXU utilization is maximized subject to the VMEM working-set constraint,
and the CI machinery is exercised by benchmarking the same kernel in
interpret mode for functional verification of the winner."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core import (Direction, EvaluationSettings, SearchSpace, Tuner,
                        default_cache, grid)
from repro.kernels.matmul import matmul, matmul_ref, vmem_bytes

from .common import emit, print_table

VMEM_BUDGET = 96 * 1024 * 1024     # leave headroom of the ~128MiB/core
MXU = 128

# target problem: one TP shard of a mixtral expert GEMM
M, N, K = 4096, 2048, 6144


def tile_space() -> SearchSpace:
    tiles = (128, 256, 512, 1024)
    return grid(bm=tiles, bn=tiles, bk=tiles).constrain(
        lambda c: vmem_bytes(c["bm"], c["bn"], c["bk"]) <= VMEM_BUDGET,
        lambda c: M % c["bm"] == 0 and N % c["bn"] == 0 and K % c["bk"] == 0)


def modeled_throughput(cfg: dict) -> float:
    """Cost-model objective (higher is better): MXU-aligned tiles amortize
    the HBM->VMEM streaming; throughput ~ arithmetic intensity of the tile
    loop, penalized by grid-edge underutilization."""
    bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
    tile_flops = 2.0 * bm * bn * bk
    tile_bytes = (bm * bk + bk * bn) * 2.0 + bm * bn * 4.0 / (K // bk)
    intensity = tile_flops / tile_bytes
    align = min(bm, MXU) * min(bn, MXU) / (MXU * MXU)
    return intensity * align


def run(quick: bool = True) -> dict:
    space = tile_space()
    settings = EvaluationSettings(max_invocations=1, max_iterations=3,
                                  max_time_s=1.0,
                                  use_ci_convergence=True,
                                  use_inner_prune=True,
                                  direction=Direction.MAXIMIZE)

    def benchmark(cfg):
        def factory():
            def sample():
                # deterministic cost model + tiny jitter to exercise the CI
                return modeled_throughput(cfg) * (1.0 + 1e-6)
            return sample
        return factory

    result = Tuner(space, settings).tune(benchmark)
    best = result.best_config

    # functional verification of the winning tile in interpret mode;
    # the Pallas wrapper is jit-decorated, so the AOT cache lowers it
    # directly with its declared static_argnames — re-running the bench
    # in-process reuses the compiled executable
    a = jax.random.normal(jax.random.key(0), (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (256, 256), jnp.float32)
    tile = {"bm": min(best["bm"], 256), "bn": min(best["bn"], 256),
            "bk": min(best["bk"], 256), "interpret": True}
    exe = default_cache().compile(matmul, (a, b), static=tile)
    out = exe(a, b)
    err = float(jnp.max(jnp.abs(out - matmul_ref(a, b))))

    rows = [{"quantity": "search space", "value": space.cardinality},
            {"quantity": "best tile",
             "value": f"bm={best['bm']},bn={best['bn']},bk={best['bk']}"},
            {"quantity": "vmem bytes",
             "value": f"{vmem_bytes(best['bm'], best['bn'], best['bk'])>>20}MiB"},
            {"quantity": "modeled I",
             "value": f"{modeled_throughput(best):.0f}"},
            {"quantity": "interpret max err", "value": f"{err:.2e}"}]
    print_table("Beyond-paper: Pallas matmul tile autotuning "
                "(dry-run cost model)", rows)
    emit("kernel_autotune/best_tile", 0.0,
         f"bm={best['bm']};bn={best['bn']};bk={best['bk']};err={err:.1e}")
    assert err < 1e-4
    return {"best": best, "err": err}


if __name__ == "__main__":
    run()

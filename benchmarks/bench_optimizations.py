"""Tables VIII-XI reproduction: evaluation-optimization comparison.

Runs the exhaustive DGEMM autotuning under every technique row of the
paper's tables — Default (fixed sample budget), Single, Confidence (C),
C+Inner, C+I+Outer, each ± search-order Reversal — plus the paper's two
hand-tuned baselines, and reports search time, speedup over Default, and
result error vs the Default's answer (paper criterion: < 2%).
"""

from __future__ import annotations

import dataclasses
import time

from repro.core import EvaluationSettings, Evaluator, Tuner, standard_techniques

from .common import (dgemm_benchmark, dgemm_space, emit, paper_settings,
                     print_table)


def hand_tuned_rows(space, base: EvaluationSettings, ref_time: float,
                    ref_score: float) -> list[dict]:
    """Paper Sec. VI-C: 'Hand-tuned Time' matches the optimized runtime with
    a fixed budget; 'Hand-tuned Accuracy' raises iterations until accuracy
    matches."""
    rows = []
    for label, iters in (("Hand-tuned Time", 3), ("Hand-tuned Accuracy", 12)):
        settings = dataclasses.replace(base, max_invocations=1,
                                       max_iterations=iters)
        t0 = time.perf_counter()
        result = Tuner(space, settings).tune(dgemm_benchmark)
        dt = time.perf_counter() - t0
        err = abs(result.best_score - ref_score) / ref_score
        rows.append({"technique": label,
                     "best_gflops": round(result.best_score, 1),
                     "best_dims": _dims(result.best_config),
                     "time_s": round(dt, 2),
                     "speedup": f"{ref_time / dt:.2f}x",
                     "err_raw": f"{err:.2%}",
                     "err_refined": "-",
                     "samples": result.total_samples,
                     "pruned": result.n_pruned})
    return rows


def _dims(cfg) -> str:
    return f"{cfg['n']},{cfg['m']},{cfg['k']}" if cfg else "-"


def run(quick: bool = True) -> list[dict]:
    space = dgemm_space(quick)
    base = paper_settings(quick)
    techniques = standard_techniques(base)
    # beyond-paper row (the paper's §VII future work): C+I+O with the
    # nonparametric median CI — robust to scheduler-noise spikes that the
    # normal CI (and hence the mean-based rows) are sensitive to
    techniques["C+I+O (median)"] = (dataclasses.replace(
        base, use_ci_convergence=True, use_inner_prune=True,
        use_outer_prune=True, ci_method="median"), "exhaustive")

    rows = []
    results = {}
    t_default = None
    for label, (settings, order) in techniques.items():
        t0 = time.perf_counter()
        result = Tuner(space, settings, order=order).tune(dgemm_benchmark)
        dt = time.perf_counter() - t0
        results[label] = (result, dt)
        if label == "Default":
            t_default = dt
    ref_score = results["Default"][0].best_score

    # refined re-scoring: every technique's WINNING config is re-evaluated
    # under one common fixed long budget, so the result-error column
    # compares configuration choices rather than run-to-run timing jitter
    # (the paper had exclusive SLURM nodes; this container does not)
    refine_settings = dataclasses.replace(
        base, max_invocations=2, max_iterations=120, max_time_s=4.0,
        use_ci_convergence=True)
    refiner = Evaluator(refine_settings)
    refined: dict[str, float] = {}
    for label, (result, _) in results.items():
        key = _dims(result.best_config)
        if key not in refined:
            cfg = result.best_config
            refined[key] = refiner.evaluate(dgemm_benchmark(cfg)).score
    ref_refined = refined[_dims(results["Default"][0].best_config)]

    for label, (result, dt) in results.items():
        err = abs(result.best_score - ref_score) / ref_score
        err_ref = abs(refined[_dims(result.best_config)] - ref_refined) \
            / ref_refined
        rows.append({"technique": label,
                     "best_gflops": round(result.best_score, 1),
                     "best_dims": _dims(result.best_config),
                     "time_s": round(dt, 2),
                     "speedup": f"{t_default / dt:.2f}x",
                     "err_raw": f"{err:.2%}",
                     "err_refined": f"{err_ref:.2%}",
                     "samples": result.total_samples,
                     "pruned": result.n_pruned})
        emit(f"optimizations/{label.replace('+', '_')}", dt * 1e6,
             f"gflops={result.best_score:.1f};err={err_ref:.4f};"
             f"samples={result.total_samples}")

    rows.extend(hand_tuned_rows(space, base, t_default, ref_score))
    print_table("Tables VIII-XI analog: evaluation optimizations "
                f"(|S|={space.cardinality})", rows)
    return rows


if __name__ == "__main__":
    run()

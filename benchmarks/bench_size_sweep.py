"""Fig. 6 analog: per-configuration evaluation time and performance as a
function of matrix size — the basis for the paper's observation that search
*order* matters (reversal starts at the expensive end)."""

from __future__ import annotations

import dataclasses
import time

from repro.core import Evaluator

from .common import dgemm_invocation_factory, emit, paper_settings, print_table

SIZES = [128, 256, 512, 1024, 1536]


def run(quick: bool = True) -> list[dict]:
    settings = dataclasses.replace(paper_settings(quick),
                                   use_ci_convergence=True)
    ev = Evaluator(settings)
    rows = []
    sizes = SIZES[:4] if quick else SIZES
    for n in sizes:
        t0 = time.perf_counter()
        r = ev.evaluate(dgemm_invocation_factory(n, n, n))
        dt = time.perf_counter() - t0
        rows.append({"n=m=k": n, "gflops": round(r.score, 1),
                     "eval_time_s": round(dt, 3),
                     "samples": r.total_samples})
        emit(f"size_sweep/n{n}", dt * 1e6 / max(r.total_samples, 1),
             f"gflops={r.score:.1f}")
    print_table("Fig. 6 analog: time & performance vs matrix size", rows)
    return rows


if __name__ == "__main__":
    run()

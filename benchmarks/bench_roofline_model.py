"""Fig. 1 analog: assemble this host's empirical Roofline model from the
autotuned peaks — the paper's end product (no vendor specs needed).

Rendering goes through :mod:`repro.core.report`, so this bench produces the
same dashboard the cache-backed CLI emits. With ``cache_dir`` set (the
harness's ``--resume``), both tuning runs persist as the ``roofline``
session (benchmarks ``dgemm`` and ``triad``), which makes
``python -m benchmarks.run --resume --report`` a no-re-measuring round trip.
"""

from __future__ import annotations

import dataclasses

from repro.core import (TRIAD_INTENSITY, Tuner, TuningSession, build_reports,
                        grid, hardware_fingerprint, load_trials,
                        operational_intensity, ridge_point,
                        trials_from_result)
from repro.core.report import render_markdown

from .common import (dgemm_benchmark, dgemm_space, emit, paper_settings,
                     print_table, triad_invocation_factory)

TRIAD_SIZES = {"cache": 1 << 22, "dram": 1 << 28}


def run(quick: bool = True, cache_dir: str | None = None) -> dict:
    settings = dataclasses.replace(paper_settings(quick),
                                   use_ci_convergence=True,
                                   use_inner_prune=True,
                                   use_outer_prune=True)
    # Each TRIAD size probes a different memory subsystem: the sizes are
    # measurements, not competitors, so incumbent pruning stays off (a
    # pruned DRAM stream would be a truncated bandwidth estimate).
    triad_settings = dataclasses.replace(settings, use_inner_prune=False,
                                         use_outer_prune=False)
    dgemm_tuner = Tuner(dgemm_space(quick), settings)
    triad_tuner = Tuner(grid(n_bytes=tuple(TRIAD_SIZES.values())),
                        triad_settings)
    triad_bench = lambda cfg: triad_invocation_factory(cfg["n_bytes"])  # noqa: E731

    fp = hardware_fingerprint()
    if cache_dir is not None:
        peak = TuningSession("roofline", dgemm_tuner, dgemm_benchmark,
                             cache_dir=cache_dir,
                             benchmark_name="dgemm").run()
        bw = TuningSession("roofline", triad_tuner, triad_bench,
                           cache_dir=cache_dir,
                           benchmark_name="triad").run()
        # across all fingerprints: a cache carried over from another
        # machine/jax version still renders as its own dashboard section
        trials = load_trials(f"{cache_dir}/roofline.jsonl")
    else:
        peak = dgemm_tuner.tune(dgemm_benchmark)
        bw = triad_tuner.tune(triad_bench)
        trials = (trials_from_result(peak, "dgemm", fp)
                  + trials_from_result(bw, "triad", fp))

    peak_flops = peak.best_score * 1e9
    by_size = {t.config["n_bytes"]: t.result.score for t in bw.trials
               if not t.result.pruned}
    bw_cache = by_size.get(TRIAD_SIZES["cache"], 0.0) * 1e9
    bw_dram = by_size.get(TRIAD_SIZES["dram"], 0.0) * 1e9

    reports, skipped = build_reports(trials)
    dgemm_I = operational_intensity(
        2 * 1024 ** 3, 3 * 1024 * 1024 * 4)  # n=m=k=1024 f32
    rows = [{
        "quantity": "peak compute",
        "value": f"{peak_flops/1e9:.1f} GFLOP/s",
    }, {
        "quantity": "bw (cache)", "value": f"{bw_cache/1e9:.1f} GB/s",
    }, {
        "quantity": "bw (dram)", "value": f"{bw_dram/1e9:.1f} GB/s",
    }, {
        "quantity": "ridge I (dram)",
        "value": f"{ridge_point(peak_flops, max(bw_dram, 1.0)):.1f} FLOP/B",
    }, {
        "quantity": "TRIAD I", "value": f"{TRIAD_INTENSITY:.4f} FLOP/B",
    }, {
        "quantity": "DGEMM-1024 I", "value": f"{dgemm_I:.1f} FLOP/B",
    }]
    print_table("Fig. 1 analog: empirical roofline (this host)", rows)
    print()
    print(render_markdown(reports, skipped))
    emit("roofline/peak_gflops", 0.0, f"{peak_flops/1e9:.1f}")
    emit("roofline/bw_dram_gbps", 0.0, f"{bw_dram/1e9:.1f}")
    # return THIS machine's model: a multi-fingerprint resume cache sorts
    # reports by fingerprint, so index 0 could be a stale machine
    model = next((r.model for r in reports if r.fingerprint == fp), None)
    return {"peak_flops": peak_flops, "bw_dram": bw_dram,
            "bw_cache": bw_cache,
            "csv": model.to_csv() if model is not None else "",
            "reports": reports}


if __name__ == "__main__":
    run()

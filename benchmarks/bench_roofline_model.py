"""Fig. 1 analog: assemble this host's empirical Roofline model from the
autotuned peaks — the paper's end product (no vendor specs needed)."""

from __future__ import annotations

import dataclasses

from repro.core import (Evaluator, TRIAD_INTENSITY, Tuner, from_measurements,
                        operational_intensity, ridge_point)

from .common import (dgemm_benchmark, dgemm_space, emit, paper_settings,
                     print_table, triad_invocation_factory)


def run(quick: bool = True) -> dict:
    settings = dataclasses.replace(paper_settings(quick),
                                   use_ci_convergence=True,
                                   use_inner_prune=True,
                                   use_outer_prune=True)
    # compute ceiling from the autotuned matmul peak
    peak = Tuner(dgemm_space(quick), settings).tune(dgemm_benchmark)
    peak_flops = peak.best_score * 1e9
    # bandwidth slopes from TRIAD at cache-resident and streaming sizes
    ev = Evaluator(settings)
    bw_cache = ev.evaluate(triad_invocation_factory(1 << 22)).score * 1e9
    bw_dram = ev.evaluate(triad_invocation_factory(1 << 28)).score * 1e9

    model = from_measurements("this-host", peak_flops,
                              {"cache": bw_cache, "dram": bw_dram})
    dgemm_I = operational_intensity(
        2 * 1024 ** 3, 3 * 1024 * 1024 * 4)  # n=m=k=1024 f32
    rows = [{
        "quantity": "peak compute",
        "value": f"{peak_flops/1e9:.1f} GFLOP/s",
    }, {
        "quantity": "bw (cache)", "value": f"{bw_cache/1e9:.1f} GB/s",
    }, {
        "quantity": "bw (dram)", "value": f"{bw_dram/1e9:.1f} GB/s",
    }, {
        "quantity": "ridge I (dram)",
        "value": f"{ridge_point(peak_flops, bw_dram):.1f} FLOP/B",
    }, {
        "quantity": "TRIAD I", "value": f"{TRIAD_INTENSITY:.4f} FLOP/B",
    }, {
        "quantity": "DGEMM-1024 I", "value": f"{dgemm_I:.1f} FLOP/B",
    }]
    print_table("Fig. 1 analog: empirical roofline (this host)", rows)
    print(model.ascii_plot(
        "dram", marks=[("T", TRIAD_INTENSITY,
                        model.attainable(TRIAD_INTENSITY, "dram")),
                       ("D", dgemm_I, peak_flops)]))
    emit("roofline/peak_gflops", 0.0, f"{peak_flops/1e9:.1f}")
    emit("roofline/bw_dram_gbps", 0.0, f"{bw_dram/1e9:.1f}")
    return {"peak_flops": peak_flops, "bw_dram": bw_dram,
            "bw_cache": bw_cache, "csv": model.to_csv()}


if __name__ == "__main__":
    run()

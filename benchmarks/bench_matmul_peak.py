"""Tables IV/V analog: autotuned matmul peak for this host.

Finds the (n, m, k) maximizing GFLOP/s with the C+I+O-optimized search and
contrasts the autotuned optimum against the square m=n=k constraint the
paper criticizes (Intel's guide used m=n=k=1000 and reached 52% of peak;
the paper's non-square optima reach 75-98%)."""

from __future__ import annotations

import dataclasses
import time

from repro.core import Evaluator, Tuner

from .common import (dgemm_benchmark, dgemm_invocation_factory, dgemm_space,
                     emit, paper_settings, print_table)


def run(quick: bool = True) -> dict:
    space = dgemm_space(quick)
    settings = dataclasses.replace(paper_settings(quick),
                                   use_ci_convergence=True,
                                   use_inner_prune=True,
                                   use_outer_prune=True)
    t0 = time.perf_counter()
    result = Tuner(space, settings).tune(dgemm_benchmark)
    dt = time.perf_counter() - t0

    # the paper's square-matrix comparison (Intel guide constraint)
    square = space.constrain(lambda c: c["n"] == c["m"] == c["k"])
    best_square, score_square = None, None
    if square.cardinality:
        sq = Tuner(square, settings).tune(dgemm_benchmark)
        best_square, score_square = sq.best_config, sq.best_score
    else:
        # evaluate n=m=k at the middle of the range directly
        n = sorted(space.params[0].values)[len(space.params[0].values) // 2]
        ev = Evaluator(settings)
        score_square = ev.evaluate(dgemm_invocation_factory(n, n, n)).score
        best_square = {"n": n, "m": n, "k": n}

    rows = [{
        "config": "autotuned",
        "dims": f"{result.best_config['n']},{result.best_config['m']},"
                f"{result.best_config['k']}",
        "gflops": round(result.best_score, 1),
        "rel": "1.00x",
    }, {
        "config": "square (m=n=k)",
        "dims": f"{best_square['n']},{best_square['m']},{best_square['k']}",
        "gflops": round(score_square, 1),
        "rel": f"{score_square / result.best_score:.2f}x",
    }]
    print_table("Table IV/V analog: matmul peak (this host)", rows)
    emit("matmul_peak/autotuned", dt * 1e6,
         f"gflops={result.best_score:.1f};dims={rows[0]['dims']}")
    emit("matmul_peak/square", dt * 1e6,
         f"gflops={score_square:.1f};ratio={score_square/result.best_score:.3f}")
    return {"autotuned": result.best_score, "square": score_square,
            "dims": result.best_config}


if __name__ == "__main__":
    run()

"""Table VI analog: TRIAD bandwidth per memory subsystem.

Sweeps the working-set size across cache-resident and DRAM-streaming
regimes (the paper's L3-vs-DRAM distinction) with CI-converged evaluation,
and reports the peak bandwidth of each regime."""

from __future__ import annotations

import dataclasses

from repro.core import Evaluator

from .common import emit, paper_settings, print_table, triad_invocation_factory

# working-set sizes: 256KiB (L2-resident) .. 512MiB (DRAM-streaming)
SIZES = [1 << 18, 1 << 20, 1 << 22, 1 << 24, 1 << 26, 1 << 28, 1 << 29]


def run(quick: bool = True) -> list[dict]:
    settings = dataclasses.replace(paper_settings(quick),
                                   use_ci_convergence=True,
                                   max_iterations=20 if quick else 200)
    ev = Evaluator(settings)
    rows = []
    sizes = SIZES[:5] if quick else SIZES
    for nbytes in sizes:
        r = ev.evaluate(triad_invocation_factory(nbytes))
        regime = "cache" if nbytes <= (1 << 24) else "dram"
        rows.append({"working_set": f"{nbytes >> 20}MiB" if nbytes >= 1 << 20
                     else f"{nbytes >> 10}KiB",
                     "gbytes_per_s": round(r.score, 2),
                     "regime": regime,
                     "samples": r.total_samples})
        emit(f"triad/{nbytes >> 10}KiB", 1e6 / max(r.score, 1e-9),
             f"gbps={r.score:.2f};samples={r.total_samples}")
    peak_cache = max(r["gbytes_per_s"] for r in rows
                     if r["regime"] == "cache")
    peak_dram = max((r["gbytes_per_s"] for r in rows
                     if r["regime"] == "dram"), default=peak_cache)
    print_table("Table VI analog: TRIAD bandwidth (this host)", rows)
    print(f"  peak cache-resident: {peak_cache:.1f} GB/s   "
          f"peak DRAM-stream: {peak_dram:.1f} GB/s")
    return rows


if __name__ == "__main__":
    run()

"""Table VII analog: iteration counts required per stop condition.

The paper reports how many hand-tuned iterations match the optimized
pipeline's time (Iter_T) and accuracy (Iter_A). We report the empirical
per-configuration sample counts the CI machinery actually used: mean/min/
max iterations under Confidence vs the fixed Default budget."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import Tuner

from .common import dgemm_benchmark, dgemm_space, emit, paper_settings, print_table


def run(quick: bool = True) -> list[dict]:
    space = dgemm_space(quick)
    base = paper_settings(quick)
    rows = []
    for label, settings in (
            ("Default", base),
            ("Confidence", dataclasses.replace(base,
                                               use_ci_convergence=True)),
            ("C+I+O", dataclasses.replace(base, use_ci_convergence=True,
                                          use_inner_prune=True,
                                          use_outer_prune=True))):
        result = Tuner(space, settings).tune(dgemm_benchmark)
        counts = [inv.count for t in result.trials
                  for inv in t.result.invocations]
        rows.append({"technique": label,
                     "mean_iters": round(float(np.mean(counts)), 1),
                     "min_iters": int(np.min(counts)),
                     "max_iters": int(np.max(counts)),
                     "total_samples": result.total_samples})
        emit(f"iteration_counts/{label.replace('+', '_')}",
             float(np.mean(counts)),
             f"total={result.total_samples}")
    print_table("Table VII analog: per-configuration iteration counts", rows)
    return rows


if __name__ == "__main__":
    run()

"""Shared benchmark utilities: CSV emission, host matmul/triad objectives.

All benches print ``name,us_per_call,derived`` CSV rows (harness contract)
plus richer per-table output to stderr-safe stdout sections.
"""

from __future__ import annotations

import itertools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import (Direction, EvaluationSettings, SearchSpace, grid,
                        timed_sampler)
from repro.core.searchspace import doubling_from, powers_of_two
from repro.lint import WorkloadSpec

CSV_ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    CSV_ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def print_table(title: str, rows: list[dict]) -> None:
    print(f"\n## {title}")
    if not rows:
        print("(empty)")
        return
    keys = list(rows[0].keys())
    print(" | ".join(f"{k:>14s}" for k in keys))
    for r in rows:
        print(" | ".join(f"{str(r.get(k, '')):>14s}" for k in keys))


# ---------------------------------------------------------------------------
# Host benchmark objectives (the paper's DGEMM / TRIAD on this machine)
# ---------------------------------------------------------------------------
#
# Work terms are computed by the shared helpers below and declared to the
# workload audit (``repro.lint``) through each benchmark's ``audit_spec``
# attribute — the audit traces the *same kernel* with the *same formula*
# the invocation factory uses, so a drifted declaration cannot hide.


def dgemm_flops(n: int, m: int, k: int) -> float:
    """Raw FLOPs of one (n,k)x(k,m) matmul — the DGEMM work term."""
    return 2.0 * n * m * k


def triad_length(n_bytes: int, dtype=jnp.float32) -> int:
    """Vector length for a TRIAD working set of ~n_bytes (three arrays)."""
    return max(1024, n_bytes // (3 * jnp.dtype(dtype).itemsize))


def triad_moved_bytes(n_bytes: int, dtype=jnp.float32) -> float:
    """Raw bytes moved per TRIAD call (read A, read B, write C)."""
    return 3.0 * triad_length(n_bytes, dtype) * jnp.dtype(dtype).itemsize


def triad_kernel(x, y):
    """TRIAD C = A + 3B — shared between the timed factory and the audit."""
    return x + 3.0 * y


def dgemm_invocation_factory(n: int, m: int, k: int,
                             dtype=jnp.float32) -> Callable:
    """One 'program invocation' of the DGEMM benchmark: allocate fresh
    matrices, pre-heat the jitted kernel (the paper pre-heats with one
    untimed call), return a GFLOP/s sampler.

    The data seed is derived from the matrix dimensions plus an invocation
    counter — deterministic across reruns (reproducible cache keys and
    resumable sessions) while still varying between invocations."""
    flops = dgemm_flops(n, m, k)
    invocation = itertools.count()

    def factory():
        seed = (n * 1_000_003 + m * 10_007 + k * 101
                + next(invocation)) % (2 ** 31)
        key = jax.random.key(seed)
        a = jax.random.normal(jax.random.fold_in(key, 1), (n, k), dtype)
        b = jax.random.normal(jax.random.fold_in(key, 2), (k, m), dtype)
        f = jax.jit(jnp.dot)
        jax.block_until_ready(f(a, b))      # pre-heat

        def run():
            jax.block_until_ready(f(a, b))

        return timed_sampler(run, work=flops / 1e9)  # GFLOP/s

    return factory


def triad_invocation_factory(n_bytes: int, dtype=jnp.float32) -> Callable:
    """TRIAD C = A + 3B over vectors totalling ~n_bytes working set."""
    n = triad_length(n_bytes, dtype)
    moved = triad_moved_bytes(n_bytes, dtype)

    def factory():
        key = jax.random.key(n % (2 ** 31))
        a = jax.random.normal(jax.random.fold_in(key, 1), (n,), dtype)
        b = jax.random.normal(jax.random.fold_in(key, 2), (n,), dtype)

        f = jax.jit(triad_kernel)
        jax.block_until_ready(f(a, b))

        def run():
            jax.block_until_ready(f(a, b))

        return timed_sampler(run, work=moved / 1e9)  # GB/s

    return factory


def dgemm_space(quick: bool = True) -> SearchSpace:
    """The paper's reduced DGEMM space (Sec. IV-A), scaled to this host:
    leading dims as multiples of 2 (500-doubling ladder) plus powers of 2."""
    if quick:
        return grid(n=(256, 512, 1024), m=(256, 512, 1024),
                    k=(64, 128, 256, 512))
    return grid(n=doubling_from(500, 4000) + powers_of_two(512, 2048),
                m=doubling_from(500, 4000) + powers_of_two(512, 2048),
                k=powers_of_two(64, 2048))


def paper_settings(quick: bool = True) -> EvaluationSettings:
    """Table I scaled for CI runtime: same structure, smaller budget."""
    if quick:
        return EvaluationSettings(max_invocations=4, max_iterations=60,
                                  max_time_s=1.5,
                                  direction=Direction.MAXIMIZE)
    return EvaluationSettings(max_invocations=10, max_iterations=200,
                              max_time_s=10.0,
                              direction=Direction.MAXIMIZE)


def dgemm_benchmark(cfg: dict) -> Callable:
    return dgemm_invocation_factory(cfg["n"], cfg["m"], cfg["k"])


def triad_benchmark(cfg: dict) -> Callable:
    return triad_invocation_factory(cfg["n_bytes"])


def synthetic_benchmark(cfg: dict) -> Callable:
    """Instant quadratic objective (optimum x=7, score 100) for
    smoke-testing session mechanics without timing noise.

    The three CLI benchmarks are module-level functions (not lambdas) so
    they pickle into ``ProcessPoolBackend`` workers. ``synthetic`` is
    deliberately *not* auditable (no device kernel to trace): it
    exercises the linter's MS100 info path.
    """
    mu = 100.0 - (cfg["x"] - 7) ** 2

    def factory():
        return lambda: mu

    return factory


# -- workload audit declarations (repro.lint pass 1) ------------------------

def dgemm_audit_spec(cfg: dict) -> WorkloadSpec:
    n, m, k = cfg["n"], cfg["m"], cfg["k"]
    dtype = jnp.float32
    return WorkloadSpec(
        fn=jnp.dot,
        args=(jax.ShapeDtypeStruct((n, k), dtype),
              jax.ShapeDtypeStruct((k, m), dtype)),
        work=dgemm_flops(n, m, k), unit="flops", dtype="float32",
        name=f"dgemm[{n}x{m}x{k}]")


def triad_audit_spec(cfg: dict) -> WorkloadSpec:
    n_bytes = cfg["n_bytes"]
    dtype = jnp.float32
    n = triad_length(n_bytes, dtype)
    return WorkloadSpec(
        fn=triad_kernel,
        args=(jax.ShapeDtypeStruct((n,), dtype),
              jax.ShapeDtypeStruct((n,), dtype)),
        work=triad_moved_bytes(n_bytes, dtype), unit="bytes",
        dtype="float32", name=f"triad[{n_bytes}B]")


dgemm_benchmark.audit_spec = dgemm_audit_spec
triad_benchmark.audit_spec = triad_audit_spec

#: benchmarks `scripts/lint.py` audits (pass 1), with a sample config each
AUDITED_WORKLOADS: dict[str, tuple[Callable, dict]] = {
    "dgemm": (dgemm_benchmark, {"n": 256, "m": 256, "k": 64}),
    "triad": (triad_benchmark, {"n_bytes": 1 << 20}),
    "synthetic": (synthetic_benchmark, {"x": 7}),
}

"""Shared benchmark utilities: CSV emission, host matmul/triad objectives.

All benches print ``name,us_per_call,derived`` CSV rows (harness contract)
plus richer per-table output to stderr-safe stdout sections.
"""

from __future__ import annotations

import itertools
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Direction, EvaluationSettings, SearchSpace,
                        default_cache, grid, steady_sampler, timed_sampler)
from repro.core.profiling import trace_instant
from repro.core.searchspace import doubling_from, powers_of_two
from repro.lint import WorkloadSpec

CSV_ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    CSV_ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def print_table(title: str, rows: list[dict]) -> None:
    print(f"\n## {title}")
    if not rows:
        print("(empty)")
        return
    keys = list(rows[0].keys())
    print(" | ".join(f"{k:>14s}" for k in keys))
    for r in rows:
        print(" | ".join(f"{str(r.get(k, '')):>14s}" for k in keys))


# ---------------------------------------------------------------------------
# Host benchmark objectives (the paper's DGEMM / TRIAD on this machine)
# ---------------------------------------------------------------------------
#
# Work terms are computed by the shared helpers below and declared to the
# workload audit (``repro.lint``) through each benchmark's ``audit_spec``
# attribute — the audit traces the *same kernel* with the *same formula*
# the invocation factory uses, so a drifted declaration cannot hide.


def dgemm_flops(n: int, m: int, k: int) -> float:
    """Raw FLOPs of one (n,k)x(k,m) matmul — the DGEMM work term."""
    return 2.0 * n * m * k


def triad_length(n_bytes: int, dtype=jnp.float32) -> int:
    """Vector length for a TRIAD working set of ~n_bytes (three arrays)."""
    return max(1024, n_bytes // (3 * jnp.dtype(dtype).itemsize))


def triad_moved_bytes(n_bytes: int, dtype=jnp.float32) -> float:
    """Raw bytes moved per TRIAD call (read A, read B, write C)."""
    return 3.0 * triad_length(n_bytes, dtype) * jnp.dtype(dtype).itemsize


def triad_kernel(x, y):
    """TRIAD C = A + 3B — shared between the timed factory and the audit."""
    return x + 3.0 * y


def _dgemm_data(n: int, m: int, k: int, seed: int, dtype):
    """Seeded operand generation on the host, then a device transfer.

    Deliberately *not* ``jax.random``: eager threefry compiles a fresh
    XLA kernel per operand shape (~150ms measured on host CPU), so a
    tuning campaign — where every trial visits a cold shape — would pay
    a data-generation compile it never amortizes. A seeded numpy
    Generator is deterministic, shape-oblivious and compile-free, and
    GEMM is data-oblivious, so operand provenance cannot shift the
    measurement."""
    rng = np.random.default_rng(seed)
    a = np.asarray(rng.standard_normal((n, k)), dtype=jnp.dtype(dtype))
    b = np.asarray(rng.standard_normal((k, m)), dtype=jnp.dtype(dtype))
    return jax.device_put(a), jax.device_put(b)


def dgemm_invocation_factory(n: int, m: int, k: int,
                             dtype=jnp.float32, *, exec_cache=None,
                             sampler: str = "timed", batch=None,
                             reuse_data: bool = False) -> Callable:
    """One 'program invocation' of the DGEMM benchmark: allocate fresh
    matrices, pre-heat the kernel (the paper pre-heats with one untimed
    call), return a GFLOP/s sampler.

    The kernel is served by the AOT
    :class:`~repro.core.exec_cache.ExecutableCache` (``exec_cache``,
    default the process-wide one): the first invocation of a config
    compiles, every later one reuses the executable — the pre-heat call
    stays, so first-timed-sample semantics are unchanged.

    ``sampler="steady"`` returns a batched
    :class:`~repro.core.evaluator.steady_sampler` (B async dispatches,
    one sync per observation); the auto-calibrated B is cached across
    invocations so calibration runs once per config. ``reuse_data=True``
    allocates operand data once per *config* instead of once per
    invocation — sound for GEMM on normal data because its runtime is
    data-oblivious, and it removes the dominant setup cost of short
    trials.

    The data seed is derived from the matrix dimensions plus an invocation
    counter — deterministic across reruns (reproducible cache keys and
    resumable sessions) while still varying between invocations."""
    flops = dgemm_flops(n, m, k)
    invocation = itertools.count()
    cache = exec_cache if exec_cache is not None else default_cache()
    state = {"batch": batch, "data": None}

    def factory():
        seed = (n * 1_000_003 + m * 10_007 + k * 101
                + next(invocation)) % (2 ** 31)
        if reuse_data and state["data"] is not None:
            a, b = state["data"]
        else:
            a, b = _dgemm_data(n, m, k, seed, dtype)
            if reuse_data:
                state["data"] = (a, b)
        f = cache.compile(jnp.dot, (a, b))
        jax.block_until_ready(f(a, b))      # pre-heat
        trace_instant("workload", kernel="dgemm", n=n, m=m, k=k,
                      flops=flops, dtype=str(jnp.dtype(dtype)))
        if sampler == "steady":
            s = steady_sampler(lambda: f(a, b), work=flops / 1e9,
                               sync=jax.block_until_ready,
                               batch=state["batch"])
            state["batch"] = s.batch       # calibrate once per config
            return s

        def run():
            jax.block_until_ready(f(a, b))

        return timed_sampler(run, work=flops / 1e9)  # GFLOP/s

    return factory


def triad_invocation_factory(n_bytes: int, dtype=jnp.float32, *,
                             exec_cache=None) -> Callable:
    """TRIAD C = A + 3B over vectors totalling ~n_bytes working set."""
    n = triad_length(n_bytes, dtype)
    moved = triad_moved_bytes(n_bytes, dtype)
    cache = exec_cache if exec_cache is not None else default_cache()

    def factory():
        key = jax.random.key(n % (2 ** 31))
        a = jax.random.normal(jax.random.fold_in(key, 1), (n,), dtype)
        b = jax.random.normal(jax.random.fold_in(key, 2), (n,), dtype)
        f = cache.compile(triad_kernel, (a, b))
        jax.block_until_ready(f(a, b))
        trace_instant("workload", kernel="triad", n=n, bytes=moved,
                      dtype=str(jnp.dtype(dtype)))

        def run():
            jax.block_until_ready(f(a, b))

        return timed_sampler(run, work=moved / 1e9)  # GB/s

    return factory


def dgemm_space(quick: bool = True) -> SearchSpace:
    """The paper's reduced DGEMM space (Sec. IV-A), scaled to this host:
    leading dims as multiples of 2 (500-doubling ladder) plus powers of 2."""
    if quick:
        return grid(n=(256, 512, 1024), m=(256, 512, 1024),
                    k=(64, 128, 256, 512))
    return grid(n=doubling_from(500, 4000) + powers_of_two(512, 2048),
                m=doubling_from(500, 4000) + powers_of_two(512, 2048),
                k=powers_of_two(64, 2048))


def paper_settings(quick: bool = True) -> EvaluationSettings:
    """Table I scaled for CI runtime: same structure, smaller budget."""
    if quick:
        return EvaluationSettings(max_invocations=4, max_iterations=60,
                                  max_time_s=1.5,
                                  direction=Direction.MAXIMIZE)
    return EvaluationSettings(max_invocations=10, max_iterations=200,
                              max_time_s=10.0,
                              direction=Direction.MAXIMIZE)


def dgemm_benchmark(cfg: dict) -> Callable:
    return dgemm_invocation_factory(cfg["n"], cfg["m"], cfg["k"])


def triad_benchmark(cfg: dict) -> Callable:
    return triad_invocation_factory(cfg["n_bytes"])


# -- pipelined-compilation hooks (Tuner.tune submits these to a background
#    CompilePipeline so trial k+1 compiles while trial k measures) ----------

def dgemm_precompile(cfg: dict) -> None:
    """Warm the executable cache for one DGEMM config — ShapeDtypeStructs
    only, nothing is allocated or executed."""
    n, m, k = cfg["n"], cfg["m"], cfg["k"]
    cache = default_cache()
    cache.compile(jnp.dot,
                  (jax.ShapeDtypeStruct((n, k), jnp.float32),
                   jax.ShapeDtypeStruct((k, m), jnp.float32)))


def triad_precompile(cfg: dict) -> None:
    n = triad_length(cfg["n_bytes"])
    cache = default_cache()
    cache.compile(triad_kernel,
                  (jax.ShapeDtypeStruct((n,), jnp.float32),
                   jax.ShapeDtypeStruct((n,), jnp.float32)))


dgemm_benchmark.precompile = dgemm_precompile
triad_benchmark.precompile = triad_precompile


def synthetic_benchmark(cfg: dict) -> Callable:
    """Instant quadratic objective (optimum x=7, score 100) for
    smoke-testing session mechanics without timing noise.

    The three CLI benchmarks are module-level functions (not lambdas) so
    they pickle into ``ProcessPoolBackend`` workers. ``synthetic`` is
    deliberately *not* auditable (no device kernel to trace): it
    exercises the linter's MS100 info path.
    """
    mu = 100.0 - (cfg["x"] - 7) ** 2

    def factory():
        return lambda: mu

    return factory


# ---------------------------------------------------------------------------
# Shape-sweep families (repro.sweep): shape -> benchmark factory
# ---------------------------------------------------------------------------
#
# A *family* specializes the objective to one problem shape; the sweep
# campaign calls it once per grid point. Family closures capture the shape
# (not picklable) — drive campaigns with the serial or thread backend.

#: shared tile ladder of the sweep config space (powers of two, so every
#: k_chunk divides every power-of-two K)
SWEEP_TILES = (16, 32, 64, 128, 256, 512)


def gemm_shape_space(quick: bool = True) -> SearchSpace:
    """The (M, N[, K]) shape grid a sweep campaign tunes: a 3×3 grid of
    the paper's host-scaled DGEMM dims for CI, the full power-of-two
    ladder (with K) otherwise."""
    if quick:
        return grid(m=(256, 512, 1024), n=(256, 512, 1024))
    return grid(m=powers_of_two(256, 4096), n=powers_of_two(256, 4096),
                k=powers_of_two(64, 1024))


def sweep_config_space() -> SearchSpace:
    """Per-shape tunables shared by the sweep families."""
    return grid(bm=SWEEP_TILES, bn=SWEEP_TILES)


def synthetic_gemm_family(shape: dict) -> Callable:
    """Instant shape-conditioned objective for sweep mechanics tests.

    The optimal (bm, bn) tile *level* moves linearly with the shape's
    position on the (log-scale) 256..1024 ladder, and the score is
    quadratic around it — so in the joint encoder's features (config
    level index × log-normalized shape coordinate, both linear) the whole
    surface is exactly degree-2. The ridge surrogate can therefore
    represent it exactly, which makes oracle-interpolation acceptance
    tests sharp: any gap to the true optimum is a harness bug, not model
    bias. Peak score is 100 when the ideal tile lands on a ladder level.
    """
    levels = {v: i for i, v in enumerate(SWEEP_TILES)}
    top = len(SWEEP_TILES) - 1

    def ideal(dim_value: float, lo: float = 256.0, hi: float = 1024.0):
        t = (math.log(dim_value) - math.log(lo)) / (math.log(hi)
                                                    - math.log(lo))
        return top * min(max(t, 0.0), 1.0)

    ia, ib = ideal(shape["m"]), ideal(shape.get("n", shape["m"]))

    def bench(cfg: dict) -> Callable:
        mu = (100.0 - (levels[cfg["bm"]] - ia) ** 2
              - 0.5 * (levels[cfg["bn"]] - ib) ** 2)

        def factory():
            return lambda: mu

        return factory

    return bench


def chunked_dgemm_kernel(a3, b3):
    """DGEMM with the K axis pre-split into (chunks, k_chunk) — one
    einsum contracting both: identical 2·M·N·K flops to ``jnp.dot``,
    different loop/layout structure (the tunable). Shared between the
    timed factory and the workload audit."""
    return jnp.einsum("mck,ckn->mn", a3, b3)


def chunked_dgemm_family(shape: dict) -> Callable:
    """Real measured DGEMM family: C = A·B with A's K axis split into
    ``k_chunk``-wide chunks (snapped down to K when larger). Scores are
    GFLOP/s over the same useful work regardless of chunking, so configs
    compare on time alone."""
    m, n, k = shape["m"], shape["n"], shape.get("k", 256)
    flops = dgemm_flops(m, n, k)
    cache = default_cache()

    def bench(cfg: dict) -> Callable:
        kc = min(cfg["k_chunk"], k)
        chunks = k // kc
        invocation = itertools.count()

        def factory():
            seed = (m * 1_000_003 + n * 10_007 + k * 101 + kc * 13
                    + next(invocation)) % (2 ** 31)
            key = jax.random.key(seed)
            a = jax.random.normal(jax.random.fold_in(key, 1),
                                  (m, chunks, kc), jnp.float32)
            b = jax.random.normal(jax.random.fold_in(key, 2),
                                  (chunks, kc, n), jnp.float32)
            f = cache.compile(chunked_dgemm_kernel, (a, b))
            jax.block_until_ready(f(a, b))      # pre-heat
            trace_instant("workload", kernel="dgemm_sweep", m=m, n=n, k=k,
                          k_chunk=kc, flops=flops)

            def run():
                jax.block_until_ready(f(a, b))

            return timed_sampler(run, work=flops / 1e9)  # GFLOP/s

        return factory

    def sweep_audit_spec(cfg: dict) -> WorkloadSpec:
        kc = min(cfg["k_chunk"], k)
        chunks = k // kc
        return WorkloadSpec(
            fn=chunked_dgemm_kernel,
            args=(jax.ShapeDtypeStruct((m, chunks, kc), jnp.float32),
                  jax.ShapeDtypeStruct((chunks, kc, n), jnp.float32)),
            work=flops, unit="flops", dtype="float32",
            name=f"dgemm_sweep[{m}x{n}x{k}/kc{kc}]")

    def sweep_precompile(cfg: dict) -> None:
        kc = min(cfg["k_chunk"], k)
        chunks = k // kc
        cache.compile(chunked_dgemm_kernel,
                      (jax.ShapeDtypeStruct((m, chunks, kc), jnp.float32),
                       jax.ShapeDtypeStruct((chunks, kc, n), jnp.float32)))

    bench.audit_spec = sweep_audit_spec
    bench.precompile = sweep_precompile
    return bench


def sweep_chunk_space(k_max: int = 512) -> SearchSpace:
    """Config space of :func:`chunked_dgemm_family`."""
    return grid(k_chunk=powers_of_two(16, k_max))


# ---------------------------------------------------------------------------
# Whole-model workloads as tuning objectives (ROADMAP: models ∩ tuner)
# ---------------------------------------------------------------------------
#
# A model step is a benchmark like any other: the config carries the
# StepConfig execution knobs (Pallas flash-attention tiles, remat), the
# score is GFLOP/s over the step's *compiler-reported* work — the same
# helper the audit checks, so the declared-vs-traced lint (MS101) pins
# the conversion constant instead of trusting an analytic 6ND estimate
# that drifts on tiny configs.


def model_step_space(quick: bool = True) -> SearchSpace:
    """Execution-knob space of a whole-model step. ``use_flash`` gates
    the Pallas path (interpret mode on CPU), the tiles only bind when it
    is on — kept in one grid so the tuner sees the interaction."""
    if quick:
        return grid(use_flash=(0, 1), flash_block_q=(64, 128),
                    flash_block_k=(64, 128))
    return grid(use_flash=(0, 1), flash_block_q=(64, 128, 256, 512),
                flash_block_k=(64, 128, 256, 512), remat=(0, 1))


def _model_step(workload: str, arch, cfg: dict, *,
                batch_size: int, seq_len: int):
    """Build one workload under a tuner config (shared by the timed
    factory, the audit spec, and the precompile hook)."""
    from repro.models.transformer import StepConfig
    from repro.models.workloads import build_workload

    step = StepConfig(
        use_flash=bool(cfg.get("use_flash", 0)),
        flash_block_q=int(cfg.get("flash_block_q", 512)),
        flash_block_k=int(cfg.get("flash_block_k", 512)),
        remat=bool(cfg.get("remat", 0)))
    return build_workload(workload, arch, step=step,
                          batch_size=batch_size, seq_len=seq_len)


def model_step_family(workload: str, arch: str | None = None, *,
                      batch_size: int = 2, seq_len: int = 64) -> Callable:
    """Benchmark family for one whole-model step (train/prefill/decode).

    ``workload`` names a :mod:`repro.models.workloads` builder; ``arch``
    picks a smoke-scale architecture (default: the tiny dense toy). The
    returned ``bench(cfg)`` exposes ``audit_spec`` and ``precompile``
    like the microbenchmarks, so model steps ride the same lint, AOT
    cache, and pipelined-compile machinery.
    """
    from repro.models.workloads import workload_static_cost

    def bench(cfg: dict) -> Callable:
        w = _model_step(workload, arch, cfg,
                        batch_size=batch_size, seq_len=seq_len)
        flops = workload_static_cost(w).flops
        state: dict = {"compiled": None}

        def factory():
            if state["compiled"] is None:
                state["compiled"] = w.compiled()
            f = state["compiled"]
            jax.block_until_ready(f(*w.args))   # pre-heat
            trace_instant("workload", kernel=workload,
                          arch=arch or "tiny-dense", flops=flops,
                          **{k: cfg[k] for k in sorted(cfg)})

            def run():
                jax.block_until_ready(f(*w.args))

            return timed_sampler(run, work=flops / 1e9)  # GFLOP/s

        return factory

    def model_audit_spec(cfg: dict) -> WorkloadSpec:
        w = _model_step(workload, arch, cfg,
                        batch_size=batch_size, seq_len=seq_len)
        return WorkloadSpec(
            fn=w.fn, args=w.args,
            work=workload_static_cost(w).flops, unit="flops",
            name=f"{workload}[{arch or 'tiny-dense'}"
                 f" b{batch_size} s{seq_len}]")

    def model_precompile(cfg: dict) -> None:
        w = _model_step(workload, arch, cfg,
                        batch_size=batch_size, seq_len=seq_len)
        w.compiled()

    bench.audit_spec = model_audit_spec
    bench.precompile = model_precompile
    bench.__name__ = f"model_step_{workload}"
    return bench


# -- workload audit declarations (repro.lint pass 1) ------------------------

def dgemm_audit_spec(cfg: dict) -> WorkloadSpec:
    n, m, k = cfg["n"], cfg["m"], cfg["k"]
    dtype = jnp.float32
    return WorkloadSpec(
        fn=jnp.dot,
        args=(jax.ShapeDtypeStruct((n, k), dtype),
              jax.ShapeDtypeStruct((k, m), dtype)),
        work=dgemm_flops(n, m, k), unit="flops", dtype="float32",
        name=f"dgemm[{n}x{m}x{k}]")


def triad_audit_spec(cfg: dict) -> WorkloadSpec:
    n_bytes = cfg["n_bytes"]
    dtype = jnp.float32
    n = triad_length(n_bytes, dtype)
    return WorkloadSpec(
        fn=triad_kernel,
        args=(jax.ShapeDtypeStruct((n,), dtype),
              jax.ShapeDtypeStruct((n,), dtype)),
        work=triad_moved_bytes(n_bytes, dtype), unit="bytes",
        dtype="float32", name=f"triad[{n_bytes}B]")


dgemm_benchmark.audit_spec = dgemm_audit_spec
triad_benchmark.audit_spec = triad_audit_spec

#: benchmarks `scripts/lint.py` audits (pass 1), with a sample config each
AUDITED_WORKLOADS: dict[str, tuple[Callable, dict]] = {
    "dgemm": (dgemm_benchmark, {"n": 256, "m": 256, "k": 64}),
    "triad": (triad_benchmark, {"n_bytes": 1 << 20}),
    "synthetic": (synthetic_benchmark, {"x": 7}),
    # one representative shape of the sweep family: the audit traces the
    # chunked kernel and must see exactly the 2mnk flops it declares
    "dgemm_sweep": (chunked_dgemm_family({"m": 256, "n": 256, "k": 256}),
                    {"k_chunk": 64}),
    # whole-model steps: work terms come from the compiler's own cost
    # analysis (shared helper), so the audit is a determinism check on
    # the GFLOP/s conversion rather than an analytic approximation
    "train_step": (model_step_family("train_step"),
                   {"use_flash": 0, "flash_block_q": 64,
                    "flash_block_k": 64}),
    "decode_step": (model_step_family("decode_step"),
                    {"use_flash": 0, "flash_block_q": 64,
                     "flash_block_k": 64}),
}

"""Semantics of the paper's four stop conditions."""



import repro.core.welford as W
from repro.core.stop_conditions import (CIConverged, Direction, EvalContext,
                                        MaxCount, MaxTime, UpperBoundPrune,
                                        first_decision)


def ctx(samples, elapsed=0.0, incumbent=None,
        direction=Direction.MAXIMIZE):
    state = W.from_samples(samples)
    return EvalContext(welford=state, elapsed_s=elapsed,
                       count=int(state.count), incumbent=incumbent,
                       direction=direction)


def test_max_time():
    cond = MaxTime(10.0)
    assert cond.check(ctx([1, 2], elapsed=5.0)) is None
    assert cond.check(ctx([1, 2], elapsed=10.0)) is not None


def test_max_count():
    cond = MaxCount(3)
    assert cond.check(ctx([1, 2])) is None
    d = cond.check(ctx([1, 2, 3]))
    assert d is not None and not d.pruned


def test_ci_converged_low_variance():
    cond = CIConverged(confidence=0.99, rel_margin=0.01, min_count=5)
    # essentially zero variance -> converges immediately past min_count
    assert cond.check(ctx([10.0] * 4)) is None          # below min_count
    tight = [10.0, 10.001, 9.999, 10.0, 10.001, 10.0]
    assert cond.check(ctx(tight)) is not None
    noisy = [10.0, 14.0, 6.0, 11.0, 9.0, 13.0]
    assert cond.check(ctx(noisy)) is None


def test_upper_bound_prune_maximize():
    """Paper Listing 1: break when mean + marg < best."""
    cond = UpperBoundPrune(confidence=0.99, min_count=2)
    doomed = [5.0, 5.1, 4.9, 5.0, 5.05]
    d = cond.check(ctx(doomed, incumbent=10.0))
    assert d is not None and d.pruned
    # competitive configuration must NOT be pruned
    close = [9.9, 10.1, 10.0, 9.95]
    assert cond.check(ctx(close, incumbent=10.0)) is None
    # no incumbent -> never prune
    assert cond.check(ctx(doomed, incumbent=None)) is None


def test_upper_bound_prune_minimize():
    cond = UpperBoundPrune(confidence=0.99, min_count=2)
    doomed = [5.0, 5.1, 4.9]  # much SLOWER than incumbent 1.0 (minimize)
    d = cond.check(ctx(doomed, incumbent=1.0,
                       direction=Direction.MINIMIZE))
    assert d is not None and d.pruned
    winner = [0.5, 0.52, 0.48]
    assert cond.check(ctx(winner, incumbent=1.0,
                          direction=Direction.MINIMIZE)) is None


def test_min_count_guard():
    """The paper's guard for slow-warm-up configurations (min_count=100 on
    the 2695v4)."""
    cond = UpperBoundPrune(min_count=100)
    doomed = [5.0] * 50
    assert cond.check(ctx(doomed, incumbent=10.0)) is None


def test_first_decision_order():
    conds = [MaxTime(1.0), MaxCount(2)]
    d = first_decision(conds, ctx([1, 2], elapsed=2.0))
    assert "max_time" in d.reason


def test_direction_better():
    assert Direction.MAXIMIZE.better(2.0, 1.0)
    assert Direction.MINIMIZE.better(1.0, 2.0)
    assert not Direction.MAXIMIZE.better(1.0, 1.0)

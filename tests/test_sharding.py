"""Sharding-rule resolution: divisibility fallback, axis consumption."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def mesh():
    # host mesh: (data=n_devices, model=1)
    return make_host_mesh(model=1)


def test_divisible_dims_get_sharded(mesh):
    n = mesh.shape["data"]
    spec = sh.logical_to_spec(("batch", None), (4 * n, 7),
                              sh.SERVE_RULES, mesh)
    assert spec == P("data", None)


def test_indivisible_dims_fall_back_to_replication(mesh):
    n = mesh.shape["data"]
    if n == 1:
        pytest.skip("single-device mesh shards everything")
    spec = sh.logical_to_spec(("batch",), (n + 1,), sh.SERVE_RULES, mesh)
    assert spec == P(None)


def test_axis_used_once(mesh):
    """Two dims mapping to the same mesh axis: first one wins."""
    rules = sh.ShardingRules(rules={"a": ("data",), "b": ("data",)})
    n = mesh.shape["data"]
    spec = sh.logical_to_spec(("a", "b"), (n, n), rules, mesh)
    assert spec == P("data", None)


def test_missing_mesh_axis_ignored(mesh):
    rules = sh.ShardingRules(rules={"x": ("pod", "data")})
    n = mesh.shape["data"]
    spec = sh.logical_to_spec(("x",), (n,), rules, mesh)
    assert spec == P("data")  # "pod" absent from host mesh -> skipped


def test_multi_axis_dim():
    """A dim divisible by the product of two axes gets both."""
    import numpy as np
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = make_host_mesh(model=1)
    rules = sh.ShardingRules(rules={"batch": ("data", "model")})
    total = mesh.shape["data"] * mesh.shape["model"]
    spec = sh.logical_to_spec(("batch",), (total * 2,), rules, mesh)
    expected = [ax for ax in ("data", "model") if mesh.shape[ax] > 1] or None
    # with model=1 mesh, only "data" participates meaningfully; both valid
    assert spec[0] is not None


def test_rules_replace():
    new = sh.TRAIN_RULES.replace(act_seq=())
    assert new.get("act_seq") == ()
    assert sh.TRAIN_RULES.get("act_seq") == ("model",)


def test_spec_tree_matches_defs(mesh):
    from repro import configs
    from repro.models import api
    cfg = configs.get_smoke("granite_3_2b")
    defs = api.param_defs(cfg)
    specs = sh.spec_tree(defs, sh.TRAIN_RULES, mesh)
    flat_d = jax.tree.leaves(defs, is_leaf=lambda x: hasattr(x, "logical"))
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_d) == len(flat_s)

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.matmul import matmul, matmul_ref, vmem_bytes
from repro.kernels.triad import triad, triad_ref

KEY = jax.random.key(0)


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


def _contraction_tol(dtype):
    # looser f32 bound: the blocked kernel's accumulation order differs from
    # the unblocked oracle over contraction dims of a few hundred
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 512, 384),
                                   (300, 450, 200), (64, 64, 64),
                                   (1024, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes_dtypes(m, n, k, dtype):
    a = jax.random.normal(jax.random.fold_in(KEY, m + n), (m, k), dtype)
    b = jax.random.normal(jax.random.fold_in(KEY, k), (k, n), dtype)
    out = matmul(a, b, bm=128, bn=128, bk=64, interpret=True)
    ref = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               **_contraction_tol(dtype))


@pytest.mark.parametrize("bm,bn,bk", [(64, 64, 64), (128, 256, 64),
                                      (256, 128, 128)])
def test_matmul_block_sweep(bm, bn, bk):
    a = jax.random.normal(jax.random.fold_in(KEY, 1), (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (256, 256), jnp.float32)
    out = matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=2e-5, atol=2e-5)


def test_matmul_vmem_accounting():
    # (bm*bk + bk*bn + bm*bn)*2 + bm*bn*4 bytes
    assert vmem_bytes(128, 128, 128, 2) == (3 * 128 * 128) * 2 + 128 * 128 * 4


# ---------------------------------------------------------------------------
# triad
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1024, 4096, 100_000, 1_048_576 + 17])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_triad_sizes(n, dtype):
    a = jax.random.normal(jax.random.fold_in(KEY, n), (n,), dtype)
    b = jax.random.normal(jax.random.fold_in(KEY, n + 1), (n,), dtype)
    out = triad(a, b, gamma=3.0, br=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(triad_ref(a, b, 3.0), np.float32),
                               **_tol(dtype))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hq,hkv", [(8, 8), (8, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_attention_gqa_causal(hq, hkv, causal):
    q = jax.random.normal(jax.random.fold_in(KEY, hq), (2, hq, 256, 64))
    k = jax.random.normal(jax.random.fold_in(KEY, hkv), (2, hkv, 256, 64))
    v = jax.random.normal(jax.random.fold_in(KEY, 9), (2, hkv, 256, 64))
    out = flash_attention(q, k, v, causal=causal, bq=64, bk=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [32, 96, 256])
def test_attention_sliding_window(window):
    q = jax.random.normal(jax.random.fold_in(KEY, window), (1, 4, 256, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, window + 1), (1, 2, 256, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, window + 2), (1, 2, 256, 32))
    out = flash_attention(q, k, v, causal=True, window=window, bq=64, bk=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("s", [100, 200, 250])
def test_attention_padded_lengths(s):
    q = jax.random.normal(jax.random.fold_in(KEY, s), (1, 4, s, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, s + 1), (1, 4, s, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, s + 2), (1, 4, s, 32))
    out = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_attention_bf16():
    q = jax.random.normal(jax.random.fold_in(KEY, 77), (1, 4, 128, 64),
                          jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(KEY, 78), (1, 4, 128, 64),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(KEY, 79), (1, 4, 128, 64),
                          jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, bq=128, bk=128,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=3e-2,
                               atol=3e-2)


def test_online_softmax_matches_xla_chunked():
    """The model zoo's XLA q-chunked path vs the kernel (same algorithm)."""
    from repro.models.layers import _attend
    q = jax.random.normal(jax.random.fold_in(KEY, 100), (1, 4, 2048, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 101), (1, 2, 2048, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 102), (1, 2, 2048, 32))
    chunked = _attend(q, k, v, causal=True, window=None)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)

"""Model-guided search: encoding, surrogates, acquisition, and the
surrogate/bandit strategies through the shared engine — including the
acceptance criterion (optimum at ≤ 40% of the exhaustive budget on serial
and process backends, with strategy attribution in cache, ledger, and
dashboards)."""

import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (Direction, EvaluationSettings, ProcessPoolBackend,
                        SearchSpace, TrialCache, Tuner, compare_techniques,
                        grid, param)
from repro.core.welford import WelfordState, from_samples
from repro.surrogate import (BanditStrategy, BayesianRidgeSurrogate,
                             KNNSurrogate, SpaceEncoder, SurrogateStrategy,
                             expected_improvement, is_ordinal, make_surrogate,
                             noise_adjusted_best, poly_dim,
                             upper_confidence_bound)

SETTINGS = EvaluationSettings(max_invocations=3, max_iterations=20,
                              use_ci_convergence=True, use_inner_prune=True,
                              use_outer_prune=True)


def surface_benchmark(cfg):
    """Deterministic module-level 2-D objective — picklable for the
    process pool — with the optimum at (a=5, b=3), score 100."""
    mu = 100.0 - (cfg["a"] - 5) ** 2 - 0.5 * (cfg["b"] - 3) ** 2

    def factory():
        return lambda: mu

    return factory


def surface_space() -> SearchSpace:
    return grid(a=tuple(range(8)), b=tuple(range(8)))


# ---------------------------------------------------------------------------
# Encoding
# ---------------------------------------------------------------------------


def test_ordinal_params_encode_as_normalized_level_index():
    space = grid(n=(256, 512, 1024), k=(64, 4096))
    enc = SpaceEncoder(space)
    assert enc.dim == 2
    assert enc.feature_names == ("n", "k")
    # level index, not raw value: the geometric ladder spreads uniformly
    assert enc.encode({"n": 256, "k": 64}).tolist() == [0.0, 0.0]
    assert enc.encode({"n": 512, "k": 4096}).tolist() == [0.5, 1.0]
    assert enc.encode({"n": 1024, "k": 64}).tolist() == [1.0, 0.0]


def test_categorical_params_encode_one_hot():
    space = SearchSpace([param("order", ("nmk", "nkm", "knm")),
                         param("n", (1, 2))])
    assert not is_ordinal(space.params[0])
    assert is_ordinal(space.params[1])
    enc = SpaceEncoder(space)
    assert enc.dim == 4
    assert enc.feature_names == ("order=nmk", "order=nkm", "order=knm", "n")
    assert enc.encode({"order": "nkm", "n": 2}).tolist() == [0, 1, 0, 1.0]


def test_bools_are_categorical_not_ordinal():
    space = SearchSpace([param("fuse", (False, True))])
    enc = SpaceEncoder(space)
    assert enc.dim == 2          # one-hot: no order-distance between flags
    assert enc.encode({"fuse": True}).tolist() == [0.0, 1.0]


def test_encode_all_shape_and_out_of_domain():
    space = grid(x=(1, 2, 3))
    enc = SpaceEncoder(space)
    X = enc.encode_all(space.ordered("exhaustive"))
    assert X.shape == (3, 1)
    assert enc.encode_all([]).shape == (0, 1)
    with pytest.raises(KeyError):
        enc.encode({"x": 99})


# ---------------------------------------------------------------------------
# Surrogate models
# ---------------------------------------------------------------------------


def test_ridge_learns_quadratic_and_uncertainty_shrinks():
    rng = np.random.default_rng(0)
    model = BayesianRidgeSurrogate(dim=1)
    f = lambda x: 10.0 - 8.0 * (x - 0.6) ** 2        # noqa: E731
    xs = rng.uniform(0, 1, size=12)
    for x in xs:
        model.observe(np.array([x]), f(x))
    grid_x = np.linspace(0, 1, 21)[:, None]
    mean, std = model.predict(grid_x)
    # the degree-2 expansion represents the target exactly
    assert np.allclose(mean, [f(x) for x in grid_x[:, 0]], atol=0.3)
    assert int(np.argmax(mean)) == 12                # x = 0.6
    # more data ⇒ tighter posterior everywhere
    before = std.mean()
    for x in rng.uniform(0, 1, size=24):
        model.observe(np.array([x]), f(x))
    _, after = model.predict(grid_x)
    assert after.mean() < before
    assert model.n_observed == 36


def test_ridge_predicts_prior_before_any_observation():
    model = BayesianRidgeSurrogate(dim=2)
    mean, std = model.predict(np.zeros((3, 2)))
    assert mean.shape == std.shape == (3,)
    assert np.all(std > 0)
    assert model.n_observed == 0


def test_knn_interpolates_and_grows_uncertainty_with_distance():
    model = KNNSurrogate(dim=1, k=2)
    for x, y in [(0.0, 1.0), (0.5, 2.0), (1.0, 3.0)]:
        model.observe(np.array([x]), y)
    mean, std = model.predict(np.array([[0.5], [10.0]]))
    assert mean[0] == pytest.approx(2.0, abs=0.2)    # on a data point
    assert std[1] > std[0]                           # far away ⇒ uncertain


def test_make_surrogate_auto_picks_knn_for_tiny_spaces():
    assert make_surrogate("auto", dim=1, cardinality=12).name == "knn"
    assert make_surrogate("auto", dim=2, cardinality=64).name == "ridge"
    assert make_surrogate("ridge", dim=3, cardinality=4).name == "ridge"
    with pytest.raises(ValueError):
        make_surrogate("gp", dim=1, cardinality=10)
    assert poly_dim(2) == 6          # 1 + 2 + 3


# ---------------------------------------------------------------------------
# Acquisition
# ---------------------------------------------------------------------------


def test_expected_improvement_prefers_mean_then_uncertainty():
    best = 10.0
    mean = np.array([9.0, 11.0, 11.0, 9.9])
    std = np.array([0.0, 0.0, 0.0, 2.0])
    ei = expected_improvement(mean, std, best, xi=0.0)
    assert ei[0] == 0.0                      # below best, no uncertainty
    assert ei[1] == pytest.approx(1.0)       # certain improvement = delta
    assert ei[3] > 0.0                       # uncertain near-best: worth a try
    # equal means: the more uncertain candidate wins
    ei2 = expected_improvement(np.array([9.5, 9.5]), np.array([0.1, 2.0]),
                               best, xi=0.0)
    assert ei2[1] > ei2[0]


def test_expected_improvement_minimize_direction():
    ei = expected_improvement(np.array([5.0, 15.0]), np.array([0.0, 0.0]),
                              best=10.0, direction=Direction.MINIMIZE,
                              xi=0.0)
    assert ei[0] == pytest.approx(5.0)       # 5 below the incumbent
    assert ei[1] == 0.0


def test_ucb_uses_the_papers_normal_quantile():
    from repro.core import normal_quantile
    mean, std = np.array([1.0]), np.array([2.0])
    ucb = upper_confidence_bound(mean, std, confidence=0.99)
    assert ucb[0] == pytest.approx(1.0 + normal_quantile(0.99) * 2.0)
    lcb = upper_confidence_bound(mean, std, direction=Direction.MINIMIZE,
                                 confidence=0.99)
    assert lcb[0] == pytest.approx(-1.0 + normal_quantile(0.99) * 2.0)


def test_noise_adjusted_best_is_the_ci_bound_facing_the_search():
    state = from_samples([10.0, 10.5, 9.5, 10.2, 9.8])
    hi = noise_adjusted_best(state, 0.99, Direction.MAXIMIZE)
    lo = noise_adjusted_best(state, 0.99, Direction.MINIMIZE)
    assert lo < float(state.mean) < hi
    # degenerate stream: unbounded CI falls back to the mean
    one = WelfordState(count=1.0, mean=42.0, m2=0.0)
    assert noise_adjusted_best(one, 0.99, Direction.MAXIMIZE) == 42.0


# ---------------------------------------------------------------------------
# SurrogateStrategy through the engine
# ---------------------------------------------------------------------------


def test_surrogate_respects_budget_and_never_repeats():
    result = Tuner(surface_space(), SETTINGS,
                   strategy=SurrogateStrategy(budget=20, seed=0)).tune(
        surface_benchmark)
    assert len(result.trials) == 20
    keys = {(t.config["a"], t.config["b"]) for t in result.trials}
    assert len(keys) == 20                   # without replacement
    assert result.strategy == "surrogate"


def test_surrogate_identical_seed_identical_proposals():
    runs = [Tuner(surface_space(), SETTINGS,
                  strategy=SurrogateStrategy(budget=16, seed=7)).tune(
        surface_benchmark) for _ in range(2)]
    assert [t.config for t in runs[0].trials] == \
        [t.config for t in runs[1].trials]


@pytest.mark.parametrize("acquisition", ["ei", "ucb"])
@pytest.mark.parametrize("model", ["auto", "knn"])
def test_surrogate_variants_find_good_configs(model, acquisition):
    result = Tuner(surface_space(), SETTINGS,
                   strategy=SurrogateStrategy(budget=24, seed=1, model=model,
                                              acquisition=acquisition)).tune(
        surface_benchmark)
    assert result.best_score >= 98.0         # within the paper's 2% budget


def test_surrogate_seeds_evaluated_first():
    result = Tuner(surface_space(), SETTINGS,
                   strategy=SurrogateStrategy(budget=8, seed=0)).tune(
        surface_benchmark, seeds=[{"a": 5, "b": 3}])
    assert result.trials[0].config == {"a": 5, "b": 3}
    assert result.n_seeded == 1
    assert result.best_score == pytest.approx(100.0)


def test_surrogate_budget_above_cardinality_sweeps_everything():
    space = grid(x=tuple(range(6)))
    result = Tuner(space, SETTINGS,
                   strategy=SurrogateStrategy(budget=50, seed=0)).tune(
        surface_benchmark_1d)
    assert len(result.trials) == 6           # exhausted, then stopped


def surface_benchmark_1d(cfg):
    mu = 100.0 - (cfg["x"] - 3) ** 2

    def factory():
        return lambda: mu

    return factory


def test_surrogate_invalid_arguments():
    with pytest.raises(ValueError):
        SurrogateStrategy(budget=0)
    with pytest.raises(ValueError):
        SurrogateStrategy(acquisition="pi")
    with pytest.raises(ValueError):
        SurrogateStrategy(n_init=0)


# ---------------------------------------------------------------------------
# Acceptance: optimum at ≤ 40% of the exhaustive budget, serial AND
# process backends, with strategy attribution everywhere downstream
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend_factory",
                         [lambda: None, lambda: ProcessPoolBackend(2)],
                         ids=["serial", "process"])
def test_surrogate_reaches_exhaustive_incumbent_under_40pct(tmp_path,
                                                            backend_factory):
    from repro.history import RunLedger, render_html

    space = surface_space()
    exhaustive = Tuner(space, SETTINGS).tune(surface_benchmark)
    budget = int(space.cardinality * 0.4)    # the acceptance ceiling
    assert budget < space.cardinality

    cache = TrialCache(tmp_path / "s.jsonl", fingerprint="fp")
    ledger = RunLedger(tmp_path / "history.jsonl")
    result = Tuner(space, SETTINGS,
                   strategy=SurrogateStrategy(budget=budget, seed=0)).tune(
        surface_benchmark, backend=backend_factory(),
        cache=cache.bound("surface"),
        ledger=ledger.bound("surface", "fp"), timestamp=1_700_000_000.0)

    assert len(result.trials) <= budget
    within_2pct = abs(result.best_score - exhaustive.best_score) \
        <= 0.02 * abs(exhaustive.best_score)
    assert result.best_config == exhaustive.best_config or within_2pct
    # attribution: every cache record carries the producing strategy...
    trials = cache.trials()
    assert trials and all(t.strategy == "surrogate" for t in trials)
    # ...the ledger's distilled run record does too...
    (run,) = ledger.series("surface", "fp")
    assert run.strategy == "surrogate"
    assert run.config == result.best_config
    # ...and the HTML trend dashboard renders it in the strategy column
    html = render_html(ledger=ledger)
    assert "surrogate" in html


def test_bandit_reaches_exhaustive_incumbent_under_40pct():
    space = surface_space()
    exhaustive = Tuner(space, SETTINGS).tune(surface_benchmark)
    budget = int(space.cardinality * 0.4)
    result = Tuner(space, SETTINGS,
                   strategy=BanditStrategy(budget=budget, seed=0)).tune(
        surface_benchmark)
    assert len(result.trials) <= budget
    within_2pct = abs(result.best_score - exhaustive.best_score) \
        <= 0.02 * abs(exhaustive.best_score)
    assert result.best_config == exhaustive.best_config or within_2pct


# ---------------------------------------------------------------------------
# BanditStrategy specifics
# ---------------------------------------------------------------------------


def test_bandit_identical_seed_identical_proposals():
    runs = [Tuner(surface_space(), SETTINGS,
                  strategy=BanditStrategy(budget=16, seed=5)).tune(
        surface_benchmark) for _ in range(2)]
    assert [t.config for t in runs[0].trials] == \
        [t.config for t in runs[1].trials]


def test_bandit_exhausts_small_space_and_stops():
    space = grid(x=tuple(range(5)))
    result = Tuner(space, SETTINGS,
                   strategy=BanditStrategy(budget=40, seed=0)).tune(
        surface_benchmark_1d)
    assert len(result.trials) == 5           # feasible space exhausted
    assert result.best_config == {"x": 3}


def test_bandit_respects_constraints():
    space = grid(x=tuple(range(8))).constrain(lambda c: c["x"] % 2 == 0)
    result = Tuner(space, SETTINGS,
                   strategy=BanditStrategy(budget=10, seed=0)).tune(
        surface_benchmark_1d)
    assert all(t.config["x"] % 2 == 0 for t in result.trials)
    assert len(result.trials) == 4


def test_bandit_seeds_evaluated_first():
    result = Tuner(surface_space(), SETTINGS,
                   strategy=BanditStrategy(budget=6, seed=0)).tune(
        surface_benchmark, seeds=[{"a": 5, "b": 3}])
    assert result.trials[0].config == {"a": 5, "b": 3}
    assert result.best_score == pytest.approx(100.0)


def test_bandit_minimize_direction():
    import dataclasses
    settings = dataclasses.replace(SETTINGS, direction=Direction.MINIMIZE,
                                   use_inner_prune=False,
                                   use_outer_prune=False)
    space = grid(x=tuple(range(8)))

    result = Tuner(space, settings,
                   strategy=BanditStrategy(budget=8, seed=0)).tune(
        valley_benchmark)
    assert result.best_config == {"x": 2}


def valley_benchmark(cfg):
    mu = (cfg["x"] - 2) ** 2

    def factory():
        return lambda: mu

    return factory


def test_bandit_invalid_arguments():
    with pytest.raises(ValueError):
        BanditStrategy(budget=0)
    with pytest.raises(ValueError):
        BanditStrategy(batch=0)


# ---------------------------------------------------------------------------
# compare_techniques: model-guided rows next to the paper's grid
# ---------------------------------------------------------------------------


def test_compare_techniques_accepts_strategy_rows():
    space = grid(x=tuple(range(10)))
    out = compare_techniques(
        space, surface_benchmark_1d, SETTINGS,
        techniques={
            "C+I+O": (SETTINGS, "exhaustive"),
            "Surrogate": (SETTINGS, SurrogateStrategy(budget=6, seed=0)),
            "Bandit": (SETTINGS, BanditStrategy(budget=6, seed=0)),
        })
    assert out["C+I+O"].strategy == "exhaustive"
    assert out["Surrogate"].strategy == "surrogate"
    assert out["Bandit"].strategy == "bandit"
    assert len(out["Surrogate"].trials) <= 6
    assert out["C+I+O"].best_config == {"x": 3}


# ---------------------------------------------------------------------------
# CLI: --strategy surrogate|bandit on the synthetic benchmark
# ---------------------------------------------------------------------------

REPO = pathlib.Path(__file__).resolve().parent.parent


def _run_tune_cli(tmp_path, *argv):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / "tune.py"),
         "--cache-dir", str(tmp_path), *map(str, argv)],
        capture_output=True, text=True, env=env, timeout=300)


def test_cli_surrogate_strategy_on_synthetic(tmp_path):
    proc = _run_tune_cli(tmp_path, "--session", "s",
                         "--benchmark", "synthetic",
                         "--strategy", "surrogate", "--budget", "8",
                         "--seed", "0")
    assert proc.returncode == 0, proc.stderr
    assert "strategy   : surrogate (acquisition=ei)" in proc.stdout
    assert "best      : {'x': 7}" in proc.stdout
    assert "strategy  : surrogate" in proc.stdout
    # the session cache annotates every record with the strategy
    from repro.core.cache import iter_trials
    trials = list(iter_trials(tmp_path / "s.jsonl"))
    assert trials and all(t.strategy == "surrogate" for t in trials)
    assert len(trials) <= 8


def test_cli_bandit_strategy_on_synthetic(tmp_path):
    proc = _run_tune_cli(tmp_path, "--session", "b",
                         "--benchmark", "synthetic",
                         "--strategy", "bandit", "--budget", "9",
                         "--seed", "0")
    assert proc.returncode == 0, proc.stderr
    assert "strategy   : bandit" in proc.stdout
    assert "best      : {'x': 7}" in proc.stdout

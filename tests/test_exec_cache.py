"""AOT executable cache, compile pipeline, phase profiler, and the
batched steady-state sampler's conformance with the classic timed one."""

import threading
import time

import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import (BatchCalibration, CompilePipeline,  # noqa: E402
                        EvaluationSettings, ExecutableCache, PhaseProfiler,
                        Tuner, calibrate_batch, grid, phase, steady_sampler,
                        timed_sampler)


def _add(a, b):
    return a + b


def _scale(a, s):
    return a * s


# ---------------------------------------------------------------------------
# ExecutableCache keying
# ---------------------------------------------------------------------------

def test_same_key_hits_different_shape_misses():
    cache = ExecutableCache(fingerprint="test")
    a = jnp.ones((4, 4))
    exe1 = cache.compile(_add, (a, a))
    exe2 = cache.compile(_add, (a, a))
    assert exe1 is exe2
    s = cache.stats
    assert (s.misses, s.hits, s.compiles) == (1, 1, 1)

    wide = jnp.ones((4, 8))
    cache.compile(_add, (wide, wide))        # new shape -> new executable
    assert cache.stats.compiles == 2


def test_dtype_changes_the_key():
    cache = ExecutableCache(fingerprint="test")
    cache.compile(_add, (jnp.ones((4,), jnp.float32),) * 2)
    cache.compile(_add, (jnp.ones((4,), jnp.int32),) * 2)
    assert cache.stats.compiles == 2


def test_static_config_changes_the_key_and_the_code():
    cache = ExecutableCache(fingerprint="test")
    a = jnp.ones((3,))
    exe2 = cache.compile(_scale, (a,), static={"s": 2})
    exe3 = cache.compile(_scale, (a,), static={"s": 3})
    assert cache.stats.compiles == 2         # config is compiled in
    assert float(exe2(a)[0]) == 2.0
    assert float(exe3(a)[0]) == 3.0


def test_device_fingerprint_is_part_of_the_key():
    c1 = ExecutableCache(fingerprint="hw-a")
    c2 = ExecutableCache(fingerprint="hw-b")
    a = jnp.ones((2, 2))
    assert c1.key_for(_add, (a, a)) != c2.key_for(_add, (a, a))


def test_shape_dtype_struct_lowers_without_allocating():
    cache = ExecutableCache(fingerprint="test")
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    exe = cache.compile(_add, (spec, spec))
    a = jnp.ones((4, 4))
    assert float(exe(a, a)[0, 0]) == 2.0
    # a concrete-array call with the same shapes is the same executable
    assert cache.compile(_add, (a, a)) is exe
    assert cache.stats.compiles == 1


def test_already_jitted_fn_routes_through_lower():
    cache = ExecutableCache(fingerprint="test")
    jitted = jax.jit(_add)
    a = jnp.ones((2,))
    exe = cache.compile(jitted, (a, a))
    assert float(exe(a, a)[0]) == 2.0
    assert cache.stats.compiles == 1


# ---------------------------------------------------------------------------
# Eviction + failure semantics
# ---------------------------------------------------------------------------

def test_lru_eviction_bounds_live_executables():
    cache = ExecutableCache(capacity=2, fingerprint="test")
    for n in (2, 3, 4):
        a = jnp.ones((n,))
        cache.compile(_add, (a, a))
    s = cache.stats
    assert len(cache) <= 2
    assert s.evictions >= 1
    assert s.compiles == 3
    # the evicted (oldest) key recompiles, the fresh ones hit
    cache.compile(_add, (jnp.ones((2,)),) * 2)
    assert cache.stats.compiles == 4


def test_failed_compile_is_not_cached():
    cache = ExecutableCache(fingerprint="test")

    def bad(a):
        raise ValueError("boom")

    a = jnp.ones((2,))
    for _ in range(2):                       # both attempts raise: no
        with pytest.raises(ValueError):      # poisoned entry is left behind
            cache.compile(bad, (a,))
    assert len(cache) == 0
    assert cache.stats.compiles == 0


def test_concurrent_compiles_dedup_to_one():
    cache = ExecutableCache(fingerprint="test")
    a = jnp.ones((8, 8))
    n_threads = 8
    barrier = threading.Barrier(n_threads)
    results, errors = [], []

    def worker():
        try:
            barrier.wait()
            results.append(cache.compile(_add, (a, a)))
        except BaseException as e:  # pragma: no cover - failure detail
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert cache.stats.compiles == 1         # one owner, n-1 waiters
    assert all(r is results[0] for r in results)
    assert float(results[0](a, a)[0, 0]) == 2.0


# ---------------------------------------------------------------------------
# CompilePipeline
# ---------------------------------------------------------------------------

def test_pipeline_counts_and_failures():
    done = []
    with CompilePipeline() as pipe:
        pipe.submit(lambda: done.append(1))
        pipe.submit(lambda: 1 / 0)
        pipe.submit(lambda: done.append(2))
        assert pipe.drain(timeout=5.0)
        assert pipe.counts == (3, 2, 1)      # failures recorded, not raised
    assert done == [1, 2]
    with pytest.raises(RuntimeError):
        pipe.submit(lambda: None)            # closed


def test_tuner_pipelines_precompiles_for_fresh_configs():
    space = grid(x=(1.0, 2.0))
    settings = EvaluationSettings(max_invocations=1, max_iterations=2,
                                  max_time_s=30.0)
    precompiled = []

    def benchmark(cfg):
        def factory():
            def sample():
                time.sleep(0.02)             # give the worker headroom
                return cfg["x"]
            return sample
        return factory

    benchmark.precompile = lambda cfg: precompiled.append(dict(cfg))
    result = Tuner(space, settings).tune(benchmark, validate="off")
    assert sorted(c["x"] for c in precompiled) == [1.0, 2.0]
    assert result.n_precompiled == 2


def test_tuner_pipeline_off_and_missing_hook():
    space = grid(x=(1.0,))
    settings = EvaluationSettings(max_invocations=1, max_iterations=1,
                                  max_time_s=30.0)

    def plain(cfg):
        return lambda: (lambda: cfg["x"])

    r = Tuner(space, settings).tune(plain, validate="off")
    assert r.n_precompiled == 0              # no precompile hook: no pipeline

    seen = []

    def hooked(cfg):
        return lambda: (lambda: cfg["x"])

    hooked.precompile = lambda cfg: seen.append(cfg)
    r = Tuner(space, settings).tune(hooked, validate="off", pipeline="off")
    assert r.n_precompiled == 0 and seen == []


def test_factory_compiles_once_across_invocations():
    """The PR 8 satellite regression test: N invocations of one config
    must compile exactly once (the pre-PR factories re-entered jax.jit
    per invocation)."""
    from benchmarks.common import dgemm_invocation_factory

    cache = ExecutableCache(fingerprint="test")
    factory = dgemm_invocation_factory(16, 16, 8, exec_cache=cache)
    for _ in range(4):
        sample = factory()
        assert sample() > 0.0                # GFLOP/s
    s = cache.stats
    assert s.compiles == 1
    assert s.hits == 3


# ---------------------------------------------------------------------------
# PhaseProfiler
# ---------------------------------------------------------------------------

def test_phase_is_noop_without_installed_profiler():
    with phase("anything"):
        pass                                 # must not raise or record


def test_profiler_buckets_count_and_accumulate():
    with PhaseProfiler() as prof:
        for _ in range(3):
            with phase("setup"):
                pass
        with phase("setup"):
            with phase("compile"):           # nesting: both buckets record
                pass
    doc = prof.to_json()
    assert doc["setup"]["count"] == 4
    assert doc["compile"]["count"] == 1
    assert doc["setup"]["seconds"] >= 0.0


def test_profiler_sees_spans_from_worker_threads():
    with PhaseProfiler() as prof:
        def work():
            with phase("compile"):
                pass
        t = threading.Thread(target=work)
        t.start()
        t.join()
    assert prof.to_json()["compile"]["count"] == 1


# ---------------------------------------------------------------------------
# steady_sampler vs timed_sampler conformance (deterministic virtual device)
# ---------------------------------------------------------------------------

class VirtualDevice:
    """Async device model on a virtual clock: dispatch enqueues free of
    charge, sync pays queued kernel time plus a fixed wake-up cost."""

    def __init__(self, t_exec_s: float, sync_overhead_s: float):
        self.t_exec_s = t_exec_s
        self.sync_overhead_s = sync_overhead_s
        self.now = 0.0
        self.pending = 0

    def clock(self):
        return self.now

    def dispatch(self):
        self.pending += 1
        return "handle"

    def sync(self, handle):
        self.now += self.pending * self.t_exec_s + self.sync_overhead_s
        self.pending = 0

    def blocking_call(self):
        self.sync(self.dispatch())


def test_steady_and_timed_conform_on_sync_light_workload():
    # per-call sync is 1% of kernel time: both samplers agree within the
    # paper's 2% budget, and batching tightens steady further
    dev = VirtualDevice(t_exec_s=10e-3, sync_overhead_s=0.1e-3)
    work = 1.0
    timed = timed_sampler(dev.blocking_call, work=work, clock=dev.clock)
    steady = steady_sampler(dev.dispatch, work=work, sync=dev.sync,
                            batch=8, clock=dev.clock)
    t, s = timed(), steady()
    true_rate = work / dev.t_exec_s
    assert abs(s - t) / t < 0.02
    assert abs(s - true_rate) < abs(t - true_rate)


def test_steady_recovers_rate_timed_cannot_on_tiny_kernels():
    # sync wake-up is 2x kernel time — the regime steady_sampler exists
    # for: the timed sampler is ~66% low, the batched one within 2%
    dev = VirtualDevice(t_exec_s=0.05e-3, sync_overhead_s=0.1e-3)
    work = 1.0
    timed = timed_sampler(dev.blocking_call, work=work, clock=dev.clock)
    steady = steady_sampler(dev.dispatch, work=work, sync=dev.sync,
                            batch=256, clock=dev.clock)
    true_rate = work / dev.t_exec_s
    assert timed() < 0.5 * true_rate
    assert abs(steady() - true_rate) / true_rate < 0.02


def test_calibrate_batch_fits_the_virtual_device_exactly():
    dev = VirtualDevice(t_exec_s=1e-3, sync_overhead_s=0.2e-3)
    cal = calibrate_batch(dev.dispatch, dev.sync, clock=dev.clock,
                          overhead_frac=0.02)
    assert cal.t_exec_s == pytest.approx(1e-3)
    assert cal.overhead_s == pytest.approx(0.2e-3)
    # smallest B with overhead/(B*t_exec) <= 2%: ceil(0.2/0.02) = 10
    assert cal.batch == 10

    free = VirtualDevice(t_exec_s=1e-3, sync_overhead_s=0.0)
    assert calibrate_batch(free.dispatch, free.sync,
                           clock=free.clock).batch == 1


def test_steady_sampler_autocalibrates_and_exposes_batch():
    dev = VirtualDevice(t_exec_s=1e-3, sync_overhead_s=0.2e-3)
    sample = steady_sampler(dev.dispatch, work=1.0, sync=dev.sync,
                            clock=dev.clock)
    assert sample.batch == 10
    assert sample() == pytest.approx(10.0 / (10 * 1e-3 + 0.2e-3))


def test_batch_calibration_dataclass_roundtrip():
    cal = BatchCalibration(batch=4, t_exec_s=1e-3, overhead_s=1e-4)
    assert (cal.batch, cal.t_exec_s, cal.overhead_s) == (4, 1e-3, 1e-4)

"""Mamba2 SSD: chunked algorithm vs the sequential recurrence oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import params as P
from repro.models import ssd
from repro.models.config import ModelConfig


def tiny_cfg(chunk=8, state=16, d_model=32, heads=None):
    return ModelConfig(name="t", family="ssm", n_layers=1, d_model=d_model,
                       n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=64,
                       ssm_state=state, ssm_head_dim=8, ssm_chunk=chunk,
                       dtype="float32")


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_chunked_matches_sequential(chunk):
    cfg = tiny_cfg(chunk=chunk)
    p = P.materialize(jax.random.key(0), ssd.ssd_defs(cfg))
    u = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model)) * 0.5
    y_chunked = ssd.ssd_forward(p, u, cfg)
    y_seq = ssd.ssd_reference_scan(p, u, cfg)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_seq),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("state", [8, 16, 64])
def test_state_size_sweep(state):
    cfg = tiny_cfg(state=state)
    p = P.materialize(jax.random.key(0), ssd.ssd_defs(cfg))
    u = jax.random.normal(jax.random.key(2), (1, 16, cfg.d_model)) * 0.5
    np.testing.assert_allclose(
        np.asarray(ssd.ssd_forward(p, u, cfg)),
        np.asarray(ssd.ssd_reference_scan(p, u, cfg)),
        rtol=3e-4, atol=3e-4)


def test_prefill_state_continues_decode():
    """ssd_forward(return_state=True) must leave the cache exactly where a
    step-by-step decode would be."""
    cfg = tiny_cfg()
    p = P.materialize(jax.random.key(0), ssd.ssd_defs(cfg))
    u = jax.random.normal(jax.random.key(3), (2, 24, cfg.d_model)) * 0.5
    u_extra = jax.random.normal(jax.random.key(4), (2, 1, cfg.d_model)) * 0.5

    _, cache = ssd.ssd_forward(p, u, cfg, return_state=True)
    y_dec, _ = ssd.ssd_decode(p, u_extra, cache, cfg)

    full = jnp.concatenate([u, u_extra], axis=1)
    y_ref = ssd.ssd_reference_scan(p, full, cfg)[:, -1:]
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_decay_is_contraction():
    """Stability: with positive dt and negative A, the state decay factor
    must be in (0, 1] — no blowup over long sequences."""
    cfg = tiny_cfg()
    p = P.materialize(jax.random.key(0), ssd.ssd_defs(cfg))
    u = jax.random.normal(jax.random.key(5), (1, 256, cfg.d_model))
    y = ssd.ssd_forward(p, u, cfg)
    assert np.all(np.isfinite(np.asarray(y)))


def test_gradients_flow():
    cfg = tiny_cfg()
    p = P.materialize(jax.random.key(0), ssd.ssd_defs(cfg))
    u = jax.random.normal(jax.random.key(6), (1, 16, cfg.d_model)) * 0.5

    def loss(pp):
        return jnp.sum(jnp.square(ssd.ssd_forward(pp, u, cfg)))

    g = jax.grad(loss)(p)
    norms = [float(jnp.linalg.norm(x)) for x in jax.tree.leaves(g)]
    assert all(np.isfinite(n) for n in norms)
    assert sum(norms) > 0

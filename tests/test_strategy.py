"""Strategy layer: ask/tell contract, the strategy x backend cross-product,
successive-halving parity with the legacy loop, process-pool picklability,
and transfer-tuning seeds (unit + CLI subprocess)."""

import os
import pathlib
import subprocess
import sys

import pytest

from repro.core import (Batch, EvaluationSettings, ExhaustiveStrategy,
                        NeighborhoodStrategy, ProcessPoolBackend,
                        RandomSearchStrategy, SearchStrategy, SerialBackend,
                        SimulatedShardedBackend, SuccessiveHalvingStrategy,
                        ThreadPoolBackend, TrialCache, Tuner, grid,
                        tune_successive_halving)
from repro.core.stop_conditions import Direction

REPO = pathlib.Path(__file__).resolve().parent.parent


def quadratic_benchmark(cfg):
    """Deterministic module-level objective — picklable for the process
    pool — with the optimum at x=7 (score 100)."""
    mu = 100.0 - (cfg["x"] - 7) ** 2

    def factory():
        return lambda: mu

    return factory


def plane_benchmark(cfg):
    """Two-parameter deterministic objective, optimum at (a=3, b=20)."""
    mu = 50.0 - abs(cfg["a"] - 3) - abs(cfg["b"] - 20) / 10.0

    def factory():
        return lambda: mu

    return factory


SETTINGS = EvaluationSettings(max_invocations=3, max_iterations=20,
                              use_ci_convergence=True, use_inner_prune=True,
                              use_outer_prune=True)

STRATEGIES = {
    "exhaustive": lambda: ExhaustiveStrategy(),
    "halving": lambda: SuccessiveHalvingStrategy(eta=3),
    "random": lambda: RandomSearchStrategy(budget=12, seed=0),
    "neighborhood": lambda: NeighborhoodStrategy(),
}

BACKENDS = {
    "serial": lambda: SerialBackend(),
    "thread": lambda: ThreadPoolBackend(3),
    "process": lambda: ProcessPoolBackend(2),
    "simulated": lambda: SimulatedShardedBackend(4),
}


# ---------------------------------------------------------------------------
# The acceptance cross-product: every strategy through the same engine on
# every backend, same optimum on a deterministic synthetic objective
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy_name", sorted(STRATEGIES))
@pytest.mark.parametrize("backend_name", sorted(BACKENDS))
def test_strategy_backend_cross_product_finds_optimum(strategy_name,
                                                      backend_name):
    space = grid(x=tuple(range(12)))
    strategy = STRATEGIES[strategy_name]()
    backend = BACKENDS[backend_name]()
    result = Tuner(space, SETTINGS, strategy=strategy).tune(
        quadratic_benchmark, backend=backend)
    assert result.best_config == {"x": 7}, (strategy_name, backend_name)
    assert result.best_score == pytest.approx(100.0)
    assert result.strategy == strategy.name
    assert result.backend == backend.name
    assert len(result.batches) >= 1
    assert sum(b.size for b in result.batches) == len(result.trials)


# ---------------------------------------------------------------------------
# Ask/tell contract
# ---------------------------------------------------------------------------


class _ContractStrategy(SearchStrategy):
    """Scripted strategy asserting every outcome is told before the next
    ask (the engine/backend guarantee round-synchronized strategies rely
    on)."""

    name = "contract"

    def reset(self, space, settings, seeds=()):
        self._queue = list(space.configs())
        self._outstanding = 0
        self.batches_asked = 0

    def ask(self, n):
        assert self._outstanding == 0, \
            "ask() called with outcomes still untold"
        if not self._queue:
            return None
        batch = self._queue[:self._cap(n, len(self._queue))]
        del self._queue[:len(batch)]
        self._outstanding = len(batch)
        self.batches_asked += 1
        return Batch(tuple(batch))

    def tell(self, config, result):
        self._outstanding -= 1


@pytest.mark.parametrize("backend_name", ["serial", "thread", "simulated"])
def test_every_outcome_told_before_next_ask(backend_name):
    space = grid(x=tuple(range(10)))
    strategy = _ContractStrategy()
    result = Tuner(space, SETTINGS, strategy=strategy).tune(
        quadratic_benchmark, backend=BACKENDS[backend_name]())
    assert len(result.trials) == 10
    assert strategy.batches_asked == len(result.batches)


def test_batch_settings_override_controls_budget():
    """A halving rung's per-batch settings must actually reach the
    evaluator: rung 0 trials spend exactly min_iterations samples, later
    rungs eta times more."""
    base = EvaluationSettings(max_time_s=30.0)
    strategy = SuccessiveHalvingStrategy(eta=4, min_iterations=4)
    result = Tuner(grid(x=tuple(range(16))), base, strategy=strategy).tune(
        quadratic_benchmark)
    per_trial = [t.result.total_samples for t in result.trials]
    assert per_trial[:16] == [4] * 16            # rung 0: budget 4
    assert set(per_trial[16:20]) == {16}         # rung 1: budget 4*eta
    assert result.best_config == {"x": 7}


# ---------------------------------------------------------------------------
# Successive halving: parity with the legacy loop
# ---------------------------------------------------------------------------


def test_halving_strategy_matches_legacy_wrapper():
    """The ported strategy reproduces the old tune_successive_halving
    trial schedule and winner on a fixed synthetic benchmark."""
    base = EvaluationSettings(max_time_s=30.0)
    via_wrapper = tune_successive_halving(grid(x=tuple(range(16))),
                                          quadratic_benchmark, base, eta=4)
    via_engine = Tuner(grid(x=tuple(range(16))), base,
                       strategy=SuccessiveHalvingStrategy(
                           eta=4, min_iterations=4)).tune(quadratic_benchmark)
    assert via_wrapper.best_config == via_engine.best_config == {"x": 7}
    assert via_wrapper.best_score == via_engine.best_score
    assert [t.config for t in via_wrapper.trials] == \
        [t.config for t in via_engine.trials]
    assert via_wrapper.total_samples == via_engine.total_samples
    assert via_wrapper.settings_label == "SuccessiveHalving"
    # the strategy path gains what the legacy loop never had
    assert via_engine.strategy == "halving"
    assert len(via_engine.batches) >= 2          # multiple rungs


def test_halving_runs_with_cache_and_backend(tmp_path):
    """The port gives halving what the old loop lacked: backends and a
    persistent cache (rung trials are persisted, not replayed)."""
    cache = TrialCache(tmp_path / "h.jsonl", fingerprint="fp")
    base = EvaluationSettings(max_time_s=30.0)
    result = Tuner(grid(x=tuple(range(16))), base,
                   strategy=SuccessiveHalvingStrategy(eta=4)).tune(
        quadratic_benchmark, backend=ThreadPoolBackend(4),
        cache=cache.bound("b"))
    assert result.best_config == {"x": 7}
    assert result.backend == "thread"
    assert len(cache) > 0
    # deepest-rung result persisted last wins; strategy name recorded
    assert all(t.strategy == "halving" for t in cache.trials())


# ---------------------------------------------------------------------------
# Random search and neighborhood specifics
# ---------------------------------------------------------------------------


def test_random_search_respects_budget():
    result = Tuner(grid(x=tuple(range(12))), SETTINGS,
                   strategy=RandomSearchStrategy(budget=5, seed=3)).tune(
        quadratic_benchmark)
    assert len(result.trials) == 5
    seen = {t.config["x"] for t in result.trials}
    assert len(seen) == 5                        # without replacement


def test_random_search_reservoir_is_deterministic_per_seed():
    """Identical seed ⇒ identical proposal set AND identical visit order
    across runs — the reservoir draw and the final shuffle both hang off
    the one seeded rng, so resumed/cached sessions replay exactly."""
    space = grid(a=(1, 2, 3, 4, 5), b=(10, 20, 30, 40))
    runs = [Tuner(space, SETTINGS,
                  strategy=RandomSearchStrategy(budget=7, seed=11)).tune(
        plane_benchmark) for _ in range(2)]
    assert [t.config for t in runs[0].trials] == \
        [t.config for t in runs[1].trials]
    assert len(runs[0].trials) == 7
    other = Tuner(space, SETTINGS,
                  strategy=RandomSearchStrategy(budget=7, seed=12)).tune(
        plane_benchmark)
    assert [t.config for t in other.trials] != \
        [t.config for t in runs[0].trials]


def test_random_search_budget_above_cardinality_degrades_to_exhaustive():
    """A budget larger than the space is a full sweep: every config is
    proposed exactly once and the reservoir never truncates."""
    space = grid(x=tuple(range(9)))
    result = Tuner(space, SETTINGS,
                   strategy=RandomSearchStrategy(budget=50, seed=0)).tune(
        quadratic_benchmark)
    assert len(result.trials) == space.cardinality
    assert {t.config["x"] for t in result.trials} == set(range(9))
    assert result.best_config == {"x": 7}


def test_random_search_seeds_count_against_budget():
    space = grid(x=tuple(range(12)))
    result = Tuner(space, SETTINGS,
                   strategy=RandomSearchStrategy(budget=4, seed=0)).tune(
        quadratic_benchmark, seeds=[{"x": 7}])
    assert result.trials[0].config == {"x": 7}       # seed front-loaded
    assert len(result.trials) == 4                   # budget includes it


def test_neighborhood_climbs_multi_param_space():
    space = grid(a=(1, 2, 3, 4, 5), b=(10, 20, 30, 40))
    result = Tuner(space, SETTINGS,
                   strategy=NeighborhoodStrategy()).tune(plane_benchmark)
    assert result.best_config == {"a": 3, "b": 20}
    assert len(result.trials) < space.cardinality    # climbed, not swept


def test_neighborhood_respects_constraints():
    space = grid(x=tuple(range(12))).constrain(lambda c: c["x"] != 6)
    result = Tuner(space, SETTINGS,
                   strategy=NeighborhoodStrategy()).tune(quadratic_benchmark)
    # the climb from x=0 stalls at the x=6 hole: 5 is a local optimum
    assert result.best_config == {"x": 5}
    assert all(t.config["x"] != 6 for t in result.trials)


def test_exhaustive_order_alias_and_strategy_conflict():
    space = grid(x=(1, 2, 3))
    tuner = Tuner(space, SETTINGS, order="reverse")
    result = tuner.tune(quadratic_benchmark)
    assert [t.config["x"] for t in result.trials] == [3, 2, 1]
    assert result.order == "reverse"
    with pytest.raises(ValueError):
        Tuner(space, SETTINGS, strategy=ExhaustiveStrategy(),
              order="reverse")


# ---------------------------------------------------------------------------
# Process pool: equivalence + picklability regression
# ---------------------------------------------------------------------------


def test_process_pool_matches_serial_best():
    space = grid(x=tuple(range(12)))
    serial = Tuner(space, SETTINGS).tune(quadratic_benchmark)
    proc = Tuner(space, SETTINGS).tune(quadratic_benchmark,
                                       backend=ProcessPoolBackend(2))
    assert proc.best_config == serial.best_config
    assert proc.best_score == serial.best_score
    assert len(proc.trials) == len(serial.trials)
    assert proc.n_workers == 2 and proc.backend == "process"


def test_process_pool_rejects_unpicklable_benchmark():
    """Regression: a closure benchmark must fail fast with a clear error,
    not die inside the pool."""
    space = grid(x=(1, 2))
    closure_benchmark = lambda cfg: (lambda: (lambda: 1.0))  # noqa: E731
    with pytest.raises(TypeError, match="picklable"):
        Tuner(space, SETTINGS).tune(closure_benchmark,
                                    backend=ProcessPoolBackend(2))


def test_process_pool_rejects_bad_worker_count():
    with pytest.raises(ValueError):
        ProcessPoolBackend(0)


# ---------------------------------------------------------------------------
# Transfer tuning: seeds from a related benchmark's cache
# ---------------------------------------------------------------------------


def test_suggest_seeds_best_first(tmp_path):
    cache = TrialCache(tmp_path / "donor.jsonl", fingerprint="fp")
    tuner = Tuner(grid(x=tuple(range(12))), SETTINGS)
    tuner.tune(quadratic_benchmark, cache=cache.bound("donor"))
    seeds = cache.suggest_seeds("donor", direction=Direction.MAXIMIZE)
    assert seeds[0] == {"x": 7}                  # incumbent first
    assert len(seeds) == 3
    assert cache.suggest_seeds("missing") == []


def test_transfer_seeds_warm_start_neighborhood(tmp_path):
    """A related benchmark's cached incumbent starts the climb at the
    optimum: the whole search collapses to the seed round + one
    non-improving neighbor round."""
    cache = TrialCache(tmp_path / "donor.jsonl", fingerprint="fp")
    Tuner(grid(x=tuple(range(12))), SETTINGS).tune(
        quadratic_benchmark, cache=cache.bound("donor"))
    seeds = cache.suggest_seeds("donor", limit=1)
    result = Tuner(grid(x=tuple(range(12))), SETTINGS,
                   strategy=NeighborhoodStrategy()).tune(
        quadratic_benchmark, seeds=seeds)
    assert result.trials[0].config == {"x": 7}   # climb starts at the seed
    assert result.best_config == {"x": 7}
    assert result.n_seeded == 1
    assert len(result.trials) <= 3               # seed + its two neighbors


def test_transfer_seeds_project_into_space():
    """Foreign-space seeds snap to the nearest in-space config; unrelated
    parameters fall back to domain defaults."""
    space = grid(n=(256, 512, 1024), k=(64, 128))
    result = Tuner(space, SETTINGS, strategy=NeighborhoodStrategy()).tune(
        lambda cfg: (lambda: (lambda: float(cfg["n"] + cfg["k"]))),
        seeds=[{"n": 600, "x": 9}])
    assert result.trials[0].config == {"n": 512, "k": 64}
    assert result.n_seeded == 1


def test_exhaustive_front_loads_seeds():
    space = grid(x=tuple(range(8)))
    result = Tuner(space, SETTINGS).tune(quadratic_benchmark,
                                         seeds=[{"x": 5}])
    assert [t.config["x"] for t in result.trials] == [5, 0, 1, 2, 3, 4, 6, 7]


# ---------------------------------------------------------------------------
# CLI: --strategy / --budget / --transfer-from (acceptance: dgemm
# warm-started from a cached synthetic session)
# ---------------------------------------------------------------------------


def _run_tune_cli(tmp_path, *argv):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / "tune.py"),
         "--cache-dir", str(tmp_path), *map(str, argv)],
        capture_output=True, text=True, env=env, timeout=300)


def test_cli_transfer_from_synthetic_warm_starts_dgemm(tmp_path):
    donor = _run_tune_cli(tmp_path, "--session", "donor",
                          "--benchmark", "synthetic")
    assert donor.returncode == 0, donor.stderr
    proc = _run_tune_cli(tmp_path, "--session", "target",
                         "--benchmark", "dgemm",
                         "--strategy", "neighborhood", "--budget", "3",
                         "--transfer-from", "donor:synthetic")
    assert proc.returncode == 0, proc.stderr
    # the donor's incumbents were offered as seeds...
    assert "transfer   : 3 seed(s) from session 'donor' " \
        "(benchmark 'synthetic')" in proc.stdout
    # ...projected into the dgemm space (no shared params -> one distinct
    # default-projected seed) and evaluated first
    assert "seeded=1" in proc.stdout
    first_trial = next(line for line in proc.stdout.splitlines()
                       if line.lstrip().startswith("[   1/"))
    assert "{'n': 256, 'm': 256, 'k': 64}" in first_trial
    assert "strategy  : neighborhood" in proc.stdout


def test_cli_halving_strategy_on_synthetic(tmp_path):
    proc = _run_tune_cli(tmp_path, "--session", "h",
                         "--benchmark", "synthetic", "--strategy", "halving")
    assert proc.returncode == 0, proc.stderr
    assert "strategy   : halving" in proc.stdout
    assert "best      : {'x': 7}" in proc.stdout


# ---------------------------------------------------------------------------
# Settings parity: rung-truncated trials must never serve as full-budget
# results (review finding)
# ---------------------------------------------------------------------------


def test_rung_trials_never_served_to_full_budget_session(tmp_path):
    """A halving session's rung-truncated records must not satisfy (or
    warm-start) a later exhaustive session under the tuner's own
    settings."""
    cache = TrialCache(tmp_path / "s.jsonl", fingerprint="fp")
    base = EvaluationSettings(max_time_s=30.0)
    Tuner(grid(x=tuple(range(16))), base,
          strategy=SuccessiveHalvingStrategy(eta=4)).tune(
        quadratic_benchmark, cache=cache.bound("b"))
    assert len(cache.trials()) == 16             # every config persisted

    replay = TrialCache(tmp_path / "s.jsonl", fingerprint="fp")
    result = Tuner(grid(x=tuple(range(16))), base).tune(
        quadratic_benchmark, cache=replay.bound("b"), warm_start=True)
    assert result.n_cached == 0                  # nothing truncated served
    assert result.improvements[0][0] is not None
    # the warm-start seed did not come from a truncated rung record: the
    # first accepted incumbent is a fresh full-budget evaluation
    full = 16 * 10 * 200                         # invocations x iterations
    assert result.total_samples == full

    # a same-settings exhaustive rerun, by contrast, is fully served
    again = Tuner(grid(x=tuple(range(16))), base).tune(
        quadratic_benchmark,
        cache=TrialCache(tmp_path / "s.jsonl", fingerprint="fp").bound("b"))
    assert again.n_cached == 16


def test_settings_key_ignores_nothing_for_legacy_records(tmp_path):
    """Records without a settings_key (pre-strategy caches, hand-written
    fixtures) keep matching any read — old session files stay resumable."""
    from repro.core import settings_key
    from tests.test_cache import make_result

    cache = TrialCache(tmp_path / "legacy.jsonl", fingerprint="fp")
    cache.put("b", {"x": 1}, make_result(10.0))  # no settings_key recorded
    key = settings_key(SETTINGS)
    assert cache.get("b", {"x": 1}, settings_key=key) is not None
    assert cache.best("b", Direction.MAXIMIZE, settings_key=key) is not None


def test_unconstrained_backends_get_full_unit_batches():
    """Serial/thread impose no round structure, so non-adaptive strategies
    propose everything at once (no mid-queue barriers); round-synchronized
    backends still get n_workers-wide rounds."""
    space = grid(x=tuple(range(12)))
    serial = Tuner(space, SETTINGS).tune(quadratic_benchmark)
    assert len(serial.batches) == 1 and serial.batches[0].size == 12
    threaded = Tuner(space, SETTINGS).tune(quadratic_benchmark,
                                           backend=ThreadPoolBackend(4))
    assert len(threaded.batches) == 1
    simulated = Tuner(space, SETTINGS).tune(quadratic_benchmark,
                                            backend=SimulatedShardedBackend(4))
    assert [b.size for b in simulated.batches] == [4, 4, 4]


def test_halving_run_does_not_clobber_full_budget_records(tmp_path):
    """Review regression: rung records live under their own settings key,
    so an interleaved halving run must not invalidate an existing
    session's full-budget cache."""
    path = tmp_path / "s.jsonl"
    space = grid(x=tuple(range(12)))
    first = Tuner(space, SETTINGS).tune(
        quadratic_benchmark,
        cache=TrialCache(path, fingerprint="fp").bound("b"))
    assert first.n_cached == 0
    Tuner(space, SETTINGS, strategy=SuccessiveHalvingStrategy(eta=3)).tune(
        quadratic_benchmark,
        cache=TrialCache(path, fingerprint="fp").bound("b"))
    resumed = Tuner(space, SETTINGS).tune(
        quadratic_benchmark,
        cache=TrialCache(path, fingerprint="fp").bound("b"))
    assert resumed.n_cached == 12                # fully served, not clobbered
    assert resumed.best_config == first.best_config


def test_thread_backend_persists_trials_as_they_finish(tmp_path):
    """Review regression: with the thread backend a completed trial must
    hit the cache file immediately (a killed run keeps it), not at the
    batch end — the slow trial here blocks until it can read the fast
    trial's record through the cache."""
    import threading  # noqa: F401  (documents the concurrency under test)
    import time as _time

    cache = TrialCache(tmp_path / "t.jsonl", fingerprint="fp")
    bound = cache.bound("b")

    def benchmark(cfg):
        mu = float(10 + cfg["x"])

        def factory():
            def sample():
                if cfg["x"] == 1:
                    deadline = _time.time() + 15.0
                    while bound.get({"x": 0}) is None:
                        assert _time.time() < deadline, \
                            "fast trial not persisted while slow in flight"
                        _time.sleep(0.01)
                return mu
            return sample

        return factory

    result = Tuner(grid(x=(0, 1)), SETTINGS).tune(
        benchmark, backend=ThreadPoolBackend(2), cache=bound)
    assert result.best_config == {"x": 1}
    assert len(cache.trials()) == 2

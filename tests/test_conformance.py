"""Statistical conformance: the paper's confidence machinery checked as
*statistics*, not as code paths — seeded Monte-Carlo over synthetic
streams with known ground truth.

Three contracts (ISSUE acceptance):

  * coverage — the 95% ``ci_mean`` interval contains the true mean in at
    least ~93% of 500 independent normal streams (t-correction keeps the
    small-sample coverage honest);
  * false positives — on *flat* data (identical true means), the Welch
    interval excludes zero at roughly its nominal rate, and the
    ``compare_runs`` verdict (99% CI **and** the 2% minimum-effect gate)
    stays under a 5% false-positive rate;
  * prune safety — stop-condition-4 pruning never discards a config
    whose true mean beats the incumbent by more than the 2% margin the
    paper's early-termination discipline allows.

Everything is seeded: a failure here is a real calibration bug, not a
flaky draw.
"""

import numpy as np
import pytest

from repro.core import Direction, EvaluationSettings
from repro.core.confidence import ci_mean
from repro.core.evaluator import Evaluator
from repro.core.welford import from_samples
from repro.history.ledger import RunRecord
from repro.history.regression import compare_runs, welch_interval

N_STREAMS = 500
TRUE_MEAN = 100.0
SD = 5.0


def _record(samples, run=0) -> RunRecord:
    st = from_samples(samples)
    return RunRecord(benchmark="conf", fingerprint="host", run=run,
                     config={"x": 1}, score=float(st.mean),
                     count=float(st.count), mean=float(st.mean),
                     m2=float(st.m2))


# ------------------------------------------------------------------ coverage

def test_ci_mean_95_coverage_over_500_streams():
    rng = np.random.default_rng(1234)
    hits = 0
    for _ in range(N_STREAMS):
        xs = rng.normal(TRUE_MEAN, SD, size=15)
        iv = ci_mean(from_samples(xs), confidence=0.95, use_t=True)
        hits += iv.lo <= TRUE_MEAN <= iv.hi
    coverage = hits / N_STREAMS
    assert 0.93 <= coverage <= 0.985, coverage


def test_ci_mean_without_t_undercovers_small_samples():
    """The z interval (the paper's n>=30 shortcut) must never cover
    *more* than the t interval it approximates — the t-correction is the
    conservative one."""
    rng = np.random.default_rng(99)
    z_hits = t_hits = 0
    for _ in range(N_STREAMS):
        xs = rng.normal(TRUE_MEAN, SD, size=5)
        st = from_samples(xs)
        z = ci_mean(st, confidence=0.95, use_t=False)
        t = ci_mean(st, confidence=0.95, use_t=True)
        assert z.hi - z.lo <= t.hi - t.lo
        z_hits += z.lo <= TRUE_MEAN <= z.hi
        t_hits += t.lo <= TRUE_MEAN <= t.hi
    assert z_hits <= t_hits


# ------------------------------------------------------- false-positive rate

def test_welch_interval_flat_data_nominal_rate():
    """Two streams with the *same* true mean: the 95% Welch interval for
    their difference should exclude zero at roughly the nominal 5% —
    neither badly anticonservative (>9%) nor uselessly wide (<1%)."""
    rng = np.random.default_rng(4321)
    fp = 0
    n_pairs = 400
    for _ in range(n_pairs):
        a = from_samples(rng.normal(50.0, 3.0, size=20))
        b = from_samples(rng.normal(50.0, 3.0, size=20))
        iv = welch_interval(a, b, confidence=0.95)
        fp += iv.lo > 0.0 or iv.hi < 0.0
    rate = fp / n_pairs
    assert 0.01 <= rate <= 0.09, rate


def test_compare_runs_flat_verdict_false_positive_rate():
    """The regression-gate verdict stacks a 99% CI on a 2% minimum
    effect; on flat data fewer than 5% of comparisons may come out
    non-flat (ISSUE: Welch regression verdict FPR under 5%)."""
    rng = np.random.default_rng(2026)
    n_pairs = 400
    wrong = 0
    for i in range(n_pairs):
        base = _record(rng.normal(50.0, 1.5, size=20), run=0)
        cand = _record(rng.normal(50.0, 1.5, size=20), run=1)
        cmp = compare_runs(base, cand, direction=Direction.MAXIMIZE)
        assert cmp.method == "welch"
        wrong += cmp.verdict != "flat"
    assert wrong / n_pairs < 0.05, wrong / n_pairs


def test_compare_runs_detects_a_real_shift():
    """Complement of the FPR test — a genuine 10% drop must not read as
    flat (the gate has power, it is not vacuously conservative)."""
    rng = np.random.default_rng(7)
    base = _record(rng.normal(50.0, 1.0, size=30), run=0)
    cand = _record(rng.normal(45.0, 1.0, size=30), run=1)
    cmp = compare_runs(base, cand, direction=Direction.MAXIMIZE)
    assert cmp.verdict == "regressed"


# ------------------------------------------------------------- prune safety

# min_count_inner=5, not the 2 the engine permits: a 2-sample t-interval
# collapses to a point when the draws nearly coincide, and at the 2.5%
# margin that falsely prunes ~2% of genuinely-better configs. Five
# samples (the same floor as min_count_ci and MIN_COUNT_WELCH) is the
# documented safe operating point — docs/sweeps.md and docs/history.md.
PRUNE_SETTINGS = EvaluationSettings(max_invocations=5, max_iterations=20,
                                    max_time_s=10.0, use_inner_prune=True,
                                    min_count_inner=5,
                                    direction=Direction.MAXIMIZE)
INCUMBENT = 100.0


def _stream(rng, mu, rel_sd=0.03):
    def make_invocation():
        return lambda: float(rng.normal(mu, rel_sd * mu))
    return make_invocation


@pytest.mark.parametrize("eps", [0.025, 0.04, 0.08])
def test_prune_never_discards_true_improvements(eps):
    """Stop-condition 4 discards a config only when its CI upper bound
    falls below the incumbent; a config whose *true* mean beats the
    incumbent by more than the 2% margin must survive every time."""
    rng = np.random.default_rng(int(eps * 1000))
    mu = INCUMBENT * (1.0 + eps)
    pruned = 0
    for _ in range(100):
        ev = Evaluator(PRUNE_SETTINGS)
        res = ev.evaluate(_stream(rng, mu), incumbent=INCUMBENT)
        pruned += res.pruned
    assert pruned == 0, f"{pruned}/100 true improvements pruned (eps={eps})"


def test_prune_does_fire_on_clearly_worse_configs():
    """...and the guarantee is not vacuous: a config 50% below the
    incumbent is pruned essentially always."""
    rng = np.random.default_rng(5)
    pruned = 0
    for _ in range(50):
        ev = Evaluator(PRUNE_SETTINGS)
        res = ev.evaluate(_stream(rng, INCUMBENT * 0.5), incumbent=INCUMBENT)
        pruned += res.pruned
    assert pruned == 50, f"only {pruned}/50 clearly-worse configs pruned"

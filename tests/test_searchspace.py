"""Search-space construction and reduction (paper Sec. IV)."""

import pytest

from repro.core.searchspace import (doubling_from, grid, param,
                                    powers_of_two)


def test_paper_dgemm_cardinality():
    """Reproduce the paper's Eq. 8 numbers: |S| = 7*7*11 = 539, reduced to
    4*4*6 = 96."""
    initial = grid(n=powers_of_two(64, 4096), m=powers_of_two(64, 4096),
                   k=powers_of_two(2, 2048))
    assert initial.raw_cardinality == 7 * 7 * 11 == 539
    reduced = initial.narrow(n=powers_of_two(512, 4096),
                             m=powers_of_two(512, 4096),
                             k=powers_of_two(64, 2048))
    assert reduced.raw_cardinality == 4 * 4 * 6 == 96


def test_leading_dimension_adjustment():
    """Paper Sec. IV-A: multiples of 2 instead of powers of 2."""
    assert doubling_from(500, 4000) == (500, 1000, 2000, 4000)


def test_constraints_filter():
    space = grid(n=(1, 2, 3, 4), m=(1, 2, 3, 4))
    square = space.constrain(lambda c: c["n"] == c["m"])
    assert square.cardinality == 4
    assert space.cardinality == 16


def test_orders():
    space = grid(x=(1, 2, 3))
    assert [c["x"] for c in space.ordered("exhaustive")] == [1, 2, 3]
    assert [c["x"] for c in space.ordered("reverse")] == [3, 2, 1]
    shuffled = [c["x"] for c in space.ordered("random", seed=7)]
    assert sorted(shuffled) == [1, 2, 3]
    # determinism
    assert shuffled == [c["x"] for c in space.ordered("random", seed=7)]


def test_duplicate_param_values_rejected():
    with pytest.raises(ValueError):
        param("x", (1, 1))


def test_narrow_unknown_param():
    with pytest.raises(KeyError):
        grid(x=(1,)).narrow(y=(2,))


def test_cardinality_computed_once():
    """Satellite fix: the filtered count is cached — constraints must not
    re-run the full product on every access (reports read this per
    render)."""
    calls = {"n": 0}

    def constraint(cfg):
        calls["n"] += 1
        return cfg["n"] != cfg["m"]

    space = grid(n=(1, 2, 3, 4), m=(1, 2, 3, 4)).constrain(constraint)
    assert space.cardinality == 12
    first = calls["n"]
    assert space.cardinality == 12
    assert space.cardinality == 12
    assert calls["n"] == first              # cached, not re-enumerated
    # derived spaces compute their own count
    narrowed = space.narrow(n=(1, 2))
    assert narrowed.cardinality == 6
    assert space.cardinality == 12


def test_contains_checks_domains_and_constraints():
    space = grid(n=(1, 2, 3), m=(1, 2, 3)).constrain(
        lambda c: c["n"] <= c["m"])
    assert {"n": 1, "m": 2} in space
    assert {"n": 3, "m": 1} not in space    # constraint violated
    assert {"n": 9, "m": 1} not in space    # out of domain
    assert {"n": 1} not in space            # missing param
    assert "nope" not in space


def test_project_snaps_to_nearest_in_space_config():
    space = grid(n=(256, 512, 1024), k=(64, 128))
    assert space.project({"n": 512, "k": 64}) == {"n": 512, "k": 64}
    assert space.project({"n": 600, "k": 100}) == {"n": 512, "k": 128}
    # unknown params ignored, missing ones default to the first value
    assert space.project({"x": 3}) == {"n": 256, "k": 64}
    # a projection that violates a constraint is unusable
    constrained = space.constrain(lambda c: c["n"] > 256)
    assert constrained.project({"x": 3}) is None

"""Search-space construction and reduction (paper Sec. IV)."""

import pytest

from repro.core.searchspace import (SearchSpace, doubling_from, grid, param,
                                    powers_of_two)


def test_paper_dgemm_cardinality():
    """Reproduce the paper's Eq. 8 numbers: |S| = 7*7*11 = 539, reduced to
    4*4*6 = 96."""
    initial = grid(n=powers_of_two(64, 4096), m=powers_of_two(64, 4096),
                   k=powers_of_two(2, 2048))
    assert initial.raw_cardinality == 7 * 7 * 11 == 539
    reduced = initial.narrow(n=powers_of_two(512, 4096),
                             m=powers_of_two(512, 4096),
                             k=powers_of_two(64, 2048))
    assert reduced.raw_cardinality == 4 * 4 * 6 == 96


def test_leading_dimension_adjustment():
    """Paper Sec. IV-A: multiples of 2 instead of powers of 2."""
    assert doubling_from(500, 4000) == (500, 1000, 2000, 4000)


def test_constraints_filter():
    space = grid(n=(1, 2, 3, 4), m=(1, 2, 3, 4))
    square = space.constrain(lambda c: c["n"] == c["m"])
    assert square.cardinality == 4
    assert space.cardinality == 16


def test_orders():
    space = grid(x=(1, 2, 3))
    assert [c["x"] for c in space.ordered("exhaustive")] == [1, 2, 3]
    assert [c["x"] for c in space.ordered("reverse")] == [3, 2, 1]
    shuffled = [c["x"] for c in space.ordered("random", seed=7)]
    assert sorted(shuffled) == [1, 2, 3]
    # determinism
    assert shuffled == [c["x"] for c in space.ordered("random", seed=7)]


def test_duplicate_param_values_rejected():
    with pytest.raises(ValueError):
        param("x", (1, 1))


def test_narrow_unknown_param():
    with pytest.raises(KeyError):
        grid(x=(1,)).narrow(y=(2,))

"""Optimizer substrate: AdamW convergence, schedules, clipping."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.params import ParamDef, materialize
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, global_norm, make_schedule,
                         opt_state_defs, wsd_schedule)


def quad_defs():
    return {"w": ParamDef(shape=(8,), logical=(None,), dtype=jnp.float32)}


def test_adamw_converges_on_quadratic():
    defs = quad_defs()
    params = materialize(jax.random.key(0), defs)
    opt = adamw_init(defs)
    target = jnp.arange(8.0)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - target))

    cfg = AdamWConfig(weight_decay=0.0)
    l0 = float(loss(params))
    for step in range(300):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, jnp.float32(0.05), cfg)
    assert float(loss(params)) < 0.01 * l0


def test_grad_clipping_bounds_update():
    defs = quad_defs()
    params = materialize(jax.random.key(0), defs)
    opt = adamw_init(defs)
    huge = {"w": jnp.full((8,), 1e9, jnp.float32)}
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    new_params, _, metrics = adamw_update(params, huge, opt,
                                          jnp.float32(0.1), cfg)
    assert float(metrics["grad_norm"]) > 1e8       # reported pre-clip
    delta = float(jnp.max(jnp.abs(new_params["w"] - params["w"])))
    assert delta < 1.0                              # clipped step is bounded


def test_opt_state_sharding_matches_params():
    defs = quad_defs()
    od = opt_state_defs(defs)
    assert od["m"]["w"].logical == defs["w"].logical
    assert od["v"]["w"].shape == defs["w"].shape


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, 1000, warmup_steps=100)
    assert float(s(0)) == 0.0
    assert abs(float(s(100)) - 1.0) < 1e-6
    assert float(s(1000)) < float(s(500)) < 1.0


def test_wsd_schedule_shape():
    """MiniCPM WSD: warmup ramp, flat stable phase, fast decay tail."""
    s = wsd_schedule(1.0, 1000, warmup_steps=100, decay_frac=0.1)
    assert float(s(0)) == 0.0
    assert abs(float(s(100)) - 1.0) < 1e-6
    assert abs(float(s(500)) - 1.0) < 1e-6          # stable plateau
    assert abs(float(s(899)) - 1.0) < 1e-6
    assert float(s(950)) < 0.5                       # decaying
    assert float(s(1000)) <= 0.011                   # min ratio


def test_make_schedule_dispatch():
    assert float(make_schedule("wsd", 1.0, 100)(50)) == \
        float(wsd_schedule(1.0, 100)(50))


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert abs(float(global_norm(t)) - np.sqrt(3 + 16)) < 1e-6

"""Welford online moments: property tests against the two-pass oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

import repro.core.welford as W

finite_floats = st.floats(min_value=-1e6, max_value=1e6,
                          allow_nan=False, allow_infinity=False)


@hypothesis.given(st.lists(finite_floats, min_size=2, max_size=200))
@hypothesis.settings(deadline=None, max_examples=200)
def test_welford_matches_two_pass(xs):
    state = W.from_samples(xs)
    arr = np.asarray(xs, dtype=np.float64)
    assert state.count == len(xs)
    np.testing.assert_allclose(state.mean, arr.mean(), rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(state.variance, arr.var(ddof=1),
                               rtol=1e-6, atol=1e-6)


@hypothesis.given(st.lists(finite_floats, min_size=2, max_size=100),
                  st.lists(finite_floats, min_size=2, max_size=100))
@hypothesis.settings(deadline=None, max_examples=200)
def test_merge_is_exact(xs, ys):
    """Chan et al. pairwise merge == folding the concatenated stream."""
    merged = W.merge(W.from_samples(xs), W.from_samples(ys))
    direct = W.from_samples(xs + ys)
    np.testing.assert_allclose(merged.mean, direct.mean, rtol=1e-9, atol=1e-8)
    np.testing.assert_allclose(merged.m2, direct.m2, rtol=1e-6, atol=1e-5)


@hypothesis.given(st.lists(st.lists(finite_floats, min_size=1, max_size=30),
                           min_size=1, max_size=8))
@hypothesis.settings(deadline=None, max_examples=100)
def test_tree_merge_matches_concat(chunks):
    flat = [x for chunk in chunks for x in chunk]
    if len(flat) < 2:
        return
    merged = W.tree_merge([W.from_samples(c) for c in chunks])
    direct = W.from_samples(flat)
    np.testing.assert_allclose(merged.mean, direct.mean, rtol=1e-9, atol=1e-8)
    np.testing.assert_allclose(merged.variance, direct.variance,
                               rtol=1e-5, atol=1e-5)


def test_merge_identity():
    a = W.from_samples([1.0, 2.0, 3.0])
    m = W.merge(a, W.init())
    assert m.count == 3 and abs(m.mean - 2.0) < 1e-12


def test_batch_state_jit(rng):
    xs = rng.normal(3.0, 1.5, size=512).astype(np.float32)
    state = jax.jit(W.batch_state)(jnp.asarray(xs))
    np.testing.assert_allclose(float(state.mean), xs.mean(), rtol=1e-5)
    np.testing.assert_allclose(float(state.variance), xs.var(ddof=1),
                               rtol=1e-4)


def test_welford_inside_scan_matches_numpy(rng):
    """The paper's use: updating inside a jitted loop."""
    xs = jnp.asarray(rng.normal(size=100).astype(np.float32))

    def body(c, x):
        return W.update(c, x), None

    state, _ = jax.lax.scan(body, W.WelfordState(jnp.zeros(()), jnp.zeros(()),
                                                 jnp.zeros(())), xs)
    np.testing.assert_allclose(float(state.mean), np.mean(np.asarray(xs)),
                               rtol=1e-5)

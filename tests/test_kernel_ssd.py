"""Pallas SSD chunk-scan kernel vs jnp oracle and vs the model-zoo math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd import ssd_chunk_scan, ssd_chunk_scan_pallas, ssd_chunk_scan_ref

KEY = jax.random.key(0)


def make_inputs(B=2, H=3, C=4, Q=16, P=8, N=16):
    xdt = jax.random.normal(jax.random.fold_in(KEY, 1), (B, H, C, Q, P)) * 0.5
    bm = jax.random.normal(jax.random.fold_in(KEY, 2), (B, C, Q, N)) * 0.5
    cm = jax.random.normal(jax.random.fold_in(KEY, 3), (B, C, Q, N)) * 0.5
    # cum must be a within-chunk cumsum of negatives (decays)
    a = -jax.random.uniform(jax.random.fold_in(KEY, 4), (B, H, C, Q),
                            minval=0.01, maxval=0.2)
    cum = jnp.cumsum(a, axis=-1)
    return xdt, bm, cm, cum


@pytest.mark.parametrize("shape", [dict(), dict(Q=32, P=16, N=8),
                                   dict(B=1, H=8, C=2), dict(C=8, Q=8)])
def test_kernel_matches_ref(shape):
    xdt, bm, cm, cum = make_inputs(**shape)
    out = ssd_chunk_scan_pallas(xdt, bm, cm, cum, interpret=True)
    ref = ssd_chunk_scan_ref(xdt, bm, cm, cum)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_ops_wrapper_matches_model_math():
    """ssd_chunk_scan (kernel path) must equal the model zoo's chunked SSD
    core (ssd_forward's y before the D-skip/gate) on identical inputs."""
    from repro.models import params as P_
    from repro.models import ssd as model_ssd
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="t", family="ssm", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=64,
                      ssm_state=16, ssm_head_dim=8, ssm_chunk=8,
                      dtype="float32")
    p = P_.materialize(jax.random.key(0), model_ssd.ssd_defs(cfg))
    u = jax.random.normal(jax.random.key(1), (2, 32, 32)) * 0.5

    # reproduce the model's pre-scan tensors
    z, x, b, c, dt, A = model_ssd._project(p, u, cfg)
    x = jax.nn.silu(model_ssd._causal_conv(x, p["conv_x"]))
    b = jax.nn.silu(model_ssd._causal_conv(b, p["conv_b"]))
    c = jax.nn.silu(model_ssd._causal_conv(c, p["conv_c"]))
    B_, S, _ = u.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xh = x.reshape(B_, S, H, P)

    y_kernel = ssd_chunk_scan(xh, dt, A, b, c, chunk=cfg.ssm_chunk,
                              interpret=True)

    # reference: the full model layer minus (D-skip + gate + out_proj)
    # recomputed via the sequential oracle state recurrence
    y_full = model_ssd.ssd_forward(p, u, cfg)  # smoke that shapes agree
    assert y_full.shape == u.shape
    # direct check against the chunk-scan reference math
    xdt = (xh * dt[..., None]).reshape(B_, S // 8, 8, H, P)
    xdt = jnp.moveaxis(xdt, 3, 1)
    cum = jnp.cumsum((dt * A).reshape(B_, S // 8, 8, H), axis=2)
    cum = jnp.moveaxis(cum, 3, 1)
    ref = ssd_chunk_scan_ref(xdt, b.reshape(B_, S // 8, 8, N),
                             c.reshape(B_, S // 8, 8, N), cum)
    ref = jnp.moveaxis(ref, 1, 3).reshape(B_, S, H, P)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_state_carries_across_chunks():
    """With a single head and constant decay, later chunks must see earlier
    chunks' contributions (non-zero inter-chunk term)."""
    xdt, bm, cm, cum = make_inputs(B=1, H=1, C=3, Q=8, P=4, N=4)
    out = ssd_chunk_scan_pallas(xdt, bm, cm, cum, interpret=True)
    # zeroing the first chunk's inputs must change later chunks' outputs
    xdt0 = xdt.at[:, :, 0].set(0.0)
    out0 = ssd_chunk_scan_pallas(xdt0, bm, cm, cum, interpret=True)
    assert np.abs(np.asarray(out[:, :, 1:]) -
                  np.asarray(out0[:, :, 1:])).max() > 1e-6

"""HLO text parsing: shape/byte arithmetic, collective traffic factors,
and the text-level cost model the workload audit falls back on."""

from __future__ import annotations

import textwrap

from repro.analysis.hlo import (_shape_bytes, _shape_numel, parse_collectives,
                                parse_hlo_cost, parse_hlo_ops)

# ---------------------------------------------------------------------------
# _shape_bytes / _shape_numel
# ---------------------------------------------------------------------------


def test_shape_bytes_simple_array():
    assert _shape_bytes("f32[4,8]{1,0}") == 4 * 8 * 4


def test_shape_bytes_tuple_sums_subshapes():
    assert _shape_bytes("(f32[4]{0}, f32[4]{0})") == 2 * 4 * 4


def test_shape_bytes_mixed_dtypes():
    assert _shape_bytes("(bf16[8]{0}, s32[2]{0})") == 8 * 2 + 2 * 4


def test_shape_bytes_scalar():
    # a scalar f32[] has one element
    assert _shape_bytes("f32[]") == 4


def test_shape_bytes_unknown_dtype_skipped():
    assert _shape_bytes("token[]") == 0
    assert _shape_bytes("(f32[4]{0}, token[])") == 16


def test_shape_bytes_fp8_one_byte_each():
    # quantized ops must not land in the unhandled tally
    assert _shape_bytes("f8e4m3fn[16,8]{1,0}") == 16 * 8
    assert _shape_bytes("f8e5m2[32]{0}") == 32
    assert _shape_bytes("(f8e4m3fnuz[4]{0}, f8e8m0fnu[4]{0})") == 8


def test_fp8_convert_costed_not_unhandled():
    text = "  %c = f8e4m3fn[64]{0} convert(f32[64]{0} %x)\n"
    cost = parse_hlo_cost(text)
    assert cost.unhandled == {}
    assert cost.bytes_by_op["convert"] == 64 * 1 + 64 * 4


def test_shape_numel_counts_unknown_dtypes():
    # numel is a structural count: unknown dtypes still contribute
    assert _shape_numel("(f32[4]{0}, u4[8]{0})") == 12
    assert _shape_numel("f32[]") == 1


# ---------------------------------------------------------------------------
# parse_collectives
# ---------------------------------------------------------------------------

AR_LINE = ("  %ar = f32[1024]{0} all-reduce(%x), "
           "replica_groups={{0,1,2,3}}, to_apply=%add\n")


def test_all_reduce_ring_factor():
    stats = parse_collectives(AR_LINE, n_devices=4)
    assert stats.count_by_op == {"all-reduce": 1}
    assert stats.bytes_by_op["all-reduce"] == 2.0 * 1024 * 4 * (3 / 4)


def test_group_size_from_iota_groups():
    line = ("  %ag = bf16[16,64]{1,0} all-gather(%x), "
            "replica_groups=[2,8], dimensions={0}\n")
    stats = parse_collectives(line, n_devices=999)
    assert stats.bytes_by_op["all-gather"] == 16 * 64 * 2 * (7 / 8)


def test_missing_replica_groups_uses_default():
    line = "  %cp = f32[256]{0} collective-permute(%x)\n"
    stats = parse_collectives(line, n_devices=2)
    assert stats.bytes_by_op["collective-permute"] == 256 * 4


def test_single_device_is_free():
    # n<=1 means no cross-device traffic at all
    stats = parse_collectives(AR_LINE.replace("{{0,1,2,3}}", "{{0}}"),
                              n_devices=1)
    assert stats.total_bytes == 0
    assert stats.total_count == 0


def test_async_done_not_double_counted():
    text = (
        "  %s = f32[64]{0} all-reduce-start(%x), replica_groups={{0,1}}\n"
        "  %d = f32[64]{0} all-reduce-done(%s)\n"
    )
    stats = parse_collectives(text, n_devices=2)
    assert stats.count_by_op == {"all-reduce": 1}


def test_empty_module_summary():
    stats = parse_collectives("HloModule empty\n", n_devices=8)
    assert stats.total_bytes == 0
    assert stats.summary() == "none"


# ---------------------------------------------------------------------------
# parse_hlo_cost (the audit's text-level fallback)
# ---------------------------------------------------------------------------

DOT_MODULE = textwrap.dedent("""\
    HloModule dot

    ENTRY %main (a: f32[64,32], b: f32[32,48]) -> f32[64,48] {
      %a = f32[64,32]{1,0} parameter(0)
      %b = f32[32,48]{1,0} parameter(1)
      ROOT %dot = f32[64,48]{1,0} dot(f32[64,32]{1,0} %a, f32[32,48]{1,0} %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
    }
""")


def test_dot_flops_are_2mnk():
    cost = parse_hlo_cost(DOT_MODULE)
    assert cost.flops == 2 * 64 * 48 * 32      # 196608
    assert cost.flops_by_op == {"dot": 196608.0}
    # operands + result traffic
    assert cost.bytes_by_op["dot"] == (64 * 32 + 32 * 48 + 64 * 48) * 4
    assert cost.unhandled == {}


def test_elementwise_one_flop_per_element():
    text = "  %add = f32[128]{0} add(f32[128]{0} %x, f32[128]{0} %y)\n"
    cost = parse_hlo_cost(text)
    assert cost.flops == 128
    assert cost.bytes_accessed == (128 + 128 + 128) * 4


def test_copy_ops_move_bytes_but_no_flops():
    text = "  %t = f32[8,16]{0,1} transpose(%x), dimensions={1,0}\n"
    cost = parse_hlo_cost(text)
    assert cost.flops == 0
    assert cost.bytes_by_op["transpose"] > 0


def test_structural_ops_are_free():
    text = textwrap.dedent("""\
        %p = f32[4]{0} parameter(0)
        %t = (f32[4]{0}, f32[4]{0}) tuple(%p, %p)
        %g = f32[4]{0} get-tuple-element(%t), index=0
    """)
    cost = parse_hlo_cost(text)
    assert cost.flops == 0
    assert cost.bytes_accessed == 0
    assert cost.unhandled == {}


def test_unhandled_ops_are_tallied_not_costed():
    text = "  %s = f32[4,4]{1,0} cholesky(%x)\n"
    cost = parse_hlo_cost(text)
    assert cost.unhandled == {"cholesky": 1}
    assert "unhandled" in cost.summary()


def test_compiled_jax_dot_matches_formula():
    import pytest
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    text = jax.jit(jnp.dot).lower(a, b).compile().as_text()
    cost = parse_hlo_cost(text)
    assert cost.flops_by_op.get("dot") == 2 * 64 * 48 * 32


# ---------------------------------------------------------------------------
# parse_hlo_ops (per-op records for roofline attribution)
# ---------------------------------------------------------------------------


def test_per_op_records_on_dot_module():
    mod = parse_hlo_ops(DOT_MODULE)
    # parameters are structural; only the dot yields a record
    assert [op.name for op in mod.ops] == ["dot"]
    dot = mod.by_name()["dot"]
    assert dot.kind == "dot"
    assert dot.flops == 2 * 64 * 48 * 32
    assert dot.bytes_accessed == (64 * 32 + 32 * 48 + 64 * 48) * 4
    assert dot.modeled
    assert mod.unhandled == {}
    # module totals agree with the flattened parser on a fusion-free module
    cost = parse_hlo_cost(DOT_MODULE)
    assert mod.flops == cost.flops
    assert mod.bytes_accessed == cost.bytes_accessed


FUSED_MODULE = textwrap.dedent("""\
    HloModule fused

    %fused_computation (p0: f32[128], p1: f32[128]) -> f32[128] {
      %p0 = f32[128]{0} parameter(0)
      %p1 = f32[128]{0} parameter(1)
      %add.1 = f32[128]{0} add(f32[128]{0} %p0, f32[128]{0} %p1)
      ROOT %tanh.2 = f32[128]{0} tanh(f32[128]{0} %add.1)
    }

    ENTRY %main (a: f32[128], b: f32[128]) -> f32[128] {
      %a = f32[128]{0} parameter(0)
      %b = f32[128]{0} parameter(1)
      ROOT %fusion = f32[128]{0} fusion(f32[128]{0} %a, f32[128]{0} %b), kind=kLoop, calls=%fused_computation
    }
""")


def test_fusion_cost_rolled_up_from_called_computation():
    mod = parse_hlo_ops(FUSED_MODULE)
    assert [op.name for op in mod.ops] == ["fusion"]
    fusion = mod.ops[0]
    assert fusion.kind == "fusion"
    assert fusion.flops == 128 + 128          # add + tanh, one per element
    assert fusion.bytes_accessed > 0
    assert fusion.modeled
    # fusion-body parameters must not pollute the unhandled tally
    assert mod.unhandled == {}


def test_reduce_costed_per_input_element():
    text = textwrap.dedent("""\
        ENTRY %main (x: f32[64,32]) -> f32[64] {
          %x = f32[64,32]{1,0} parameter(0)
          %c = f32[] constant(0)
          ROOT %reduce.1 = f32[64]{0} reduce(f32[64,32]{1,0} %x, f32[] %c), dimensions={1}, to_apply=%add
        }
    """)
    mod = parse_hlo_ops(text)
    red = mod.by_name()["reduce.1"]
    # one combiner application per input element (+1 for the init scalar)
    assert red.flops == 64 * 32 + 1
    assert red.kind == "reduce"


def test_while_body_counted_once_and_flagged():
    text = textwrap.dedent("""\
        %body (p: f32[16]) -> f32[16] {
          %p = f32[16]{0} parameter(0)
          ROOT %add.b = f32[16]{0} add(f32[16]{0} %p, f32[16]{0} %p)
        }

        %cond (p: f32[16]) -> pred[] {
          %p = f32[16]{0} parameter(0)
          ROOT %lt = pred[] compare(f32[16]{0} %p, f32[16]{0} %p), direction=LT
        }

        ENTRY %main (x: f32[16]) -> f32[16] {
          %x = f32[16]{0} parameter(0)
          ROOT %while.1 = f32[16]{0} while(f32[16]{0} %x), condition=%cond, body=%body
        }
    """)
    mod = parse_hlo_ops(text)
    wh = mod.by_name()["while.1"]
    assert wh.flops == 16                     # body counted exactly once
    assert mod.unhandled == {"while": 1}      # trip count unknown -> partial


def test_unmodeled_op_keeps_record_with_zero_cost():
    text = textwrap.dedent("""\
        ENTRY %main (x: f32[4,4]) -> f32[4,4] {
          %x = f32[4,4]{1,0} parameter(0)
          ROOT %cholesky.1 = f32[4,4]{1,0} cholesky(f32[4,4]{1,0} %x)
        }
    """)
    mod = parse_hlo_ops(text)
    op = mod.by_name()["cholesky.1"]
    assert not op.modeled                     # record exists for time-joins
    assert op.flops == 0 and op.bytes_accessed == 0
    assert mod.unhandled == {"cholesky": 1}


def test_op_intensity_edge_cases():
    mod = parse_hlo_ops(DOT_MODULE)
    dot = mod.by_name()["dot"]
    assert dot.intensity == dot.flops / dot.bytes_accessed
    from repro.analysis.hlo import OpCost
    assert OpCost("x", "exp", flops=4.0, bytes_accessed=0.0).intensity \
        == float("inf")
    assert OpCost("c", "copy", flops=0.0, bytes_accessed=8.0).intensity == 0.0
    assert OpCost("t", "tuple", flops=0.0, bytes_accessed=0.0).intensity == 0.0


def test_compiled_module_parses_per_op():
    import pytest
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    text = jax.jit(jnp.dot).lower(a, b).compile().as_text()
    mod = parse_hlo_ops(text)
    dots = [op for op in mod.ops if op.kind == "dot"]
    assert sum(op.flops for op in dots) == 2 * 64 * 48 * 32

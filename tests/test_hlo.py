"""HLO text parsing: shape/byte arithmetic, collective traffic factors,
and the text-level cost model the workload audit falls back on."""

from __future__ import annotations

import textwrap

from repro.analysis.hlo import (_shape_bytes, _shape_numel, parse_collectives,
                                parse_hlo_cost)

# ---------------------------------------------------------------------------
# _shape_bytes / _shape_numel
# ---------------------------------------------------------------------------


def test_shape_bytes_simple_array():
    assert _shape_bytes("f32[4,8]{1,0}") == 4 * 8 * 4


def test_shape_bytes_tuple_sums_subshapes():
    assert _shape_bytes("(f32[4]{0}, f32[4]{0})") == 2 * 4 * 4


def test_shape_bytes_mixed_dtypes():
    assert _shape_bytes("(bf16[8]{0}, s32[2]{0})") == 8 * 2 + 2 * 4


def test_shape_bytes_scalar():
    # a scalar f32[] has one element
    assert _shape_bytes("f32[]") == 4


def test_shape_bytes_unknown_dtype_skipped():
    assert _shape_bytes("token[]") == 0
    assert _shape_bytes("(f32[4]{0}, token[])") == 16


def test_shape_numel_counts_unknown_dtypes():
    # numel is a structural count: unknown dtypes still contribute
    assert _shape_numel("(f32[4]{0}, u4[8]{0})") == 12
    assert _shape_numel("f32[]") == 1


# ---------------------------------------------------------------------------
# parse_collectives
# ---------------------------------------------------------------------------

AR_LINE = ("  %ar = f32[1024]{0} all-reduce(%x), "
           "replica_groups={{0,1,2,3}}, to_apply=%add\n")


def test_all_reduce_ring_factor():
    stats = parse_collectives(AR_LINE, n_devices=4)
    assert stats.count_by_op == {"all-reduce": 1}
    assert stats.bytes_by_op["all-reduce"] == 2.0 * 1024 * 4 * (3 / 4)


def test_group_size_from_iota_groups():
    line = ("  %ag = bf16[16,64]{1,0} all-gather(%x), "
            "replica_groups=[2,8], dimensions={0}\n")
    stats = parse_collectives(line, n_devices=999)
    assert stats.bytes_by_op["all-gather"] == 16 * 64 * 2 * (7 / 8)


def test_missing_replica_groups_uses_default():
    line = "  %cp = f32[256]{0} collective-permute(%x)\n"
    stats = parse_collectives(line, n_devices=2)
    assert stats.bytes_by_op["collective-permute"] == 256 * 4


def test_single_device_is_free():
    # n<=1 means no cross-device traffic at all
    stats = parse_collectives(AR_LINE.replace("{{0,1,2,3}}", "{{0}}"),
                              n_devices=1)
    assert stats.total_bytes == 0
    assert stats.total_count == 0


def test_async_done_not_double_counted():
    text = (
        "  %s = f32[64]{0} all-reduce-start(%x), replica_groups={{0,1}}\n"
        "  %d = f32[64]{0} all-reduce-done(%s)\n"
    )
    stats = parse_collectives(text, n_devices=2)
    assert stats.count_by_op == {"all-reduce": 1}


def test_empty_module_summary():
    stats = parse_collectives("HloModule empty\n", n_devices=8)
    assert stats.total_bytes == 0
    assert stats.summary() == "none"


# ---------------------------------------------------------------------------
# parse_hlo_cost (the audit's text-level fallback)
# ---------------------------------------------------------------------------

DOT_MODULE = textwrap.dedent("""\
    HloModule dot

    ENTRY %main (a: f32[64,32], b: f32[32,48]) -> f32[64,48] {
      %a = f32[64,32]{1,0} parameter(0)
      %b = f32[32,48]{1,0} parameter(1)
      ROOT %dot = f32[64,48]{1,0} dot(f32[64,32]{1,0} %a, f32[32,48]{1,0} %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
    }
""")


def test_dot_flops_are_2mnk():
    cost = parse_hlo_cost(DOT_MODULE)
    assert cost.flops == 2 * 64 * 48 * 32      # 196608
    assert cost.flops_by_op == {"dot": 196608.0}
    # operands + result traffic
    assert cost.bytes_by_op["dot"] == (64 * 32 + 32 * 48 + 64 * 48) * 4
    assert cost.unhandled == {}


def test_elementwise_one_flop_per_element():
    text = "  %add = f32[128]{0} add(f32[128]{0} %x, f32[128]{0} %y)\n"
    cost = parse_hlo_cost(text)
    assert cost.flops == 128
    assert cost.bytes_accessed == (128 + 128 + 128) * 4


def test_copy_ops_move_bytes_but_no_flops():
    text = "  %t = f32[8,16]{0,1} transpose(%x), dimensions={1,0}\n"
    cost = parse_hlo_cost(text)
    assert cost.flops == 0
    assert cost.bytes_by_op["transpose"] > 0


def test_structural_ops_are_free():
    text = textwrap.dedent("""\
        %p = f32[4]{0} parameter(0)
        %t = (f32[4]{0}, f32[4]{0}) tuple(%p, %p)
        %g = f32[4]{0} get-tuple-element(%t), index=0
    """)
    cost = parse_hlo_cost(text)
    assert cost.flops == 0
    assert cost.bytes_accessed == 0
    assert cost.unhandled == {}


def test_unhandled_ops_are_tallied_not_costed():
    text = "  %s = f32[4,4]{1,0} cholesky(%x)\n"
    cost = parse_hlo_cost(text)
    assert cost.unhandled == {"cholesky": 1}
    assert "unhandled" in cost.summary()


def test_compiled_jax_dot_matches_formula():
    import pytest
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    text = jax.jit(jnp.dot).lower(a, b).compile().as_text()
    cost = parse_hlo_cost(text)
    assert cost.flops_by_op.get("dot") == 2 * 64 * 48 * 32

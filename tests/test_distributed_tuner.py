"""Distributed CI-pruned tuning (beyond-paper extension)."""

import pytest

from repro.core import EvaluationSettings
from repro.core.searchspace import grid
from repro.core.tuner import Tuner
from repro.distributed.tuner import (DistributedTuner, replicated_evaluate,
                                     shard_configs)


def make_benchmark(rng, sigma=0.3):
    def bench(cfg):
        mu = 100.0 - (cfg["x"] - 5) ** 2

        def factory():
            def sample():
                return float(rng.normal(mu, sigma))
            return sample

        return factory

    return bench


SETTINGS = EvaluationSettings(max_invocations=3, max_iterations=60,
                              use_ci_convergence=True, use_inner_prune=True,
                              use_outer_prune=True)


def test_shard_configs_strided():
    cfgs = [{"i": i} for i in range(10)]
    shards = shard_configs(cfgs, 3)
    assert [c["i"] for c in shards[0]] == [0, 3, 6, 9]
    assert sum(len(s) for s in shards) == 10


@pytest.mark.parametrize("workers", [1, 3, 8])
def test_distributed_finds_same_optimum(rng, workers):
    space = grid(x=tuple(range(10)))
    result = DistributedTuner(space, SETTINGS, n_workers=workers).tune(
        make_benchmark(rng))
    assert result.best_config == {"x": 5}
    assert result.parallel_time_s <= result.serial_time_s + 1e-9


def test_distributed_matches_serial_answer(rng):
    space = grid(x=tuple(range(10)))
    serial = Tuner(space, SETTINGS).tune(make_benchmark(rng))
    dist = DistributedTuner(space, SETTINGS, n_workers=4).tune(
        make_benchmark(rng))
    assert serial.best_config == dist.best_config
    # same evaluation machinery -> comparable scores
    assert abs(serial.best_score - dist.best_score) / serial.best_score < 0.02


def test_replicated_evaluate_merges_exactly(rng):
    settings = EvaluationSettings(max_invocations=2, max_iterations=25)

    def factory():
        def sample():
            return float(rng.normal(10.0, 1.0))
        return sample

    interval, merged, _ = replicated_evaluate(factory, settings, n_workers=4)
    assert merged.count == 4 * 2 * 25
    assert interval.lo <= 10.2 and interval.hi >= 9.8
    # merged variance must reflect within-invocation spread (sigma=1)
    assert 0.5 < merged.std < 2.0

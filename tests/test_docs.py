"""Documentation snippets must execute: every fenced python/bash block in
README.md and docs/ runs via scripts/check_docs.py (blocks marked
``<!-- check-docs: skip -->`` are exempt), so examples cannot rot."""

import importlib.util
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "check_docs", REPO / "scripts" / "check_docs.py")
check_docs = importlib.util.module_from_spec(_spec)
# dataclasses resolves cls.__module__ through sys.modules at class-creation
# time, so the module must be registered before exec
sys.modules["check_docs"] = check_docs
_spec.loader.exec_module(check_docs)

DOCS = [str(p.relative_to(REPO)) for p in check_docs.default_docs(REPO)]


def test_docs_exist():
    assert "README.md" in DOCS
    assert any(d.startswith("docs/") for d in DOCS), \
        "docs/ must contain at least one markdown file"


def test_every_doc_has_runnable_snippets():
    """The checker must actually be exercising something per file."""
    for doc in DOCS:
        blocks = check_docs.extract_blocks(
            (REPO / doc).read_text(encoding="utf-8"))
        assert any(b.runnable for b in blocks), \
            f"{doc} has no runnable fenced snippet"


def test_extract_blocks_skip_marker():
    text = ("prose\n"
            "<!-- check-docs: skip -->\n"
            "```bash\nexit 1\n```\n"
            "```python\nx = 1\n```\n"
            "```text\nnot runnable\n```\n")
    blocks = check_docs.extract_blocks(text)
    assert [b.lang for b in blocks] == ["bash", "python", "text"]
    assert blocks[0].skipped and not blocks[0].runnable
    assert blocks[1].runnable
    assert not blocks[2].runnable


def test_extract_blocks_info_string_attributes():
    """A fence like ```python title=x must still parse as python and must
    not swallow the following block."""
    text = ("```python title=demo\nx = 1\n```\n"
            "```bash\necho hi\n```\n")
    blocks = check_docs.extract_blocks(text)
    assert [b.lang for b in blocks] == ["python", "bash"]
    assert all(b.runnable for b in blocks)
    assert blocks[0].code == "x = 1\n"


@pytest.mark.parametrize("doc", DOCS)
def test_doc_snippets_execute(doc):
    failures = check_docs.check_file(REPO / doc)
    assert not failures, "\n".join(failures)

"""Execution backends: serial/thread equivalence, live incumbent sharing."""

import threading

import pytest

from repro.core import (EvaluationSettings, IncumbentCell, SerialBackend,
                        SimulatedShardedBackend, ThreadPoolBackend, Tuner)
from repro.core.searchspace import grid
from repro.core.stop_conditions import Direction


def deterministic_benchmark(cfg):
    """Noise-free objective: score is exactly 100 - (x - 7)^2."""
    mu = 100.0 - (cfg["x"] - 7) ** 2

    def factory():
        return lambda: mu

    return factory


SETTINGS = EvaluationSettings(max_invocations=3, max_iterations=20,
                              use_ci_convergence=True, use_inner_prune=True,
                              use_outer_prune=True)


def test_incumbent_cell_direction_aware():
    cell = IncumbentCell(Direction.MAXIMIZE)
    assert cell.get() is None
    assert cell.offer({"x": 1}, 5.0)
    assert not cell.offer({"x": 2}, 4.0)      # worse
    assert not cell.offer({"x": 2}, 5.0)      # tie is not strictly better
    assert cell.offer({"x": 3}, 6.0)
    assert cell.snapshot() == ({"x": 3}, 6.0)

    cell = IncumbentCell(Direction.MINIMIZE)
    assert cell.offer({"x": 1}, 5.0)
    assert cell.offer({"x": 2}, 4.0)
    assert not cell.offer({"x": 3}, 4.5)


@pytest.mark.parametrize("backend", [
    SerialBackend(),
    ThreadPoolBackend(2),
    ThreadPoolBackend(8),
    SimulatedShardedBackend(4),
])
def test_backends_find_same_best_config(backend):
    space = grid(x=tuple(range(12)))
    result = Tuner(space, SETTINGS).tune(deterministic_benchmark,
                                         backend=backend)
    assert result.best_config == {"x": 7}
    assert result.best_score == pytest.approx(100.0)
    assert len(result.trials) == 12
    assert result.n_workers == getattr(backend, "n_workers", 1)
    assert result.backend == backend.name


def test_thread_matches_serial_best(rng):
    space = grid(x=tuple(range(12)))
    serial = Tuner(space, SETTINGS).tune(deterministic_benchmark)
    threaded = Tuner(space, SETTINGS).tune(deterministic_benchmark,
                                           backend=ThreadPoolBackend(4))
    assert threaded.best_config == serial.best_config
    assert threaded.best_score == serial.best_score


def test_thread_trials_preserve_search_order():
    space = grid(x=tuple(range(12)))
    result = Tuner(space, SETTINGS).tune(deterministic_benchmark,
                                         backend=ThreadPoolBackend(4))
    assert [t.config["x"] for t in result.trials] == list(range(12))


def test_thread_incumbent_sharing_prunes():
    """A best score found on one thread must prune evaluations still in
    flight on other threads (stop condition 4 against the live cell).

    The optimum (x=7) is first in search order; every other config's
    sampler blocks until the optimum's trial has been folded into the
    incumbent cell, so each of them must observe incumbent=100 and be
    pruned (zero sample variance -> zero CI margin).
    """
    optimum_done = threading.Event()

    def benchmark(cfg):
        mu = 100.0 - (cfg["x"] - 7) ** 2

        def factory():
            def sample():
                if cfg["x"] != 7:
                    assert optimum_done.wait(timeout=30.0)
                return mu
            return sample

        return factory

    def progress(cfg, res):
        if cfg["x"] == 7:
            optimum_done.set()

    space = grid(x=(7, 0, 1, 2, 3, 4))
    result = Tuner(space, SETTINGS).tune(
        benchmark, progress=progress, backend=ThreadPoolBackend(3))
    assert result.best_config == {"x": 7}
    assert result.n_pruned == 5              # everything except the optimum
    for t in result.trials:
        if t.config["x"] != 7:
            assert t.result.pruned


def test_simulated_backend_accounting():
    space = grid(x=tuple(range(10)))
    result = Tuner(space, SETTINGS).tune(deterministic_benchmark,
                                         backend=SimulatedShardedBackend(4))
    assert result.parallel_time_s <= result.serial_time_s + 1e-9
    workers = {t.worker for t in result.trials}
    assert workers == {0, 1, 2, 3}


def test_minimize_direction_with_thread_backend():
    settings = EvaluationSettings(max_invocations=2, max_iterations=10,
                                  direction=Direction.MINIMIZE)

    def benchmark(cfg):
        mu = (cfg["x"] - 3) ** 2 + 1.0
        return lambda: (lambda: mu)

    space = grid(x=tuple(range(8)))
    result = Tuner(space, settings).tune(benchmark,
                                         backend=ThreadPoolBackend(4))
    assert result.best_config == {"x": 3}


def test_bad_worker_count_rejected():
    with pytest.raises(ValueError):
        ThreadPoolBackend(0)
    with pytest.raises(ValueError):
        SimulatedShardedBackend(0)


def test_incumbent_cell_history():
    cell = IncumbentCell(Direction.MAXIMIZE)
    cell.offer({"x": 1}, 5.0)
    cell.offer({"x": 2}, 4.0)                 # rejected: not recorded
    cell.offer({"x": 3}, 6.0)
    assert cell.history() == (({"x": 1}, 5.0), ({"x": 3}, 6.0))
    # a pre-seeded cell (warm start) records the seed as entry 0
    seeded = IncumbentCell(Direction.MAXIMIZE, score=9.0, config={"x": 9})
    seeded.offer({"x": 4}, 10.0)
    assert seeded.history()[0] == ({"x": 9}, 9.0)
    assert seeded.history()[1] == ({"x": 4}, 10.0)


def test_tuning_result_improvements_trajectory():
    result = Tuner(grid(x=tuple(range(12))), SETTINGS).tune(
        deterministic_benchmark)
    scores = [s for _, s in result.improvements]
    assert scores == sorted(scores)           # monotone for MAXIMIZE
    assert result.improvements[-1] == (result.best_config,
                                       result.best_score)

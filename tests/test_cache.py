"""Trial cache + resumable sessions: exact round-trip, skip-on-resume,
fingerprint invalidation."""

import json
import math
import random

import pytest

from repro.core import (EvaluationSettings, ThreadPoolBackend, Tuner,
                        TuningSession)
from repro.core.cache import TrialCache, config_key
from repro.core.evaluator import EvalResult, InvocationResult
from repro.core.searchspace import grid
from repro.core.stop_conditions import Direction

SETTINGS = EvaluationSettings(max_invocations=2, max_iterations=10,
                              use_ci_convergence=True, use_inner_prune=True,
                              use_outer_prune=True)


def make_result(score=42.0):
    # deliberately awkward floats: exact round-trip must survive repr/json
    inv = InvocationResult(mean=score / 3.0, count=7, elapsed_s=0.1230000004,
                           stop_reason="max_count(7)", pruned=False,
                           m2=1.0000000000000002e-9)
    return EvalResult(score=score, best_invocation=score / 3.0,
                      invocations=(inv, inv), total_samples=14,
                      total_time_s=0.25, measured_time_s=0.2460000008,
                      pruned=False, stop_reason="max_count(2)")


def counting_benchmark(counter):
    """Deterministic objective that counts factory instantiations."""

    def bench(cfg):
        mu = 100.0 - (cfg["x"] - 5) ** 2

        def factory():
            counter[cfg["x"]] = counter.get(cfg["x"], 0) + 1
            return lambda: mu

        return factory

    return bench


def test_roundtrip_exact_welford_moments(tmp_path):
    path = tmp_path / "cache.jsonl"
    cache = TrialCache(path, fingerprint="fp")
    res = make_result()
    cache.put("bench", {"n": 128, "m": 256}, res)

    reloaded = TrialCache(path, fingerprint="fp")
    hit = reloaded.get("bench", {"m": 256, "n": 128})  # key order-insensitive
    assert hit is not None
    assert hit == res          # dataclass equality: every float bit-exact
    assert hit.invocations[0].m2 == res.invocations[0].m2
    assert reloaded.get("bench", {"n": 1, "m": 1}) is None
    assert reloaded.get("other-bench", {"n": 128, "m": 256}) is None


def test_fingerprint_mismatch_invalidates(tmp_path):
    path = tmp_path / "cache.jsonl"
    TrialCache(path, fingerprint="tpu-v5e").put("bench", {"x": 1},
                                                make_result())
    other = TrialCache(path, fingerprint="cpu-host")
    assert other.get("bench", {"x": 1}) is None
    assert other.n_stale == 1
    assert len(other) == 0


def test_torn_trailing_line_tolerated(tmp_path):
    path = tmp_path / "cache.jsonl"
    cache = TrialCache(path, fingerprint="fp")
    cache.put("bench", {"x": 1}, make_result(10.0))
    cache.put("bench", {"x": 2}, make_result(20.0))
    with open(path, "a") as f:
        f.write('{"version": 1, "fingerprint": "fp", "benchm')  # killed write
    reloaded = TrialCache(path, fingerprint="fp")
    assert len(reloaded) == 2
    assert reloaded.get("bench", {"x": 2}).score == 20.0


def test_resume_skips_completed_trials(tmp_path):
    space = grid(x=tuple(range(8)))
    counter = {}
    bench = counting_benchmark(counter)
    session = TuningSession("s1", Tuner(space, SETTINGS), bench,
                            cache_dir=tmp_path, fingerprint="fp")
    first = session.run()
    assert first.best_config == {"x": 5}
    assert first.n_cached == 0
    assert sum(counter.values()) > 0

    counter.clear()
    resumed = TuningSession("s1", Tuner(space, SETTINGS), bench,
                            cache_dir=tmp_path, fingerprint="fp")
    second = resumed.run()
    assert counter == {}                    # nothing re-evaluated
    assert second.n_cached == len(second.trials) == 8
    assert second.best_config == first.best_config
    assert second.best_score == first.best_score


def test_killed_session_resumes_where_it_left_off(tmp_path):
    space = grid(x=tuple(range(8)))
    counter = {}
    bench = counting_benchmark(counter)

    class Killed(RuntimeError):
        pass

    def kill_after_three(cfg, res):
        if len(counter) >= 3:
            raise Killed

    session = TuningSession("s2", Tuner(space, SETTINGS), bench,
                            cache_dir=tmp_path, fingerprint="fp")
    with pytest.raises(Killed):
        session.run(progress=kill_after_three)
    assert len(counter) == 3                # three configs hit the disk

    counter.clear()
    resumed = TuningSession("s2", Tuner(space, SETTINGS), bench,
                            cache_dir=tmp_path, fingerprint="fp")
    result = resumed.run()
    assert result.best_config == {"x": 5}
    assert len(result.trials) == 8
    assert result.n_cached == 3             # the pre-kill trials
    assert len(counter) == 5                # only the remaining configs ran


def test_warm_start_prunes_from_trial_one(tmp_path):
    """With the incumbent seeded from a cached optimum, every new config
    (all strictly worse, zero variance) is pruned immediately."""
    space = grid(x=tuple(range(8)))
    bench = counting_benchmark({})
    # pre-populate only the optimum
    seed_session = TuningSession("s3", Tuner(grid(x=(5,)), SETTINGS), bench,
                                 cache_dir=tmp_path, fingerprint="fp")
    seed_session.run()

    session = TuningSession("s3", Tuner(space, SETTINGS), bench,
                            cache_dir=tmp_path, fingerprint="fp")
    result = session.run()
    assert result.best_config == {"x": 5}
    assert result.n_cached == 1
    assert result.n_pruned == 7             # every non-cached trial pruned


def test_session_with_thread_backend(tmp_path):
    space = grid(x=tuple(range(8)))
    bench = counting_benchmark({})
    session = TuningSession("s4", Tuner(space, SETTINGS), bench,
                            cache_dir=tmp_path, fingerprint="fp")
    first = session.run(backend=ThreadPoolBackend(4))
    resumed = TuningSession("s4", Tuner(space, SETTINGS), bench,
                            cache_dir=tmp_path, fingerprint="fp")
    second = resumed.run(backend=ThreadPoolBackend(4))
    assert second.n_cached == 8
    assert second.best_config == first.best_config == {"x": 5}


def test_cached_best_feeds_incumbent_even_without_warm_start(tmp_path):
    """Cache hits replay through the incumbent cell so best_config is
    correct when the whole space is served from cache."""
    path = tmp_path / "c.jsonl"
    cache = TrialCache(path, fingerprint="fp")
    for x in range(4):
        cache.put("b", {"x": x}, make_result(score=float(10 + x)))
    best = cache.best("b", Direction.MAXIMIZE)
    assert best == ({"x": 3}, 13.0)
    assert cache.best("missing", Direction.MAXIMIZE) is None


def test_config_key_canonical():
    assert config_key({"a": 1, "b": 2}) == config_key({"b": 2, "a": 1})
    assert config_key({"a": 1}) != config_key({"a": 2})
    assert json.loads(config_key({"a": 1, "b": 2})) == {"a": 1, "b": 2}


# ---------------------------------------------------------------------------
# Transfer-seed ranking: Spearman correlation across donor fingerprints
# ---------------------------------------------------------------------------


def test_spearman_basics():
    from repro.core import spearman
    assert spearman([1, 2, 3], [10, 20, 30]) == pytest.approx(1.0)
    assert spearman([1, 2, 3], [30, 20, 10]) == pytest.approx(-1.0)
    # monotone transform leaves ranks (and rho) unchanged
    assert spearman([1, 2, 3, 4], [1, 8, 27, 64]) == pytest.approx(1.0)
    # ties share average ranks
    assert spearman([1, 1, 2], [1, 1, 2]) == pytest.approx(1.0)
    # degenerate: too short, or one side constant
    assert spearman([1], [2]) is None
    assert spearman([1, 2, 3], [5, 5, 5]) is None
    with pytest.raises(ValueError):
        spearman([1, 2], [1])


def _seed_cache(tmp_path):
    """Own fingerprint 'fp' has 4 trials; donor 'agree' ranks the shared
    configs the same way, donor 'disagree' ranks them inverted, donor
    'sparse' overlaps on too few configs to correlate. File order makes
    'sparse' the most recently written donor."""
    path = tmp_path / "c.jsonl"
    scores = {0: 10.0, 1: 20.0, 2: 30.0, 3: 40.0}
    for fp, score_of in (
            ("fp", lambda x, s: s),
            ("agree", lambda x, s: 2 * s + 1),     # same ranking
            ("disagree", lambda x, s: -s),         # inverted ranking
    ):
        c = TrialCache(path, fingerprint=fp)
        for x, s in scores.items():
            c.put("b", {"x": x}, make_result(score=score_of(x, s)))
    sparse = TrialCache(path, fingerprint="sparse")
    sparse.put("b", {"x": 0}, make_result(score=99.0))
    sparse.put("b", {"x": 9}, make_result(score=98.0))
    return path


def test_rank_donors_orders_by_shared_config_correlation(tmp_path):
    path = _seed_cache(tmp_path)
    cache = TrialCache(path, fingerprint="fp")
    ranked = cache.rank_donors("b")
    assert [fp for fp, _ in ranked] == ["agree", "disagree", "sparse"]
    assert ranked[0][1] == pytest.approx(1.0)
    assert ranked[1][1] == pytest.approx(-1.0)
    assert ranked[2][1] is None                    # overlap < 3: no rho


def test_spearman_tied_ranks_use_average_ranks():
    """Ties share average ranks (the tie-robust form, not the 6Σd²
    shortcut) — pinned against hand-computed references so the donor
    ranking keys cannot drift."""
    from repro.core import spearman
    # xs ranks: [1, 2.5, 2.5, 4]; ys strictly increasing: [1, 2, 3, 4]
    # cov = 4.5, var_x = 4.5, var_y = 5  ⇒  rho = 4.5 / sqrt(22.5)
    assert spearman([1, 2, 2, 3], [10, 20, 30, 40]) == \
        pytest.approx(4.5 / math.sqrt(22.5))
    # ties on both sides, same positions: perfect rank agreement
    assert spearman([1, 2, 2, 3], [5, 7, 7, 9]) == pytest.approx(1.0)
    # symmetric in its arguments
    assert spearman([1, 2, 2, 3], [10, 20, 30, 40]) == \
        pytest.approx(spearman([10, 20, 30, 40], [1, 2, 2, 3]))
    # an all-tied side has zero rank variance: undefined, not 0
    assert spearman([2, 2, 2], [1, 2, 3]) is None


def test_spearman_invariant_under_pair_reordering():
    """rho is a function of the pair *set*: feeding the pairs in any
    order (dict-insertion order upstream) gives the same value."""
    from repro.core import spearman
    xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0]
    ys = [2.0, 7.0, 1.0, 8.0, 2.0, 8.0]
    base = spearman(xs, ys)
    rng = random.Random(42)
    for _ in range(5):
        pairs = list(zip(xs, ys))
        rng.shuffle(pairs)
        sx, sy = zip(*pairs)
        assert spearman(list(sx), list(sy)) == pytest.approx(base)


def test_rank_donors_stable_across_record_insertion_orders(tmp_path):
    """The donor order must be a function of (rho, recency), not of the
    order donor records happen to interleave in the file — the pools
    dict's insertion order follows file order, so shuffling the writes
    must not change the ranking."""
    scores = {0: 10.0, 1: 20.0, 2: 30.0, 3: 40.0}
    donors = {"agree": lambda s: 2 * s + 1,
              "disagree": lambda s: -s,
              "noisy": lambda s: s if s != 30.0 else 5.0}  # partial agreement
    rng = random.Random(7)
    rankings = []
    for trial in range(3):
        path = tmp_path / f"c{trial}.jsonl"
        own = TrialCache(path, fingerprint="fp")
        for x, s in scores.items():
            own.put("b", {"x": x}, make_result(score=s))
        writes = [(fp, x, s) for fp, f in donors.items()
                  for x, s in scores.items()]
        rng.shuffle(writes)                    # a different file order each time
        for fp, x, s in writes:
            TrialCache(path, fingerprint=fp).put(
                "b", {"x": x}, make_result(score=donors[fp](s)))
        cache = TrialCache(path, fingerprint="fp")
        rankings.append([fp for fp, _ in cache.rank_donors("b")])
    # rho orders them: agree (1.0) > noisy (partial) > disagree (-1.0),
    # identically for every insertion order
    assert rankings[0] == ["agree", "noisy", "disagree"]
    assert rankings[1] == rankings[0] and rankings[2] == rankings[0]


def test_rank_donors_equal_rho_ties_break_by_recency(tmp_path):
    """Two donors with identical rho order by last write position —
    deterministic, not dict-insertion luck."""
    path = tmp_path / "c.jsonl"
    own = TrialCache(path, fingerprint="fp")
    for x in range(3):
        own.put("b", {"x": x}, make_result(score=float(x)))
    for fp in ("first", "second"):             # both rho = 1.0
        donor = TrialCache(path, fingerprint=fp)
        for x in range(3):
            donor.put("b", {"x": x}, make_result(score=float(10 + x)))
    ranked = TrialCache(path, fingerprint="fp").rank_donors("b")
    assert [fp for fp, _ in ranked] == ["second", "first"]
    assert ranked[0][1] == pytest.approx(1.0)
    assert ranked[1][1] == pytest.approx(1.0)


def test_rank_donors_recency_fallback_without_own_trials(tmp_path):
    """With no own trials nothing correlates: donors keep recency order,
    most recently written first."""
    path = _seed_cache(tmp_path)
    cache = TrialCache(path, fingerprint="brand-new-machine")
    ranked = cache.rank_donors("b")
    assert [fp for fp, rho in ranked] == ["sparse", "disagree", "agree", "fp"]
    assert all(rho is None for _, rho in ranked)


def test_suggest_seeds_tops_up_from_correlated_donors(tmp_path):
    path = _seed_cache(tmp_path)
    cache = TrialCache(path, fingerprint="fp")
    # own best fill first; the correlated donor's foreign config ({"x": 9}
    # isn't there, but 'agree' has none unseen) — ask for more than own 4
    seeds = cache.suggest_seeds("b", limit=6)
    assert seeds[:4] == [{"x": 3}, {"x": 2}, {"x": 1}, {"x": 0}]
    # donors contribute only configs the own pool didn't already supply:
    # 'sparse' brings {"x": 9}
    assert {"x": 9} in seeds
    # explicit foreign fingerprint: unchanged single-donor semantics
    assert cache.suggest_seeds("b", fingerprint="disagree", limit=2) == \
        [{"x": 0}, {"x": 1}]


def test_suggest_seeds_without_own_pool_uses_recency_ranked_donors(tmp_path):
    path = _seed_cache(tmp_path)
    cache = TrialCache(path, fingerprint="brand-new-machine")
    seeds = cache.suggest_seeds("b", limit=2)
    # most recent donor is 'sparse': its best configs lead
    assert seeds == [{"x": 0}, {"x": 9}]

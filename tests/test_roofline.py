"""Roofline model math (paper Eq. 1-2) and term analysis."""

import math


from repro.analysis.hlo import parse_collectives
from repro.core.roofline import (TPU_V5E, TRIAD_INTENSITY, attainable,
                                 from_measurements, operational_intensity,
                                 ridge_point)


def test_attainable_eq2():
    # memory-bound region: F = B*I
    assert attainable(0.5, 100e12, 800e9) == 400e9
    # compute-bound region: F = Fp
    assert attainable(1000.0, 100e12, 800e9) == 100e12


def test_ridge_point():
    assert abs(ridge_point(100e12, 800e9) - 125.0) < 1e-9
    # v5e bf16 ridge: 197e12 / 819e9 ≈ 240 FLOP/byte
    assert 230 < ridge_point(TPU_V5E.peak_flops,
                             TPU_V5E.mem_bandwidths["hbm"]) < 250


def test_triad_intensity():
    assert abs(TRIAD_INTENSITY - 1.0 / 12.0) < 1e-12


def test_operational_intensity():
    assert operational_intensity(24.0, 288.0) == 1.0 / 12.0
    assert operational_intensity(1.0, 0.0) == math.inf


def test_model_bound_classification():
    model = from_measurements("test", 100e12, {"dram": 800e9})
    assert model.bound(1.0, "dram") == "memory"
    assert model.bound(1e4, "dram") == "compute"


def test_curve_monotone_saturating():
    model = from_measurements("test", 100e12, {"dram": 800e9})
    pts = model.curve("dram")
    ys = [p[1] for p in pts]
    assert all(b >= a for a, b in zip(ys, ys[1:]))
    assert ys[-1] == 100e12


def test_csv_and_ascii():
    model = from_measurements("test", 1e12, {"l3": 1e11, "dram": 1e10})
    csv = model.to_csv()
    assert csv.splitlines()[0] == "subsystem,intensity_flop_per_byte,attainable_flops"
    assert "dram" in csv and "l3" in csv
    art = model.ascii_plot("dram", marks=[("x", 1.0, 1e10)])
    assert "roofline[test/dram]" in art


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

SAMPLE_HLO = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups=[32,16]<=[512], dimensions={0}
  %ar = f32[128]{0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %ard = f32[128]{0} all-reduce-done(%ar)
  %cp = f32[64]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %noise = f32[2,2]{1,0} add(%a, %b)
"""


def test_parse_collectives_counts_and_bytes():
    stats = parse_collectives(SAMPLE_HLO, n_devices=512)
    assert stats.count_by_op["all-gather"] == 1
    assert stats.count_by_op["all-reduce"] == 1      # -done not re-counted
    assert stats.count_by_op["collective-permute"] == 1
    ag = 16 * 1024 * 2 * (15 / 16)                    # group size 16
    ar = 2 * 128 * 4 * (3 / 4)                        # group size 4
    cp = 64 * 4
    assert abs(stats.bytes_by_op["all-gather"] - ag) < 1e-6
    assert abs(stats.bytes_by_op["all-reduce"] - ar) < 1e-6
    assert abs(stats.bytes_by_op["collective-permute"] - cp) < 1e-6


def test_parse_collectives_empty():
    stats = parse_collectives("%r = f32[4]{0} add(%a, %b)", 8)
    assert stats.total_bytes == 0 and stats.summary() == "none"


def test_percent_of_roof():
    model = from_measurements("test", 100e12, {"dram": 800e9})
    # memory-bound point: roof is B*I
    assert abs(model.percent_of_roof(1.0, 400e9, "dram") - 50.0) < 1e-9
    # compute-bound point: roof is Fp
    assert abs(model.percent_of_roof(1e4, 100e12, "dram") - 100.0) < 1e-9


def test_gap_table_rows():
    model = from_measurements("test", 100e12, {"l3": 8e12, "dram": 800e9})
    rows = model.gap_table([("dgemm", 1000.0, 90e12)])
    assert len(rows) == 2                      # one row per subsystem
    by_sub = {r["subsystem"]: r for r in rows}
    assert by_sub["dram"]["bound"] == "compute"
    assert abs(by_sub["dram"]["pct_of_roof"] - 90.0) < 1e-9
    assert by_sub["dram"]["attainable_flops"] == 100e12


def test_dashboard_multi_subsystem():
    model = from_measurements("test", 1e12, {"l3": 1e11, "dram": 1e10})
    art = model.dashboard(marks=[("dgemm", 64.0, 9e11)])
    assert "roofline[test]" in art
    assert "legend:" in art and "*=l3" in art and "+=dram" in art
    assert "D=dgemm" in art
    grid_lines = art.splitlines()[1:-1]
    assert any("D" in line for line in grid_lines)  # marker actually drawn
    # deterministic: same inputs, same art
    assert art == model.dashboard(marks=[("dgemm", 64.0, 9e11)])

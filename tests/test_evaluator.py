"""Two-level evaluation process (paper Fig. 2)."""

import pytest

from repro.core.evaluator import EvaluationSettings, Evaluator, timed_sampler
from repro.core.stop_conditions import Direction


def gaussian_bench(mu, sigma, rng):
    def factory():
        def sample():
            return float(rng.normal(mu, sigma))
        return sample
    return factory


def test_default_runs_fixed_budget(rng):
    s = EvaluationSettings(max_invocations=3, max_iterations=50,
                           max_time_s=60.0)
    r = Evaluator(s).evaluate(gaussian_bench(10, 0.5, rng))
    assert r.total_samples == 150            # 3 x 50, no early stop
    assert len(r.invocations) == 3
    assert abs(r.score - 10.0) < 0.5
    assert not r.pruned


def test_label():
    base = EvaluationSettings()
    assert base.label() == "Default"
    assert EvaluationSettings(use_ci_convergence=True).label() == "C"
    assert EvaluationSettings(use_ci_convergence=True, use_inner_prune=True,
                              use_outer_prune=True).label() == "C+I+O"


def test_ci_convergence_stops_early(rng):
    s = EvaluationSettings(max_invocations=3, max_iterations=500,
                           max_time_s=60.0, use_ci_convergence=True)
    r = Evaluator(s).evaluate(gaussian_bench(10, 0.01, rng))
    assert r.total_samples < 150             # terminates well before cap
    assert abs(r.score - 10.0) < 0.1


def test_inner_prune_kills_doomed_configs(rng):
    s = EvaluationSettings(max_invocations=3, max_iterations=500,
                           use_ci_convergence=True, use_inner_prune=True)
    r = Evaluator(s).evaluate(gaussian_bench(5, 0.1, rng), incumbent=50.0)
    assert r.pruned
    assert r.total_samples <= 10             # dies after min_count samples


def test_pruning_respects_direction(rng):
    s = EvaluationSettings(max_invocations=2, max_iterations=100,
                           use_ci_convergence=True, use_inner_prune=True,
                           direction=Direction.MINIMIZE)
    # incumbent time 1.0s; candidate at 5.0s must be pruned
    r = Evaluator(s).evaluate(gaussian_bench(5.0, 0.05, rng), incumbent=1.0)
    assert r.pruned


def test_timed_sampler_returns_rate():
    ticks = iter([0.0, 0.5])
    sample = timed_sampler(lambda: None, work=100.0,
                           clock=lambda: next(ticks))
    assert abs(sample() - 200.0) < 1e-6      # 100 units / 0.5 s


def test_timed_sampler_subtracts_clock_overhead():
    from repro.core.evaluator import ClockCalibration

    ticks = iter([0.0, 0.6])
    cal = ClockCalibration(resolution_s=0.0, overhead_s=0.1)
    sample = timed_sampler(lambda: None, work=100.0,
                           clock=lambda: next(ticks), calibration=cal)
    assert abs(sample() - 200.0) < 1e-6      # 100 / (0.6 - 0.1)


def test_timed_sampler_warns_once_under_clock_resolution():
    import warnings

    from repro.core.evaluator import ClockCalibration, TimingResolutionWarning

    t = [0.0]

    def clock():
        t[0] += 1e-4                         # sample dt 1e-4 << 10x res
        return t[0]

    cal = ClockCalibration(resolution_s=1e-3, overhead_s=0.0)
    sample = timed_sampler(lambda: None, work=1.0, clock=clock,
                           calibration=cal)
    with pytest.warns(TimingResolutionWarning):
        first = sample()
    # the reading is floored at the calibrated resolution, not 1e-12:
    # a sub-resolution dt cannot fabricate a huge throughput
    assert first == pytest.approx(1.0 / 1e-3)
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # one-shot: no second warning
        sample()


def test_calibrate_clock_caches_default_but_not_custom():
    import time

    from repro.core.evaluator import calibrate_clock

    a = calibrate_clock()
    assert a is calibrate_clock()            # per-process cache
    assert a.resolution_s > 0.0
    assert a.overhead_s >= 0.0
    custom = calibrate_clock(time.perf_counter.__call__, samples=64)
    assert custom is not a                   # fresh measurement


def test_high_variance_hits_max_count(rng):
    s = EvaluationSettings(max_invocations=1, max_iterations=30,
                           max_time_s=60.0, use_ci_convergence=True)
    r = Evaluator(s).evaluate(gaussian_bench(10, 8.0, rng))
    assert r.invocations[0].count == 30
    assert "max_count" in r.invocations[0].stop_reason


@pytest.mark.parametrize("method", ["welford", "bootstrap", "median"])
def test_ci_methods_converge(method, rng):
    """Paper §VII future work: bootstrap and median stop statistics are
    drop-in CI methods — all converge to the same answer on clean data."""
    s = EvaluationSettings(max_invocations=2, max_iterations=300,
                           use_ci_convergence=True, ci_method=method,
                           rel_margin=0.02)
    r = Evaluator(s).evaluate(gaussian_bench(10.0, 0.3, rng))
    assert abs(r.score - 10.0) < 0.3
    assert r.total_samples < 600  # converged before the cap


def test_median_method_robust_to_outliers(rng):
    """The median CI ignores rare spikes that wreck the normal CI width."""
    def factory():
        state = {"i": 0}

        def sample():
            state["i"] += 1
            if state["i"] % 50 == 0:
                return 1000.0            # rare scheduler spike
            return float(rng.normal(10.0, 0.2))
        return sample

    s = EvaluationSettings(max_invocations=1, max_iterations=300,
                           use_ci_convergence=True, ci_method="median",
                           rel_margin=0.02)
    r = Evaluator(s).evaluate(factory)
    # the mean-based score is pulled by spikes, but convergence was reached
    # by the median CI rather than the (noisy) normal CI
    assert "ci_converged" in r.invocations[0].stop_reason

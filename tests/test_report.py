"""Cache-backed roofline reporting: query layer, incumbent extraction
(must match warm-start selection), exact-moment CIs, golden dashboards,
and the report CLI.

Regenerate the golden files after an intentional rendering change with:

    PYTHONPATH=src python -m pytest tests/test_report.py -q --update-golden
"""

import os
import pathlib
import subprocess
import sys

from repro.core import (EvaluationSettings, TrialCache, Tuner, TuningSession,
                        build_reports, ci_mean, extract_incumbent,
                        group_by_fingerprint, load_trials, welford)
from repro.core.cache import CachedTrial, iter_trials
from repro.core.evaluator import EvalResult, InvocationResult
from repro.core.report import (dgemm_config_intensity, pooled_state,
                               render_csv, render_markdown,
                               trials_from_result, triad_subsystems)
from repro.core.searchspace import grid
from repro.core.stop_conditions import Direction

REPO = pathlib.Path(__file__).resolve().parent.parent


def make_result(score, pruned=False, spreads=(1.0, 2.0)):
    """Deterministic EvalResult whose invocation moments come from real
    sample streams (mean of each stream is exactly ``score``)."""
    invs = []
    samples = 0
    for off in spreads:
        st = welford.from_samples([score - off, score + off])
        samples += int(st.count)
        invs.append(InvocationResult(mean=float(st.mean), count=int(st.count),
                                     elapsed_s=0.125, pruned=False,
                                     stop_reason="max_count(2)",
                                     m2=float(st.m2)))
    return EvalResult(score=score, best_invocation=score,
                      invocations=tuple(invs), total_samples=samples,
                      total_time_s=0.25, measured_time_s=0.25,
                      pruned=pruned, stop_reason="max_count(2)")


def synthetic_trials():
    """Two complete fingerprints + one triad-only fingerprint (skipped)."""
    return [
        CachedTrial("dgemm", "fpA", {"n": 256, "m": 256, "k": 64},
                    make_result(80.0)),
        CachedTrial("dgemm", "fpA", {"n": 512, "m": 512, "k": 128},
                    make_result(120.0)),
        CachedTrial("dgemm", "fpA", {"n": 1024, "m": 1024, "k": 512},
                    make_result(999.0, pruned=True)),   # pruned: never wins
        CachedTrial("triad", "fpA", {"n_bytes": 1 << 22}, make_result(40.0)),
        CachedTrial("triad", "fpA", {"n_bytes": 1 << 28}, make_result(10.0)),
        CachedTrial("dgemm", "fpB", {"n": 512, "m": 512, "k": 128},
                    make_result(900.0)),
        CachedTrial("triad", "fpB", {"n_bytes": 1 << 22}, make_result(300.0)),
        CachedTrial("triad", "fpB", {"n_bytes": 1 << 28}, make_result(100.0)),
        CachedTrial("triad", "fpC", {"n_bytes": 1 << 22}, make_result(55.0)),
    ]


def write_cache(path, trials):
    for t in trials:
        TrialCache(path, fingerprint=t.fingerprint).put(
            t.benchmark, t.config, t.result)


# ---------------------------------------------------------------------------
# Query layer
# ---------------------------------------------------------------------------


def test_iter_trials_reads_across_fingerprints(tmp_path):
    path = tmp_path / "c.jsonl"
    write_cache(path, synthetic_trials())
    got = list(iter_trials(path))
    assert len(got) == len(synthetic_trials())
    assert {t.fingerprint for t in got} == {"fpA", "fpB", "fpC"}
    # TrialCache by contrast only serves its own fingerprint
    assert len(TrialCache(path, fingerprint="fpA")) == 5


def test_load_trials_last_wins_dedup(tmp_path):
    path = tmp_path / "c.jsonl"
    cache = TrialCache(path, fingerprint="fp")
    cache.put("b", {"x": 1}, make_result(10.0))
    cache.put("b", {"x": 1}, make_result(20.0))   # re-run overwrites
    cache.put("b", {"x": 2}, make_result(5.0))
    got = load_trials(path)
    assert len(got) == 2
    assert got[0].result.score == 20.0            # last record won
    assert [t.config for t in got] == [{"x": 1}, {"x": 2}]  # order kept


def test_load_trials_directory_of_sessions(tmp_path):
    write_cache(tmp_path / "s1.jsonl", synthetic_trials()[:2])
    write_cache(tmp_path / "s2.jsonl", synthetic_trials()[5:6])
    got = load_trials(tmp_path)
    assert len(got) == 3
    assert load_trials(tmp_path / "s1.jsonl") == got[:2]


def test_trial_cache_query_methods(tmp_path):
    path = tmp_path / "c.jsonl"
    write_cache(path, synthetic_trials())
    cache = TrialCache(path, fingerprint="fpA")
    assert cache.benchmarks() == ["dgemm", "triad"]
    assert len(cache.items("triad")) == 2
    assert all(t.fingerprint == "fpA" for t in cache.trials())


def test_version_mismatch_skipped(tmp_path):
    path = tmp_path / "c.jsonl"
    write_cache(path, synthetic_trials()[:1])
    text = path.read_text().replace('"version": 1', '"version": 99')
    path.write_text(text)
    assert list(iter_trials(path)) == []


# ---------------------------------------------------------------------------
# Incumbent extraction == warm-start selection
# ---------------------------------------------------------------------------


def counting_benchmark(cfg):
    mu = 100.0 - (cfg["x"] - 5) ** 2
    return lambda: (lambda: mu)


SETTINGS = EvaluationSettings(max_invocations=2, max_iterations=10,
                              use_ci_convergence=True, use_inner_prune=True,
                              use_outer_prune=True)


def test_extract_incumbent_matches_session_warm_start(tmp_path):
    """The report layer must name the same winner a resumed TuningSession
    warm-starts from (TrialCache.best)."""
    session = TuningSession("s", Tuner(grid(x=tuple(range(8))), SETTINGS),
                            counting_benchmark, cache_dir=tmp_path,
                            fingerprint="fp", benchmark_name="bench")
    result = session.run()
    trials = load_trials(tmp_path / "s.jsonl")
    inc = extract_incumbent(trials, "bench", Direction.MAXIMIZE)
    warm = session.cache.best("bench", Direction.MAXIMIZE)
    assert warm is not None and inc is not None
    assert (inc.config, inc.score) == warm
    assert inc.config == result.best_config
    assert inc.score == result.best_score


def test_extract_incumbent_skips_pruned_and_other_benchmarks():
    trials = synthetic_trials()
    fpA = group_by_fingerprint(trials)["fpA"]
    inc = extract_incumbent(fpA, "dgemm")
    assert inc.score == 120.0                 # not the pruned 999.0
    assert extract_incumbent(fpA, "missing") is None


def test_pooled_state_exact_roundtrip(tmp_path):
    """CI recovered from cached moments == CI over the raw sample stream."""
    res = make_result(100.0, spreads=(0.5, 1.5, 2.5))
    path = tmp_path / "c.jsonl"
    TrialCache(path, fingerprint="fp").put("b", {"x": 1}, res)
    hit = TrialCache(path, fingerprint="fp").get("b", {"x": 1})
    raw = []
    for off in (0.5, 1.5, 2.5):
        raw += [100.0 - off, 100.0 + off]
    assert ci_mean(pooled_state(hit), 0.99) == \
        ci_mean(welford.from_samples(raw), 0.99)


# ---------------------------------------------------------------------------
# Benchmark interpretation
# ---------------------------------------------------------------------------


def test_dgemm_config_intensity():
    # n=m=k=1024 f32: 2*1024^3 / (3*1024^2*4)
    i = dgemm_config_intensity({"n": 1024, "m": 1024, "k": 1024})
    assert abs(i - 2 * 1024 / 12.0) < 1e-9
    assert dgemm_config_intensity({"x": 3}) is None


def test_triad_subsystems_per_config():
    subs = triad_subsystems(synthetic_trials(), "triad")
    # grouped across fingerprints only when caller doesn't pre-group;
    # here fpB's 300 GB/s wins the 4MiB bucket
    assert set(subs) == {"mem[4MiB]", "mem[256MiB]"}
    assert subs["mem[4MiB]"].score == 300.0
    fpA = group_by_fingerprint(synthetic_trials())["fpA"]
    assert triad_subsystems(fpA, "triad")["mem[4MiB]"].score == 40.0


# ---------------------------------------------------------------------------
# Report assembly + golden dashboards
# ---------------------------------------------------------------------------


def test_build_reports_structure():
    reports, skipped = build_reports(synthetic_trials())
    assert [r.fingerprint for r in reports] == ["fpA", "fpB"]
    assert skipped == [("fpC", "no unpruned 'dgemm' trials")]
    fpA = reports[0]
    assert fpA.peak_flops == 120.0e9
    assert dict(fpA.bandwidths)["mem[4MiB]"].score == 40.0
    labels = [label for label, _, _ in fpA.marks]
    assert labels == ["dgemm", "triad:mem[256MiB]", "triad:mem[4MiB]"]
    # triad marks gap only against their own subsystem; dgemm against all
    gap = fpA.gap_rows()
    assert sum(1 for g in gap if g["kernel"] == "dgemm") == 2
    triad_rows = [g for g in gap if g["kernel"].startswith("triad:")]
    assert all(g["kernel"].endswith(g["subsystem"]) for g in triad_rows)
    # TRIAD sits on its own slope by construction: 100% of its roof
    assert all(abs(g["pct_of_roof"] - 100.0) < 1e-9 for g in triad_rows)


def test_markdown_dashboard_matches_golden(golden):
    reports, skipped = build_reports(synthetic_trials())
    md = render_markdown(reports, skipped)
    assert "ASCII" not in md  # sanity: plot is embedded, not described
    for section in ("# Cache-backed roofline dashboard",
                    "## Fingerprint `fpA`", "## Fingerprint `fpB`",
                    "```text", "### Model vs measured (% of roof)",
                    "## Fingerprint comparison",
                    "## Skipped fingerprints"):
        assert section in md
    golden("roofline_report.md", md)


def test_csv_dashboard_matches_golden(golden):
    reports, _ = build_reports(synthetic_trials())
    csv = render_csv(reports)
    header, *rows = csv.splitlines()
    assert header == ("fingerprint,kind,name,intensity_flop_per_byte,"
                      "value,pct_of_roof,config")
    kinds = {r.split(",")[1] for r in rows}
    assert kinds == {"peak_flops", "bandwidth", "curve", "mark", "gap"}
    assert all(len(r.split(",")) == 7 for r in rows)  # no embedded commas
    golden("roofline_report.csv", csv)


def test_trials_from_result_roundtrip():
    result = Tuner(grid(x=tuple(range(8))), SETTINGS).tune(
        counting_benchmark)
    trials = trials_from_result(result, "bench", "fp-mem")
    assert len(trials) == len(result.trials)
    inc = extract_incumbent(trials, "bench")
    assert inc.config == result.best_config


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _run_cli(*argv):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / "roofline_report.py"),
         *map(str, argv)],
        capture_output=True, text=True, env=env, timeout=120)


def test_cli_emits_dashboard_and_csv(tmp_path):
    cache = tmp_path / "nightly.jsonl"
    write_cache(cache, synthetic_trials())
    out_csv = tmp_path / "roofline.csv"
    proc = _run_cli(cache, "--csv", out_csv)
    assert proc.returncode == 0, proc.stderr
    assert "# Cache-backed roofline dashboard" in proc.stdout
    assert "## Fingerprint comparison" in proc.stdout
    assert "- `fpC`: no unpruned 'dgemm' trials" in proc.stdout
    assert out_csv.read_text().startswith("fingerprint,kind,name,")


def test_cli_refuses_unreportable_cache(tmp_path):
    cache = tmp_path / "triad-only.jsonl"
    write_cache(cache, synthetic_trials()[8:])   # fpC only
    proc = _run_cli(cache)
    assert proc.returncode == 1
    assert "no unpruned 'dgemm' trials" in proc.stderr


def test_cli_missing_path():
    proc = _run_cli("/nonexistent/cache.jsonl")
    assert proc.returncode == 2

"""Per-architecture smoke tests: reduced same-family configs, one train
step on CPU, shape + finiteness asserts, prefill/decode consistency."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.models import params as P
from repro.models.transformer import StepConfig

STEP = StepConfig(remat=False, loss_chunk=8)
B, S = 2, 16


def make_batch(cfg, seq=S):
    key = jax.random.key(1)
    batch = {"tokens": jax.random.randint(key, (B, seq), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.n_frames, cfg.d_enc),
            cfg.jdtype)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.n_image_tokens, cfg.d_model),
            cfg.jdtype)
    return batch


@pytest.fixture(scope="module", params=configs.ARCH_IDS)
def arch(request):
    return request.param


def test_exact_assigned_config_values():
    """The full configs must match the assignment table exactly."""
    c = configs.get("command_r_plus_104b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (64, 12288, 96, 8, 33792, 256000)
    c = configs.get("granite_3_2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 2048, 32, 8, 8192, 49155)
    c = configs.get("minicpm_2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 2304, 36, 36, 5760, 122753)
    assert c.lr_schedule == "wsd"
    c = configs.get("gemma_2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.head_dim) == (18, 2048, 8, 1, 16384, 256000, 256)
    assert c.act == "gelu"
    c = configs.get("whisper_base")
    assert (c.n_layers, c.n_enc_layers, c.d_model, c.n_heads, c.d_ff,
            c.vocab_size) == (6, 6, 512, 8, 2048, 51865)
    c = configs.get("granite_moe_1b_a400m")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.n_experts, c.top_k) == (24, 1024, 16, 8, 512,
                                                    49155, 32, 8)
    c = configs.get("mixtral_8x22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.n_experts, c.top_k) == (56, 6144, 48, 8, 16384,
                                                    32768, 8, 2)
    assert c.window is not None
    c = configs.get("llama_3_2_vision_11b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 4096, 32, 8, 14336, 128256)
    c = configs.get("mamba2_130m")
    assert (c.n_layers, c.d_model, c.vocab_size, c.ssm_state) == (24, 768,
                                                                  50280, 128)
    c = configs.get("zamba2_2_7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size,
            c.ssm_state) == (54, 2560, 32, 10240, 32000, 64)


def test_train_step_smoke(arch):
    """One forward/backward on the reduced config: finite loss + grads,
    correct logits shapes."""
    cfg = configs.get_smoke(arch)
    p = P.materialize(jax.random.key(0), api.param_defs(cfg))
    batch = make_batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda pp: api.loss_fn(pp, batch, cfg, STEP)))(p)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32)))
               for g in leaves)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in leaves)


def test_prefill_decode_consistency(arch):
    """Teacher-forced decode from a prefilled cache must reproduce the
    full-sequence forward logits position by position."""
    cfg = configs.get_smoke(arch)
    p = P.materialize(jax.random.key(0), api.param_defs(cfg))
    batch = make_batch(cfg, seq=S)
    n_prefill, n_decode = 8, 4

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :n_prefill]
    logits_p, cache = jax.jit(
        lambda pp, bb: api.prefill_fn(pp, bb, cfg, STEP))(p, pre)
    cache = api.extend_cache(cache, n_decode)

    # reference: full forward logits at each position
    full = dict(batch)
    full["tokens"] = batch["tokens"][:, :n_prefill + n_decode]
    ref_logits, _ = jax.jit(
        lambda pp, bb: api.prefill_fn(pp, bb, cfg, STEP))(p, full)

    step_logits = None
    for t in range(n_prefill, n_prefill + n_decode):
        dec = dict(batch)
        dec["tokens"] = batch["tokens"][:, t:t + 1]
        step_logits, cache = jax.jit(
            lambda pp, bb, cc, pos: api.decode_fn(pp, bb, cc, pos, cfg,
                                                  STEP))(
            p, dec, cache, jnp.int32(t))
    # compare final decode logits to the full forward's last position
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0], np.float32),
        np.asarray(ref_logits[:, 0], np.float32), rtol=2e-3, atol=2e-3)


def test_param_count_scales(arch):
    """Full config param count is positive and far larger than smoke."""
    full = configs.get(arch)
    smoke = configs.get_smoke(arch)
    n_full = full.n_params()
    assert n_full > 50 * P.n_params(api.param_defs(smoke))
    assert full.n_active_params() <= n_full


def test_full_param_counts_plausible():
    """Sanity against the advertised model sizes (±40%; embeddings and our
    simplifications account for slack)."""
    expect = {
        "command_r_plus_104b": 104e9,
        "mixtral_8x22b": 141e9,
        "granite_3_2b": 2.5e9,
        "gemma_2b": 2.5e9,
        "minicpm_2b": 2.7e9,
        "llama_3_2_vision_11b": 10e9,
        "mamba2_130m": 0.13e9,
        "zamba2_2_7b": 2.7e9,
    }
    for arch, n in expect.items():
        got = configs.get(arch).n_params()
        assert 0.6 * n < got < 1.6 * n, (arch, got, n)

"""Shape-sweep campaigns and the dispatch-time config oracle — shape
keys, joint shape×config encoding, prior-warmed sweep strategy, campaign
cache/ledger attribution, cold-start fallback, and the acceptance
criterion: on a 3×3 synthetic-DGEMM grid with one held-out shape, the
oracle's predicted config lands within 2% of that shape's exhaustive
optimum while the campaign spends ≤ 25% of the exhaustive trial count."""

import pathlib
import subprocess
import sys

import numpy as np
import pytest

from benchmarks.common import (gemm_shape_space, sweep_config_space,
                               synthetic_gemm_family)
from repro.core import Direction, EvaluationSettings, TrialCache, grid
from repro.history.ledger import iter_runs
from repro.surrogate import SpaceEncoder
from repro.sweep import (ConfigOracle, SweepCampaign, SweepStrategy,
                         parse_shape_key, shape_benchmark_name, shape_key,
                         split_benchmark_name)

SETTINGS = EvaluationSettings(max_invocations=2, max_iterations=3,
                              max_time_s=5.0, use_inner_prune=True,
                              direction=Direction.MAXIMIZE)


def true_score(shape, cfg):
    """The synthetic family's deterministic objective, evaluated directly."""
    return synthetic_gemm_family(shape)(cfg)()()


def exhaustive_optimum(shape, space):
    best_cfg, best = None, -np.inf
    for cfg in space.ordered("exhaustive"):
        s = true_score(shape, cfg)
        if s > best:
            best_cfg, best = cfg, s
    return best_cfg, best


# ---------------------------------------------------------------- shape keys

def test_shape_key_is_canonical_and_roundtrips():
    shape = {"n": 1024, "m": 512}
    key = shape_key(shape)
    assert key == "m=512,n=1024"              # sorted, insertion-order-proof
    assert parse_shape_key(key) == {"m": 512, "n": 1024}
    assert shape_key(parse_shape_key(key)) == key


def test_shape_key_parses_value_types():
    assert parse_shape_key("a=1,b=1.5,c=fp16") == {"a": 1, "b": 1.5,
                                                   "c": "fp16"}


def test_shape_key_rejects_reserved_characters():
    for bad in ({}, {"m=1": 2}, {"m": "a,b"}, {"m": "x@y"}):
        with pytest.raises(ValueError):
            shape_key(bad)


def test_benchmark_name_split_roundtrips():
    name = shape_benchmark_name("dgemm", {"m": 256, "n": 512})
    assert name == "dgemm@m=256,n=512"
    assert split_benchmark_name(name) == ("dgemm", {"m": 256, "n": 512})
    assert split_benchmark_name("plain") == ("plain", None)
    with pytest.raises(ValueError):
        shape_benchmark_name("a@b", {"m": 1})


# ------------------------------------------------------------ shape encoding

def test_encoder_shape_features_interpolate_on_log_scale():
    space = grid(bm=(16, 32))
    shapes = grid(m=(256, 512, 1024))
    enc = SpaceEncoder(space, shape_space=shapes)
    assert enc.dim == enc.config_dim + 1
    lo = enc.shape_features({"m": 256})
    mid = enc.shape_features({"m": 512})
    hi = enc.shape_features({"m": 1024})
    assert lo[0] == 0.0 and hi[0] == 1.0
    assert mid[0] == pytest.approx(0.5)       # geometric midpoint, log scale
    # unseen shapes interpolate; out-of-range shapes clamp
    assert 0.5 < enc.shape_features({"m": 768})[0] < 1.0
    assert enc.shape_features({"m": 4096})[0] == 1.0
    assert enc.shape_features({"m": 16})[0] == 0.0


def test_encoder_joint_encoding_requires_and_embeds_shape():
    space = grid(bm=(16, 32))
    enc = SpaceEncoder(space, shape_space=grid(m=(256, 1024)))
    with pytest.raises(TypeError):
        enc.encode({"bm": 16})
    x = enc.encode({"bm": 32}, shape={"m": 1024})
    assert x.shape == (enc.dim,)
    assert x[-1] == 1.0
    assert enc.decode(x)["bm"] == 32          # decode ignores shape block


def test_encoder_categorical_shape_param_is_one_hot():
    enc = SpaceEncoder(grid(bm=(16, 32)),
                       shape_space=grid(dtype=("fp16", "fp32")))
    f16 = enc.shape_features({"dtype": "fp16"})
    f32 = enc.shape_features({"dtype": "fp32"})
    assert sorted(f16) == [0.0, 1.0] and sorted(f32) == [0.0, 1.0]
    assert not np.allclose(f16, f32)


# ------------------------------------------------------------- SweepStrategy

def test_sweep_strategy_requires_complete_shape():
    with pytest.raises(KeyError):
        SweepStrategy({"m": 256}, grid(m=(256, 512), n=(256, 512)))


def test_sweep_strategy_priors_shrink_n_init():
    space = sweep_config_space()
    shapes = gemm_shape_space(quick=True)
    cold = SweepStrategy({"m": 256, "n": 256}, shapes, seed=0)
    cold.reset(space, SETTINGS)
    priors = [({"m": 512, "n": 512}, cfg, true_score({"m": 512, "n": 512},
                                                     cfg))
              for cfg in space.ordered("exhaustive")]
    warm = SweepStrategy({"m": 256, "n": 256}, shapes, priors=priors, seed=0)
    warm.reset(space, SETTINGS)
    assert warm._n_priors == len(priors)
    assert len(warm._init_queue) < len(cold._init_queue)


def test_sweep_strategy_skips_foreign_prior_configs():
    space = sweep_config_space()
    shapes = gemm_shape_space(quick=True)
    priors = [({"m": 512, "n": 512}, {"bm": 16, "bn": 16}, 99.0),
              ({"m": 512, "n": 512}, {"weird": True}, 1.0)]
    strat = SweepStrategy({"m": 256, "n": 256}, shapes, priors=priors)
    strat.reset(space, SETTINGS)
    assert strat._n_priors == 1


# ----------------------------------------------------- campaign + attribution

def test_campaign_stamps_cache_and_ledger(tmp_path):
    shapes = grid(m=(256, 1024))
    campaign = SweepCampaign(sweep_config_space(), shapes,
                             synthetic_gemm_family, SETTINGS, name="camp",
                             cache_dir=tmp_path, budget_per_shape=5, seed=3)
    result = campaign.run(timestamp=1.0)
    assert len(result.outcomes) == 2
    assert result.outcome_for({"m": 1024}) is not None
    assert result.outcome_for({"m": 4096}) is None

    cache = TrialCache(tmp_path / "camp.jsonl")
    benches = cache.benchmarks(prefix="camp@")
    assert benches == ["camp@m=1024", "camp@m=256"]
    for t in cache.trials():
        assert t.strategy == "sweep"

    records = list(iter_runs(tmp_path / "history.jsonl"))
    assert {r.benchmark for r in records} == {"camp@m=256", "camp@m=1024"}
    assert all(r.strategy == "sweep" for r in records)
    assert all(r.campaign == "camp" for r in records)


def test_campaign_resume_serves_from_cache(tmp_path):
    shapes = grid(m=(256, 1024))
    campaign = SweepCampaign(sweep_config_space(), shapes,
                             synthetic_gemm_family, SETTINGS, name="camp",
                             cache_dir=tmp_path, budget_per_shape=5, seed=3)
    first = campaign.run(timestamp=1.0)
    n = len(TrialCache(campaign.cache_path))
    second = campaign.run(timestamp=2.0)
    assert len(TrialCache(campaign.cache_path)) == n   # nothing re-measured
    for o in second.outcomes:
        assert o.result.n_cached == len(o.result.trials)
    assert {shape_key(o.shape) for o in first.outcomes} \
        == {shape_key(o.shape) for o in second.outcomes}


def test_campaign_priors_exclude_own_shape(tmp_path):
    shapes = grid(m=(256, 1024))
    campaign = SweepCampaign(sweep_config_space(), shapes,
                             synthetic_gemm_family, SETTINGS, name="camp",
                             cache_dir=tmp_path, budget_per_shape=4, seed=1)
    campaign.run(timestamp=1.0)
    pri = campaign.priors(exclude={"m": 256})
    assert pri, "sibling trials should produce priors"
    assert all(shape_key(s) != "m=256" for s, _, _ in pri)
    assert len(campaign.priors()) > len(pri)


# ------------------------------------------------------------------- oracle

def test_oracle_cold_falls_back_to_nearest_incumbent(tmp_path):
    shapes = grid(m=(256, 512, 1024))
    campaign = SweepCampaign(sweep_config_space(), shapes,
                             synthetic_gemm_family, SETTINGS, name="cold",
                             cache_dir=tmp_path, budget_per_shape=6, seed=0)
    campaign.run(shapes=[{"m": 256}], timestamp=1.0)
    oracle = campaign.oracle()
    assert not oracle.is_warm()               # one tuned shape < min_shapes
    ans = oracle.best_for({"m": 300})
    assert ans.cold
    assert ans.source == "nearest:m=256"
    assert ans.donor == {"m": 256}
    # a directly-tuned query answers with its own incumbent
    own = oracle.best_for({"m": 256})
    assert own.source == "nearest:m=256"


def test_oracle_empty_cache_raises(tmp_path):
    oracle = ConfigOracle(sweep_config_space(), grid(m=(256, 1024)),
                          [], base="none")
    with pytest.raises(LookupError):
        oracle.best_for({"m": 512})


def test_oracle_validates_query_shape(tmp_path):
    oracle = ConfigOracle(sweep_config_space(),
                          grid(m=(256, 1024), n=(256, 1024)), [],
                          base="none")
    with pytest.raises(KeyError):
        oracle.best_for({"m": 512})


# ------------------------------------------------------- acceptance criterion

def test_oracle_recovers_heldout_shape_optimum(tmp_path):
    """ISSUE acceptance: 3×3 synthetic grid, shape (512, 512) held out.
    The oracle's prediction for the unseen shape must score within 2% of
    its exhaustive optimum, at ≤ 25% of the exhaustive trial count —
    end-to-end through the shared cache and ledger."""
    config_space = sweep_config_space()
    shape_space = gemm_shape_space(quick=True)
    holdout = {"m": 512, "n": 512}
    campaign = SweepCampaign(config_space, shape_space,
                             synthetic_gemm_family, SETTINGS,
                             name="accept", cache_dir=tmp_path,
                             budget_per_shape=9, seed=0)
    result = campaign.run(holdout=[holdout], timestamp=1.0)

    assert len(result.outcomes) == 8          # 9 grid shapes minus holdout
    assert result.outcome_for(holdout) is None
    exhaustive = shape_space.cardinality * config_space.cardinality
    assert result.total_trials <= 0.25 * exhaustive

    oracle = campaign.oracle()
    assert oracle.is_warm()
    answer = oracle.best_for(holdout)
    assert answer.source == "model"

    best_cfg, best = exhaustive_optimum(holdout, config_space)
    achieved = true_score(holdout, answer.config)
    assert achieved >= best * 0.98, (answer.config, best_cfg)

    # attribution survived the full pipeline
    cache = TrialCache(campaign.cache_path)
    assert "accept@m=512,n=512" not in cache.benchmarks()
    assert all(t.strategy == "sweep" for t in cache.trials())
    records = [r for r in iter_runs(tmp_path / "history.jsonl")
               if r.campaign == "accept"]
    assert len(records) == 8


# ----------------------------------------------------------------------- CLI

def test_sweep_cli_holdout_eval(tmp_path):
    repo = pathlib.Path(__file__).resolve().parent.parent
    out = subprocess.run(
        [sys.executable, str(repo / "scripts" / "sweep.py"),
         "--session", "cli", "--benchmark", "synthetic",
         "--budget-per-shape", "9", "--oracle-eval", "m=512,n=512",
         "--cache-dir", str(tmp_path)],
        capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert "oracle     : warm" in out.stdout
    assert "gap 0.00%" in out.stdout

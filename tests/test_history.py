"""Performance-history subsystem: run ledger, regression gating, rendering.

Covers the acceptance flow end to end: two synthetic tuning sessions on
one fingerprint populate the ledger, an injected slowdown makes
``scripts/perf_gate.py`` exit non-zero with a CI-backed verdict while a
flat rerun passes, and the HTML renderer matches a golden snapshot
(regenerate intentionally-changed goldens with ``pytest --update-golden``).
"""

import os
import pathlib
import subprocess
import sys

import pytest

from repro.core import (EvaluationSettings, Direction, TrialCache, Tuner,
                        TuningSession, grid, welford)
from repro.core.cache import iter_trials
from repro.core.confidence import ci_mean
from repro.core.evaluator import EvalResult, InvocationResult
from repro.core.welford import WelfordState
from repro.history import (RunLedger, ascii_sparkline, compare_runs,
                           detect_regressions, render_html,
                           render_trend_text, welch_interval)
from repro.history.ledger import RunRecord, iter_runs, record_from_result

REPO = pathlib.Path(__file__).resolve().parent.parent

SETTINGS = EvaluationSettings(max_invocations=2, max_iterations=10,
                              use_ci_convergence=True, use_inner_prune=True,
                              use_outer_prune=True)


def quadratic_benchmark(cfg):
    mu = 100.0 - (cfg["x"] - 5) ** 2
    return lambda: (lambda: mu)


def slow_quadratic_benchmark(cfg):
    """The same objective after an injected 10% slowdown."""
    mu = 90.0 - (cfg["x"] - 5) ** 2
    return lambda: (lambda: mu)


def make_record(score, offsets=(0.5, 0.7, 0.4, 0.6, 0.5), run=0,
                benchmark="dgemm", fingerprint="fp", **kw):
    """RunRecord whose moments come from real sample streams: one
    3-sample invocation per offset, each with mean exactly ``score``."""
    states = [welford.from_samples([score - o, score + o, score])
              for o in offsets]
    pooled = welford.tree_merge(states)
    return RunRecord(benchmark=benchmark, fingerprint=fingerprint, run=run,
                     config={"n": 512}, score=score,
                     count=float(pooled.count), mean=float(pooled.mean),
                     m2=float(pooled.m2),
                     invocation_means=tuple(float(s.mean) for s in states),
                     **kw)


# ---------------------------------------------------------------------------
# Ledger mechanics
# ---------------------------------------------------------------------------


def test_append_assigns_monotone_run_index_per_series(tmp_path):
    led = RunLedger(tmp_path / "history.jsonl")
    a0 = led.append(make_record(100.0, run=99))          # caller run ignored
    b0 = led.append(make_record(50.0, benchmark="triad"))
    a1 = led.append(make_record(101.0))
    assert (a0.run, a1.run, b0.run) == (0, 1, 0)
    assert [r.run for r in led.series("dgemm", "fp")] == [0, 1]
    # reload continues the numbering
    led2 = RunLedger(tmp_path / "history.jsonl")
    assert led2.append(make_record(102.0)).run == 2
    assert len(led2) == 4


def test_compact_keeps_best_plus_most_recent(tmp_path):
    """A long series compacts to its best run plus the last keep_last;
    run indices survive unrenumbered and append continues the series."""
    led = RunLedger(tmp_path / "history.jsonl")
    # scores 100..109, then decay: run 9 is the series' best forever
    for k in range(10):
        led.append(make_record(100.0 + k))
    for k in range(6):
        led.append(make_record(95.0 - k))
    assert len(led) == 16
    dropped = led.compact(keep_last=3)
    assert dropped == 12
    runs = led.series("dgemm", "fp")
    assert [r.run for r in runs] == [9, 13, 14, 15]      # best + last 3
    assert runs[0].score == 109.0
    # on-disk state agrees with memory, and a fresh load sees the same
    reloaded = RunLedger(tmp_path / "history.jsonl")
    assert [r.run for r in reloaded.series("dgemm", "fp")] == [9, 13, 14, 15]
    # the next append continues where the series left off
    assert reloaded.append(make_record(96.0)).run == 16
    # a second compact of an already-compact ledger is a no-op
    led2 = RunLedger(tmp_path / "history.jsonl")
    assert led2.compact(keep_last=3) == 1    # run 13 now superseded by 16
    assert led2.compact(keep_last=3) == 0


def test_compact_respects_each_series_direction_and_scope(tmp_path):
    """Per-series best uses the record's own recorded direction, and
    compaction of one series never touches another."""
    led = RunLedger(tmp_path / "history.jsonl")
    for k, s in enumerate([5.0, 1.0, 4.0, 3.0, 2.0]):    # run 1 is best (min)
        led.append(make_record(s, benchmark="latency",
                               direction=Direction.MINIMIZE.value))
    led.append(make_record(50.0, benchmark="triad"))
    led.compact(keep_last=1)
    lat = led.series("latency", "fp")
    assert [r.run for r in lat] == [1, 4]                # min-best + newest
    assert len(led.series("triad", "fp")) == 1           # untouched


def test_compact_preserves_foreign_lines_and_regression_baseline(tmp_path):
    """Foreign lines (other versions, torn writes) survive the rewrite
    verbatim, and the regression baseline — the best historical run —
    still gates after compaction."""
    path = tmp_path / "history.jsonl"
    led = RunLedger(path)
    led.append(make_record(100.0))           # the best: must survive
    for k in range(5):
        led.append(make_record(90.0 - k))
    with open(path, "a", encoding="utf-8") as f:
        f.write('{"ledger_version": 999, "alien": true}\n')
        f.write('{"torn...\n')
    led2 = RunLedger(path)
    led2.compact(keep_last=2)
    text = path.read_text(encoding="utf-8")
    assert '"alien": true' in text
    assert '{"torn...' in text
    report = detect_regressions(RunLedger(path))
    (series,) = report.series
    assert series.verdict == "regressed"     # newest 85 vs best 100 survives
    assert series.comparison.baseline.mean == pytest.approx(100.0)


def test_compact_missing_ledger_and_bad_args(tmp_path):
    led = RunLedger(tmp_path / "nope.jsonl")
    assert led.compact(keep_last=5) == 0     # nothing on disk: no-op
    with pytest.raises(ValueError):
        led.compact(keep_last=0)


def test_tune_cli_compact_history_standalone(tmp_path):
    """scripts/tune.py --compact-history works without --session (pure
    maintenance) and reports what it dropped."""
    led = RunLedger(tmp_path / "history.jsonl")
    for k in range(8):
        led.append(make_record(100.0 + k))
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "tune.py"),
         "--cache-dir", str(tmp_path), "--compact-history", "2"],
        capture_output=True, text=True, env=env, timeout=120)
    assert proc.returncode == 0, proc.stderr
    assert "dropped 6 of 8" in proc.stdout   # best (run 7) is in the last 2
    assert [r.run for r in RunLedger(tmp_path / "history.jsonl")
            .series("dgemm", "fp")] == [6, 7]
    # without --session and without --compact-history: usage error
    bad = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "tune.py"),
         "--cache-dir", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=120)
    assert bad.returncode == 2
    assert "--session is required" in bad.stderr


def test_record_roundtrip_is_exact(tmp_path):
    led = RunLedger(tmp_path / "history.jsonl")
    rec = led.append(make_record(123.456, strategy="exhaustive",
                                 settings_key="abc", session="nightly",
                                 timestamp=1700000000.25))
    got = RunLedger(tmp_path / "history.jsonl").series("dgemm", "fp")[0]
    assert got == rec                                   # floats bit-exact
    assert ci_mean(got.state, 0.99) == ci_mean(rec.state, 0.99)


def test_torn_trailing_line_tolerated(tmp_path):
    path = tmp_path / "history.jsonl"
    led = RunLedger(path)
    led.append(make_record(100.0))
    with open(path, "a") as f:
        f.write('{"ledger_version": 1, "benchmark": "dg')   # killed mid-write
    assert len(RunLedger(path)) == 1


def test_ledger_and_cache_files_skip_each_other(tmp_path):
    """A ledger next to session caches must not confuse cache readers,
    and vice versa — the two record schemas are mutually invisible."""
    cache = TrialCache(tmp_path / "trials.jsonl", fingerprint="fp")
    st = welford.from_samples([1.0, 2.0, 3.0])
    inv = InvocationResult(mean=float(st.mean), count=int(st.count),
                           elapsed_s=0.1, stop_reason="x", pruned=False,
                           m2=float(st.m2))
    cache.put("b", {"x": 1}, EvalResult(
        score=2.0, best_invocation=2.0, invocations=(inv,), total_samples=3,
        total_time_s=0.1, measured_time_s=0.1, pruned=False,
        stop_reason="x"))
    led = RunLedger(tmp_path / "history.jsonl")
    led.append(make_record(100.0))
    assert list(iter_runs(tmp_path / "trials.jsonl")) == []
    assert list(iter_trials(tmp_path / "history.jsonl")) == []
    # TrialCache load counts the foreign schema as stale, not a crash
    assert len(TrialCache(tmp_path / "history.jsonl", fingerprint="fp")) == 0


def test_record_from_result_pools_incumbent_moments():
    result = Tuner(grid(x=tuple(range(8))), SETTINGS).tune(
        quadratic_benchmark)
    rec = record_from_result("bench", "fp", result, settings_key="sk",
                             session="s1")
    assert rec.config == result.best_config
    assert rec.score == result.best_score
    assert rec.mean == pytest.approx(result.best_score)
    assert rec.n_trials == len(result.trials)
    assert rec.strategy == "exhaustive"
    assert rec.settings_key == "sk"
    assert rec.timestamp is None          # core never reads a clock
    winner = next(t for t in result.trials
                  if t.config == result.best_config)
    assert rec.count == sum(i.count for i in winner.result.invocations)


def test_tuning_session_auto_records_runs(tmp_path):
    """Two sessions on one fingerprint -> two ledger runs, resumed run
    included (acceptance criterion part 1)."""
    def session():
        return TuningSession("s", Tuner(grid(x=tuple(range(8))), SETTINGS),
                             quadratic_benchmark, cache_dir=tmp_path,
                             fingerprint="fp", benchmark_name="bench")

    session().run(timestamp=100.0)
    session().run(timestamp=200.0)        # fully cache-served rerun
    led = RunLedger(tmp_path / "history.jsonl")
    runs = led.series("bench", "fp")
    assert [r.run for r in runs] == [0, 1]
    assert [r.timestamp for r in runs] == [100.0, 200.0]
    assert all(r.session == "s" and r.config == {"x": 5} for r in runs)


def test_tuning_session_ledger_opt_out(tmp_path):
    TuningSession("s", Tuner(grid(x=(1, 2)), SETTINGS), quadratic_benchmark,
                  cache_dir=tmp_path, fingerprint="fp",
                  ledger=None).run()
    assert not (tmp_path / "history.jsonl").exists()


def test_append_sees_other_writers_on_disk(tmp_path):
    """Two ledger handles on one file (e.g. two processes) must not hand
    out the same run index from stale in-memory snapshots."""
    path = tmp_path / "history.jsonl"
    a, b = RunLedger(path), RunLedger(path)      # both snapshot empty
    assert a.append(make_record(100.0)).run == 0
    assert b.append(make_record(101.0)).run == 1   # disk re-read, not 0
    assert a.append(make_record(102.0)).run == 2
    assert [r.run for r in RunLedger(path).series("dgemm", "fp")] == [0, 1, 2]


def test_backfill_respects_direction(tmp_path):
    """A minimize-direction archive (e.g. wall-time scores) must backfill
    its *lowest*-scoring trial as the incumbent, stamped minimize."""
    cache = TrialCache(tmp_path / "s.jsonl", fingerprint="fp")
    settings = EvaluationSettings(max_invocations=2, max_iterations=10,
                                  direction=Direction.MINIMIZE,
                                  use_ci_convergence=True)
    Tuner(grid(x=tuple(range(4))), settings).tune(
        lambda cfg: (lambda: (lambda: 10.0 + cfg["x"])),
        cache=cache.bound("lat"))
    led = RunLedger(tmp_path / "h.jsonl")
    (rec,) = led.backfill(cache, direction=Direction.MINIMIZE)
    assert rec.config == {"x": 0}
    assert rec.score == 10.0
    assert rec.direction == "minimize"


def test_backfill_from_cache_is_idempotent(tmp_path):
    cache = TrialCache(tmp_path / "s.jsonl", fingerprint="fp")
    Tuner(grid(x=tuple(range(8))), SETTINGS).tune(
        quadratic_benchmark, cache=cache.bound("bench"))
    led = RunLedger(tmp_path / "history.jsonl")
    added = led.backfill(cache)
    assert [r.key for r in added] == [("bench", "fp")]
    assert added[0].config == {"x": 5}
    assert added[0].score == 100.0
    assert led.backfill(cache) == []              # second backfill: no-op
    assert led.backfill(tmp_path / "s.jsonl") == []   # path form, same data
    assert len(led) == 1


# ---------------------------------------------------------------------------
# Regression statistics
# ---------------------------------------------------------------------------


def test_welch_interval_known_value():
    # n=10, mean=100, s^2=4  vs  n=12, mean=103, s^2=9
    a = WelfordState(count=10.0, mean=100.0, m2=4.0 * 9)
    b = WelfordState(count=12.0, mean=103.0, m2=9.0 * 11)
    iv = welch_interval(a, b, confidence=0.99)
    assert iv.mean == pytest.approx(3.0)
    # Welch df ~= 19.2, t_.995 ~= 2.858, half-width ~= 3.065
    assert iv.lo == pytest.approx(3.0 - 3.065, abs=0.01)
    assert iv.hi == pytest.approx(3.0 + 3.065, abs=0.01)


def test_welch_interval_degenerate_inputs():
    tight = WelfordState(count=10.0, mean=5.0, m2=0.0)
    tiny = WelfordState(count=1.0, mean=4.0, m2=0.0)
    assert welch_interval(tight, tiny).lo == -float("inf")
    iv = welch_interval(tight, WelfordState(count=10.0, mean=4.0, m2=0.0))
    assert (iv.lo, iv.hi) == (-1.0, -1.0)         # zero variance: exact delta


def test_compare_runs_verdicts():
    base = make_record(100.0)
    assert compare_runs(base, make_record(90.0, run=1)).verdict == "regressed"
    assert compare_runs(base, make_record(110.0, run=1)).verdict == "improved"
    assert compare_runs(base, make_record(100.1, run=1)).verdict == "flat"
    # significant but tiny drift: suppressed by the 2% effect floor
    narrow = make_record(99.0, offsets=(0.01,) * 8, run=1)
    base_n = make_record(100.0, offsets=(0.01,) * 8)
    assert compare_runs(base_n, narrow).verdict == "flat"
    assert compare_runs(base_n, narrow, min_effect=0.001).verdict == \
        "regressed"


def test_compare_runs_direction_aware():
    base = make_record(100.0, direction=Direction.MINIMIZE.value)
    worse = make_record(110.0, run=1, direction=Direction.MINIMIZE.value)
    assert compare_runs(base, worse).verdict == "regressed"
    assert compare_runs(base, worse,
                        direction=Direction.MAXIMIZE).verdict == "improved"


def _tiny_record(score, run=0):
    """Two 2-sample invocations: 4 pooled samples (< the Welch floor of
    5) but two invocation means for the bootstrap to resample."""
    states = [welford.from_samples([score - o, score + o])
              for o in (0.4, 0.6)]
    pooled = welford.tree_merge(states)
    return RunRecord(benchmark="dgemm", fingerprint="fp", run=run,
                     config={"n": 512}, score=score,
                     count=float(pooled.count), mean=float(pooled.mean),
                     m2=float(pooled.m2),
                     invocation_means=tuple(float(s.mean) for s in states))


def test_compare_runs_bootstrap_fallback_low_n():
    """Runs pooling fewer than min_count samples route through the
    reservoir bootstrap over the stored invocation means."""
    cmp = compare_runs(_tiny_record(100.0), _tiny_record(80.0, run=1))
    assert cmp.method == "bootstrap"
    assert cmp.verdict == "regressed"
    flat = compare_runs(_tiny_record(100.0), _tiny_record(100.0, run=1))
    assert flat.method == "bootstrap"
    assert flat.verdict == "flat"
    # without stored invocation means there is nothing to resample: welch
    bare = RunRecord(benchmark="d", fingerprint="fp", run=0, config={},
                     score=100.0, count=3.0, mean=100.0, m2=0.5)
    assert compare_runs(bare, bare).method == "welch"


def test_detect_regressions_baseline_is_best_historical(tmp_path):
    """A slow decay can't hide: run N gates against the series' high-water
    mark, not against run N-1."""
    led = RunLedger(tmp_path / "h.jsonl")
    for score in (100.0, 99.0, 98.0, 97.0):       # each step < 2%
        led.append(make_record(score))
    report = detect_regressions(led)
    (series,) = report.series
    assert series.comparison.baseline.run == 0    # not run 2
    assert series.verdict == "regressed"          # 3% vs best, confirmed
    assert not report.ok


def test_detect_regressions_single_run_is_baseline(tmp_path):
    led = RunLedger(tmp_path / "h.jsonl")
    led.append(make_record(100.0))
    report = detect_regressions(led)
    assert report.series[0].verdict == "baseline"
    assert report.ok
    assert "baseline" in report.render_text()


def test_detect_regressions_filters(tmp_path):
    led = RunLedger(tmp_path / "h.jsonl")
    led.append(make_record(100.0))
    led.append(make_record(50.0, benchmark="triad"))
    led.append(make_record(40.0, benchmark="triad", run=1))
    report = detect_regressions(led, benchmark="dgemm")
    assert [s.benchmark for s in report.series] == ["dgemm"]
    assert detect_regressions(led, fingerprint="other").series == ()


# ---------------------------------------------------------------------------
# Rendering: sparklines, trend text, HTML golden
# ---------------------------------------------------------------------------


def test_ascii_sparkline():
    assert ascii_sparkline([]) == ""
    assert ascii_sparkline([5.0, 5.0, 5.0]) == "▄▄▄"
    spark = ascii_sparkline([0, 1, 2, 3, 4, 5, 6, 7])
    assert spark == "▁▂▃▄▅▆▇█"
    assert ascii_sparkline([1.0, 0.0]) == "█▁"


def test_render_trend_text():
    runs = [make_record(100.0, run=0, strategy="exhaustive", session="s"),
            make_record(90.0, run=1)]
    text = render_trend_text(runs)
    assert "2 run(s)" in text
    assert "via exhaustive" in text and "[s]" in text
    assert render_trend_text([]) == "(no history yet)"


def _make_eval_result(score, spreads=(1.0, 2.0)):
    invs, samples = [], 0
    for off in spreads:
        st = welford.from_samples([score - off, score + off])
        samples += int(st.count)
        invs.append(InvocationResult(mean=float(st.mean), count=int(st.count),
                                     elapsed_s=0.125, pruned=False,
                                     stop_reason="max_count(2)",
                                     m2=float(st.m2)))
    return EvalResult(score=score, best_invocation=score,
                      invocations=tuple(invs), total_samples=samples,
                      total_time_s=0.25, measured_time_s=0.25,
                      pruned=False, stop_reason="max_count(2)")


def _dashboard_inputs(tmp_path):
    from repro.core import build_reports
    from repro.core.cache import CachedTrial
    trials = [
        CachedTrial("dgemm", "fpA", {"n": 512, "m": 512, "k": 128},
                    _make_eval_result(120.0)),
        CachedTrial("triad", "fpA", {"n_bytes": 1 << 22},
                    _make_eval_result(40.0)),
        CachedTrial("triad", "fpA", {"n_bytes": 1 << 28},
                    _make_eval_result(10.0)),
    ]
    reports, skipped = build_reports(trials)
    led = RunLedger(tmp_path / "h.jsonl")
    led.append(make_record(118.0, fingerprint="fpA", strategy="exhaustive",
                           session="nightly", timestamp=1700000000.0))
    led.append(make_record(120.0, fingerprint="fpA", strategy="exhaustive",
                           session="nightly", timestamp=1700086400.0))
    led.append(make_record(112.0, fingerprint="fpA", strategy="random",
                           session="nightly", timestamp=1700172800.0))
    return reports, skipped, led


def test_html_dashboard_matches_golden(tmp_path, golden):
    reports, skipped, led = _dashboard_inputs(tmp_path)
    regression = detect_regressions(led)
    html = render_html(reports, skipped, ledger=led, regression=regression,
                       subtitle="golden fixture")
    # structural sanity before byte-compare
    for needle in ("<!DOCTYPE html>", "<style>", "<script>",
                   "Regression verdicts", "verdict-regressed",
                   "Roofline — <code>fpA</code>",
                   "Trend — dgemm @ <code>fpA</code>",
                   "<svg", "trend-band", "roof-curve",
                   "2023-11-14 22:13 UTC"):
        assert needle in html, needle
    assert "http://" not in html and "https://" not in html  # self-contained
    golden("dashboard.html", html)


def test_render_html_empty_inputs():
    html = render_html()
    assert "Nothing to render" in html
    assert "<!DOCTYPE html>" in html


def test_render_html_single_run_series(tmp_path):
    """One-point trend series must not divide by zero in the SVG scaler."""
    led = RunLedger(tmp_path / "h.jsonl")
    led.append(make_record(100.0))
    html = render_html(ledger=led, regression=detect_regressions(led))
    assert "verdict-baseline" in html and "<svg" in html


# ---------------------------------------------------------------------------
# CLIs: perf_gate end-to-end acceptance + report --html
# ---------------------------------------------------------------------------


def _run_cli(script, *argv):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / script), *map(str, argv)],
        capture_output=True, text=True, env=env, timeout=120)


@pytest.mark.slow
def test_perf_gate_end_to_end(tmp_path):
    """The acceptance flow: two synthetic sessions -> flat gate passes;
    an injected slowdown -> gate exits non-zero with a CI-backed verdict."""
    def run_session(name, benchmark):
        TuningSession(name, Tuner(grid(x=tuple(range(8))), SETTINGS),
                      benchmark, cache_dir=tmp_path, fingerprint="fp",
                      benchmark_name="bench").run()

    ledger_path = tmp_path / "history.jsonl"
    run_session("s1", quadratic_benchmark)
    run_session("s2", quadratic_benchmark)        # flat rerun
    assert len(RunLedger(ledger_path).series("bench", "fp")) == 2
    proc = _run_cli("perf_gate.py", ledger_path)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "flat" in proc.stdout

    run_session("s3", slow_quadratic_benchmark)   # injected 10% slowdown
    proc = _run_cli("perf_gate.py", ledger_path)
    assert proc.returncode == 1
    assert "REGRESSED" in proc.stdout
    assert "CI [" in proc.stdout                  # verdict is CI-backed
    # dry-run reports the same verdict without failing the build
    proc = _run_cli("perf_gate.py", ledger_path, "--dry-run")
    assert proc.returncode == 0
    assert "REGRESSED" in proc.stdout


def test_perf_gate_missing_ledger(tmp_path):
    assert _run_cli("perf_gate.py", tmp_path / "no.jsonl").returncode == 2
    proc = _run_cli("perf_gate.py", tmp_path / "no.jsonl", "--dry-run")
    assert proc.returncode == 0


@pytest.mark.slow
def test_roofline_report_html_cli(tmp_path):
    reports, _, led = _dashboard_inputs(tmp_path)
    cache = tmp_path / "nightly.jsonl"
    from repro.core.cache import TrialCache as TC
    for t in [("dgemm", {"n": 512, "m": 512, "k": 128}, 120.0),
              ("triad", {"n_bytes": 1 << 22}, 40.0),
              ("triad", {"n_bytes": 1 << 28}, 10.0)]:
        TC(cache, fingerprint="fpA").put(t[0], t[1], _make_eval_result(t[2]))
    out = tmp_path / "dash.html"
    proc = _run_cli("roofline_report.py", cache, "--html", out,
                    "--history", tmp_path / "h.jsonl")
    assert proc.returncode == 0, proc.stderr
    html = out.read_text()
    assert "Regression verdicts" in html
    assert "Trend — dgemm" in html
    # missing ledger is a usage error
    proc = _run_cli("roofline_report.py", cache, "--html", out,
                    "--history", tmp_path / "missing.jsonl")
    assert proc.returncode == 2


@pytest.mark.slow
def test_roofline_report_ledger_only_still_writes_requested_files(tmp_path):
    """With no roofline-complete fingerprint but --html/--history given,
    every explicitly requested artifact (--out, --csv) is still written —
    a 0 exit must never leave a requested file missing."""
    from repro.core.cache import TrialCache as TC
    cache = tmp_path / "synthetic-only.jsonl"
    TC(cache, fingerprint="fpA").put("synthetic", {"x": 5},
                                     _make_eval_result(100.0))
    led = RunLedger(tmp_path / "h.jsonl")
    led.append(make_record(100.0, benchmark="synthetic", fingerprint="fpA"))
    out_md, out_csv = tmp_path / "r.md", tmp_path / "r.csv"
    out_html = tmp_path / "dash.html"
    proc = _run_cli("roofline_report.py", cache, "--out", out_md,
                    "--csv", out_csv, "--html", out_html,
                    "--history", tmp_path / "h.jsonl")
    assert proc.returncode == 0, proc.stderr
    assert "no reportable fingerprint" in proc.stderr
    assert out_md.exists() and out_csv.exists()
    assert "Trend — synthetic" in out_html.read_text()
    # without the ledger escape hatch the same cache still refuses
    proc = _run_cli("roofline_report.py", cache, "--out", out_md)
    assert proc.returncode == 1

"""Confidence intervals: quantile accuracy, coverage, robust variants."""

import math

import numpy as np
import pytest

try:            # only the property-based test needs hypothesis
    import hypothesis
    import hypothesis.strategies as st
except ImportError:             # pragma: no cover - env-dependent
    hypothesis = st = None

import repro.core.welford as W
from repro.core import confidence as C


def test_normal_quantile_known_values():
    assert abs(C.normal_quantile(0.975) - 1.959964) < 1e-5
    assert abs(C.normal_quantile(0.995) - 2.575829) < 1e-5
    assert abs(C.normal_quantile(0.5)) < 1e-9
    assert abs(C.normal_quantile(0.025) + 1.959964) < 1e-5


def test_t_quantile_known_values():
    # scipy.stats.t.ppf references
    assert abs(C.t_quantile(0.975, 10) - 2.2281389) < 1e-5
    assert abs(C.t_quantile(0.995, 5) - 4.0321430) < 1e-5
    assert abs(C.t_quantile(0.975, 1) - 12.7062047) < 1e-4
    assert abs(C.t_quantile(0.975, 1e7) - 1.959964) < 1e-4


@pytest.mark.skipif(hypothesis is None, reason="needs hypothesis")
def test_t_quantile_inverts_cdf():
    @hypothesis.given(st.floats(0.01, 0.99), st.integers(2, 200))
    @hypothesis.settings(deadline=None, max_examples=100)
    def prop(p, df):
        t = C.t_quantile(p, df)
        assert abs(C.t_cdf(t, df) - p) < 1e-7

    prop()


def test_ci_mean_coverage(rng):
    """~99% of 99% CIs should contain the true mean (normal data)."""
    hits = 0
    trials = 400
    for _ in range(trials):
        xs = rng.normal(10.0, 2.0, size=40)
        interval = C.ci_mean(W.from_samples(xs), confidence=0.99)
        hits += interval.lo <= 10.0 <= interval.hi
    assert hits / trials >= 0.95  # loose lower bound, 99% nominal


def test_ci_margin_shrinks_with_n(rng):
    xs = rng.normal(5.0, 1.0, size=1000)
    m_small = C.ci_mean(W.from_samples(xs[:10])).margin
    m_large = C.ci_mean(W.from_samples(xs)).margin
    assert m_large < m_small


def test_interval_relative_margin():
    i = C.Interval(lo=9.0, hi=11.0, mean=10.0)
    assert abs(i.margin - 1.0) < 1e-12
    assert abs(i.relative_margin - 0.1) < 1e-12


def test_reservoir_bootstrap_ci(rng):
    boot = C.ReservoirBootstrap(capacity=128, resamples=200, seed=1)
    for x in rng.normal(7.0, 1.0, size=5000):
        boot.update(float(x))
    interval = boot.ci_mean(0.99)
    assert boot.count == 5000
    assert interval.lo <= 7.0 <= interval.hi
    assert interval.hi - interval.lo < 1.0


def test_median_of_means_robust_to_outliers(rng):
    xs = list(rng.normal(3.0, 0.1, size=64)) + [1e6]
    assert abs(C.median_of_means(xs, n_blocks=8) - 3.0) < 1.0
    assert abs(np.mean(xs) - 3.0) > 100  # plain mean is destroyed


def test_sign_test_median_ci(rng):
    xs = rng.normal(2.0, 1.0, size=100)
    interval = C.sign_test_median_ci(xs, confidence=0.99)
    assert interval.lo <= 2.0 <= interval.hi
    assert interval.lo > -math.inf


# ---------------------------------------------------------------------------
# Under-exercised paths: reservoir past capacity, robust-stat edge cases
# ---------------------------------------------------------------------------


def test_reservoir_bootstrap_past_capacity_stays_bounded(rng):
    """Once the stream exceeds capacity the reservoir must stay a bounded,
    uniform subsample — count keeps growing, the buffer does not, and the
    CI neither collapses nor drifts off the true mean."""
    boot = C.ReservoirBootstrap(capacity=32, resamples=200, seed=3)
    for x in rng.normal(5.0, 0.5, size=10_000):
        boot.update(float(x))
    assert boot.count == 10_000
    assert len(boot._buf) == 32
    interval = boot.ci_mean(0.99)
    assert interval.lo <= 5.0 <= interval.hi
    # a 32-sample reservoir cannot pretend to 10k-sample precision
    assert interval.hi - interval.lo > 0.01


def test_reservoir_bootstrap_small_stream_degenerate():
    boot = C.ReservoirBootstrap(capacity=8, resamples=50, seed=0)
    assert boot.ci_mean().lo == -math.inf          # empty: infinite CI
    boot.update(7.0)
    interval = boot.ci_mean()                      # one sample: still infinite
    assert interval.lo == -math.inf and interval.mean == 7.0
    boot.update(9.0)
    assert boot.ci_mean().lo > -math.inf           # two samples: finite


def test_median_of_means_edge_cases():
    with pytest.raises(ValueError):
        C.median_of_means([])
    assert C.median_of_means([4.0]) == 4.0         # one sample, one block
    # more blocks than samples: k clamps to n, result is the median
    assert C.median_of_means([1.0, 2.0, 3.0], n_blocks=100) == 2.0
    assert C.median_of_means([5.0] * 16) == 5.0    # all-equal: exact


def test_sign_test_median_ci_small_n_is_uninformative():
    """Below n=8 no pair of order statistics covers 99%: the CI must
    degrade to infinite honestly, never to a false finite interval."""
    for n in (2, 3, 4):
        interval = C.sign_test_median_ci([float(i) for i in range(n)],
                                         confidence=0.99)
        assert interval.lo == -math.inf and interval.hi == math.inf
    single = C.sign_test_median_ci([3.0])
    assert single.mean == 3.0 and single.lo == -math.inf
    assert C.sign_test_median_ci([]).mean == 0.0


def test_sign_test_median_ci_all_equal_samples():
    interval = C.sign_test_median_ci([2.5] * 40, confidence=0.99)
    assert interval.lo == interval.hi == interval.mean == 2.5

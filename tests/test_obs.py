"""Observability subsystem: span tracing, metrics, exports, device timing.

Covers the trace recorder's nesting/threading semantics, the JSONL and
Chrome-trace (Perfetto) exports, the per-session metrics/exec-cache delta
discipline, the GitHub Actions annotations emitted by the perf gate, and
the dashboard drill-down rendering (golden-pinned).
"""

import dataclasses
import io
import json
import os
import pathlib
import subprocess
import sys
import threading

import pytest

from repro.core import (EvaluationSettings, ThreadPoolBackend, Tuner,
                        TuningSession, grid, welford)
from repro.core.exec_cache import ExecutableCache, default_cache
from repro.core.profiling import PhaseProfiler, phase, record_phase
from repro.history import RunLedger, detect_regressions, render_html
from repro.history.ledger import RunRecord
from repro.obs import (MetricsRegistry, TraceRecorder, load_events, metrics,
                       recorder, to_chrome_trace, trial_summaries,
                       validate_chrome_trace)

REPO = pathlib.Path(__file__).resolve().parent.parent

SETTINGS = EvaluationSettings(max_invocations=2, max_iterations=10,
                              use_ci_convergence=True, use_inner_prune=True,
                              use_outer_prune=True)


def quadratic_benchmark(cfg):
    mu = 100.0 - (cfg["x"] - 5) ** 2
    return lambda: (lambda: mu)


# ---------------------------------------------------------------------------
# TraceRecorder mechanics
# ---------------------------------------------------------------------------


def test_recorder_nesting_and_jsonl_roundtrip(tmp_path):
    path = tmp_path / "t.trace.jsonl"
    seen = {}
    with TraceRecorder(path, session="s") as rec:
        assert recorder() is rec
        with rec.span("outer", cat="session", context=True) as outer:
            with rec.span("inner") as inner:
                rec.instant("mark", k=1)

            # a thread with an empty local span stack parents to the
            # context span — this is what attributes worker-thread trials
            # to the session
            def child():
                with rec.span("child") as c:
                    seen["parent"] = c.parent

            t = threading.Thread(target=child)
            t.start()
            t.join()
    assert recorder() is None
    assert seen["parent"] == outer.id

    events = load_events(path)
    assert events == rec.events()          # the file is the event stream
    spans = {e["id"]: e for e in events if e["type"] == "span"}
    assert spans[inner.id]["parent"] == outer.id
    assert spans[outer.id]["parent"] is None
    mark = next(e for e in events if e["type"] == "instant")
    assert mark["parent"] == inner.id and mark["attrs"] == {"k": 1}
    header = events[0]
    assert header["type"] == "meta" and header["session"] == "s"


def test_recorder_is_exclusive_per_process(tmp_path):
    with TraceRecorder(tmp_path / "a.jsonl"):
        other = TraceRecorder(tmp_path / "b.jsonl")
        with pytest.raises(RuntimeError):
            other.__enter__()
        other.close()
    # uninstalled cleanly: a fresh recorder installs fine
    with TraceRecorder(tmp_path / "c.jsonl") as rec:
        assert recorder() is rec
    assert recorder() is None


def test_phase_feeds_both_profiler_and_trace():
    prof = PhaseProfiler()
    with TraceRecorder() as rec, prof:
        with phase("work"):
            pass
        record_phase("sync", 0.25)
    buckets = prof.to_json()
    assert buckets["work"]["count"] == 1
    assert buckets["sync"]["seconds"] == pytest.approx(0.25)
    spans = [e for e in rec.events() if e["type"] == "span"]
    by_name = {s["name"]: s for s in spans}
    assert by_name["work"]["cat"] == "phase"
    assert by_name["sync"]["dur"] == pytest.approx(0.25)


def test_metrics_registry_snapshot_and_delta():
    reg = MetricsRegistry()
    reg.inc("a")
    reg.inc("a", 2)
    reg.gauge("g", 1.5)
    snap = reg.snapshot()
    assert snap["counters"]["a"] == 3 and snap["gauges"]["g"] == 1.5
    reg.inc("b", 5)
    delta = reg.delta(snap)
    assert delta["counters"] == {"b": 5}          # only movement reported
    assert metrics() is metrics()                 # process-global accessor


# ---------------------------------------------------------------------------
# Traced tuning sessions: concurrency correctness + exports
# ---------------------------------------------------------------------------


def test_thread_backend_trace_attribution(tmp_path):
    """The concurrency acceptance check: a 4-worker threaded session's
    trace covers every persisted trial exactly once, every trial span
    hangs off the single session span, and the Chrome-trace export is
    structurally valid (balanced, per-tid monotone)."""
    session = TuningSession(
        "traced", Tuner(grid(x=tuple(range(12))), SETTINGS),
        quadratic_benchmark, cache_dir=tmp_path, fingerprint="fp",
        benchmark_name="bench", trace=True)
    reg = metrics()
    base = reg.snapshot()
    result = session.run(backend=ThreadPoolBackend(4))

    assert result.trace_path == str(tmp_path / "traced.trace.jsonl")
    events = load_events(result.trace_path)
    sessions = [e for e in events
                if e.get("type") == "span" and e.get("cat") == "session"]
    trials = [e for e in events
              if e.get("type") == "span" and e.get("cat") == "trial"]
    assert len(sessions) == 1
    assert len(trials) == len(result.trials) == 12
    assert sorted(t["attrs"]["index"] for t in trials) == list(range(12))
    assert all(t["parent"] == sessions[0]["id"] for t in trials)
    assert {t["attrs"]["worker"] for t in trials} <= set(range(4))
    # a trial span carries the tid of the worker thread that ran it, and
    # its nested invocation spans land on the same tid
    by_id = {e["id"]: e for e in events if e.get("type") == "span"}
    for inv in (e for e in events if e.get("cat") == "invocation"):
        assert by_id[inv["parent"]]["cat"] == "trial"
        assert inv["tid"] == by_id[inv["parent"]]["tid"]

    doc = to_chrome_trace(events)
    assert validate_chrome_trace(doc) == []
    assert any(e["ph"] == "M" for e in doc["traceEvents"])

    rows = trial_summaries(events)
    assert [r["index"] for r in rows] == list(range(12))
    assert all(r["invocations"] >= 1 for r in rows)

    # per-session result metrics: this session's activity, as a delta
    counters = result.metrics["counters"]
    assert counters["trials.started"] == 12
    assert counters["trials.completed"] == 12
    assert counters["cache.appends"] == 12
    # the ledger append happens in TuningSession.run, after tune()'s
    # delta closes — it lands in the global registry instead
    assert reg.delta(base)["counters"]["ledger.appends"] == 1


def test_cached_rerun_traces_cache_hits(tmp_path):
    def make(trace):
        return TuningSession(
            "hits", Tuner(grid(x=tuple(range(6))), SETTINGS),
            quadratic_benchmark, cache_dir=tmp_path, fingerprint="fp",
            benchmark_name="bench", trace=trace)

    make(False).run()
    result = make(tmp_path / "rerun.trace.jsonl").run()
    assert result.n_cached == 6
    assert result.metrics["counters"]["trials.cached"] == 6
    assert "trials.completed" not in result.metrics["counters"]

    events = load_events(tmp_path / "rerun.trace.jsonl")
    hits = [e for e in events
            if e.get("type") == "instant" and e["name"] == "cache_hit"]
    assert len(hits) == 6
    rows = trial_summaries(events)
    assert len(rows) == 6 and all(r["cached"] for r in rows)
    assert all(r["score"] is not None for r in rows)


def test_exec_cache_stats_report_per_session_deltas(tmp_path, monkeypatch):
    """Two sessions sharing the process-global executable cache must each
    report their own activity: the second session re-serves session 1's
    executables, so its delta shows hits and zero misses — cumulative
    reporting would repeat session 1's misses."""
    monkeypatch.setattr(
        ExecutableCache, "_lower_and_compile",
        staticmethod(lambda fn, args, static=None: lambda *a: None))
    np = pytest.importorskip("numpy")
    arrays = {x: np.zeros((x + 1,), dtype=np.float32) for x in range(4)}

    def bench(cfg):
        def factory():
            default_cache().compile(_kernel_stub, (arrays[cfg["x"]],),
                                    static={"x": cfg["x"]})
            return lambda: float(cfg["x"])
        return factory

    def run(name, benchmark_name):
        return TuningSession(
            name, Tuner(grid(x=tuple(range(4))), SETTINGS), bench,
            cache_dir=tmp_path, fingerprint="fp",
            benchmark_name=benchmark_name).run()

    r1 = run("s1", "b1")
    r2 = run("s2", "b2")
    assert r1.exec_cache["misses"] == 4
    assert r2.exec_cache["misses"] == 0 and r2.exec_cache["compiles"] == 0
    assert r2.exec_cache["hits"] >= 4


def _kernel_stub(x):
    return x


# ---------------------------------------------------------------------------
# Campaign tracing
# ---------------------------------------------------------------------------


def test_campaign_trace_spans(tmp_path):
    from repro.sweep import SweepCampaign

    def family(shape):
        def bench(cfg):
            mu = 100.0 - (cfg["bm"] - shape["m"]) ** 2
            return lambda: (lambda: mu)
        return bench

    camp = SweepCampaign(grid(bm=(1, 2)), grid(m=(1, 2)), family, SETTINGS,
                         name="camp", cache_dir=tmp_path, seed=0)
    result = camp.run(trace=True)
    assert result.trace_path == str(tmp_path / "camp.trace.jsonl")

    events = load_events(result.trace_path)
    spans = {e["id"]: e for e in events if e["type"] == "span"}
    campaigns = [s for s in spans.values() if s["cat"] == "session"
                 and s["name"] == "campaign"]
    shapes = [s for s in spans.values() if s["cat"] == "shape"]
    tunes = [s for s in spans.values() if s["name"] == "tune"]
    trials = [s for s in spans.values() if s["cat"] == "trial"]
    assert len(campaigns) == 1 and len(shapes) == 2 and len(tunes) == 2
    assert all(s["parent"] == campaigns[0]["id"] for s in shapes)
    assert {t["parent"] for t in tunes} == {s["id"] for s in shapes}
    assert trials and all(spans[t["parent"]]["name"] == "tune"
                          for t in trials)
    assert campaigns[0]["attrs"]["total_trials"] == len(trials)
    assert validate_chrome_trace(to_chrome_trace(events)) == []


# ---------------------------------------------------------------------------
# Device timing: graceful degradation off-GPU
# ---------------------------------------------------------------------------


def test_device_timing_degrades_gracefully():
    from repro.obs import device_timing_available, profile_sample
    from repro.obs.device_timing import DeviceTiming
    assert isinstance(device_timing_available(), bool)
    out = profile_sample(lambda: sum(range(100)))
    assert out is None or isinstance(out, DeviceTiming)


def test_evaluator_emits_device_timing_instant(tmp_path):
    settings = dataclasses.replace(SETTINGS, device_timing=True)
    with TraceRecorder(tmp_path / "d.jsonl") as rec:
        Tuner(grid(x=(5,)), settings).tune(quadratic_benchmark,
                                           validate="off")
    names = {e["name"] for e in rec.events() if e["type"] == "instant"}
    # either a real on-device reading or the explicit unavailable marker —
    # silence would mean the opt-in was dropped on the floor
    assert names & {"device_timing", "device_timing_unavailable"}


def test_device_timing_skipped_without_recorder():
    # the profiled invocation is a trace attribute: with no recorder the
    # evaluator must not pay for it (and must not crash)
    settings = dataclasses.replace(SETTINGS, device_timing=True)
    result = Tuner(grid(x=(5,)), settings).tune(quadratic_benchmark,
                                                validate="off")
    assert result.best_score == pytest.approx(100.0)


# ---------------------------------------------------------------------------
# perf_gate: GitHub Actions annotations
# ---------------------------------------------------------------------------


def make_record(score, offsets=(0.5, 0.7, 0.4, 0.6, 0.5), run=0,
                benchmark="dgemm", fingerprint="fp", **kw):
    states = [welford.from_samples([score - o, score + o, score])
              for o in offsets]
    pooled = welford.tree_merge(states)
    return RunRecord(benchmark=benchmark, fingerprint=fingerprint, run=run,
                     config={"n": 512}, score=score,
                     count=float(pooled.count), mean=float(pooled.mean),
                     m2=float(pooled.m2),
                     invocation_means=tuple(float(s.mean) for s in states),
                     **kw)


def _run_gate(ledger_path, *argv, github=True):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    if github:
        env["GITHUB_ACTIONS"] = "1"
    else:
        env.pop("GITHUB_ACTIONS", None)
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / "perf_gate.py"),
         str(ledger_path), *map(str, argv)],
        capture_output=True, text=True, env=env, timeout=120)


def test_perf_gate_github_annotations(tmp_path):
    """Under GITHUB_ACTIONS=1 a confirmed regression emits an ::error
    workflow command whose file/line point at the candidate's exact
    ledger record; --dry-run downgrades it to ::warning."""
    sys.path.insert(0, str(REPO / "scripts"))
    try:
        import perf_gate
    finally:
        sys.path.pop(0)

    ledger_path = tmp_path / "history.jsonl"
    led = RunLedger(ledger_path)
    # a "%" in the name exercises workflow-command escaping end to end
    led.append(make_record(100.0, benchmark="dg%mm"))
    led.append(make_record(88.0, benchmark="dg%mm"))

    report = detect_regressions(RunLedger(ledger_path))
    assert not report.ok
    buf = io.StringIO()
    assert perf_gate.emit_annotations(report, ledger_path, out=buf) == 1
    expected = buf.getvalue().strip()
    assert expected.startswith("::error file=")
    assert f"file={perf_gate._esc_prop(str(ledger_path))},line=2," in expected
    assert "dg%25mm" in expected                 # % escaped, both segments
    assert "dg%mm" not in expected

    proc = _run_gate(ledger_path)
    assert proc.returncode == 1
    assert expected in proc.stdout.splitlines()

    proc = _run_gate(ledger_path, "--dry-run")
    assert proc.returncode == 0
    warning = "::warning " + expected[len("::error "):]
    assert warning in proc.stdout.splitlines()

    # outside GitHub Actions the same gate emits no workflow commands
    proc = _run_gate(ledger_path, github=False)
    assert proc.returncode == 1 and "::error" not in proc.stdout


def test_perf_gate_annotations_skip_clean_series(tmp_path):
    sys.path.insert(0, str(REPO / "scripts"))
    try:
        import perf_gate
    finally:
        sys.path.pop(0)
    ledger_path = tmp_path / "history.jsonl"
    led = RunLedger(ledger_path)
    led.append(make_record(100.0))
    led.append(make_record(100.0))
    buf = io.StringIO()
    n = perf_gate.emit_annotations(
        detect_regressions(RunLedger(ledger_path)), ledger_path, out=buf)
    assert n == 0 and buf.getvalue() == ""


# ---------------------------------------------------------------------------
# Dashboard drill-down (golden-pinned)
# ---------------------------------------------------------------------------


def test_dashboard_trial_drilldown_golden(golden):
    rows = [
        {"index": 0, "config": {"x": 0}, "score": 75.0, "pruned": False,
         "stop_reason": "converged", "samples": 30, "worker": 0,
         "thread": "w0", "tid": 1, "ts": 0.001, "dur_s": 0.0123,
         "invocations": 2, "phases": {"measure": 0.0101,
                                      "cache_io": 0.0004},
         "improved": True, "cached": False},
        {"index": 1, "config": {"x": 1}, "score": 84.0, "pruned": True,
         "stop_reason": "outer_pruned", "samples": 6, "worker": 1,
         "thread": "w1", "tid": 2, "ts": 0.002, "dur_s": 0.0042,
         "invocations": 1, "phases": {"measure": 0.0031},
         "improved": False, "cached": False},
        {"index": None, "config": {"x": 2}, "score": 91.0, "pruned": False,
         "stop_reason": "converged", "samples": 30, "worker": None,
         "thread": None, "tid": None, "ts": 0.003, "dur_s": 0.0,
         "invocations": 0, "phases": {}, "improved": False, "cached": True},
    ]
    html = render_html(trials=rows, subtitle="golden fixture")
    for needle in ("Trial drill-down", "3 traced trial(s)",
                   "trial-improved", "outer_pruned", "cached",
                   "measure 10.10ms"):
        assert needle in html, needle
    golden("dashboard_trials.html", html)


def test_trial_summaries_row_shape_from_live_trace(tmp_path):
    session = TuningSession(
        "rows", Tuner(grid(x=(3, 5)), SETTINGS), quadratic_benchmark,
        cache_dir=tmp_path, fingerprint="fp", benchmark_name="bench",
        trace=True)
    result = session.run()
    rows = trial_summaries(load_events(result.trace_path))
    assert len(rows) == 2
    for row in rows:
        assert {"index", "config", "score", "pruned", "stop_reason",
                "samples", "worker", "dur_s", "invocations", "phases",
                "improved", "cached"} <= set(row)
    assert any(r["improved"] for r in rows)
    # the best config's row carries the incumbent score
    best = max(rows, key=lambda r: r["score"])
    assert best["score"] == pytest.approx(result.best_score)


# ---------------------------------------------------------------------------
# CLI surface
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_tune_cli_trace_and_live(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "tune.py"),
         "--session", "smoke", "--benchmark", "synthetic",
         "--cache-dir", str(tmp_path), "--trace", "--live"],
        capture_output=True, text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    trace_path = tmp_path / "smoke.trace.jsonl"
    assert str(trace_path) in proc.stdout
    events = load_events(trace_path)
    trials = [e for e in events
              if e.get("type") == "span" and e.get("cat") == "trial"]
    assert len(trials) == 12                     # the synthetic grid
    assert validate_chrome_trace(to_chrome_trace(events)) == []
    assert "[live]" in proc.stderr

"""Data pipeline: determinism, resumability, structure."""

import numpy as np

from repro.data import DataConfig, SyntheticLM


def test_batch_is_pure_function_of_step():
    p1 = SyntheticLM(DataConfig(seed=3, vocab_size=100), batch=4, seq_len=32)
    p2 = SyntheticLM(DataConfig(seed=3, vocab_size=100), batch=4, seq_len=32)
    b1 = p1.batch_at(17)["tokens"]
    b2 = p2.batch_at(17)["tokens"]
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))


def test_different_steps_differ():
    p = SyntheticLM(DataConfig(seed=3, vocab_size=100), batch=4, seq_len=32)
    a = np.asarray(p.batch_at(0)["tokens"])
    b = np.asarray(p.batch_at(1)["tokens"])
    assert (a != b).any()


def test_tokens_in_vocab_range():
    p = SyntheticLM(DataConfig(seed=0, vocab_size=50), batch=8, seq_len=64)
    t = np.asarray(p.batch_at(5)["tokens"])
    assert t.min() >= 0 and t.max() < 50
    assert t.shape == (8, 64)


def test_markov_structure_is_learnable():
    """With structure=1.0 every next token is succ(prev): the bigram is
    deterministic, so an LM can reach ~0 loss — verify the property."""
    cfg = DataConfig(seed=1, vocab_size=64, structure=1.0)
    p = SyntheticLM(cfg, batch=2, seq_len=128)
    t = np.asarray(p.batch_at(0)["tokens"])
    succ = np.asarray(p._succ)
    follows = (t[:, 1:] == succ[t[:, :-1]]).mean()
    assert follows == 1.0


def test_sharded_batch_matches_shape():
    from jax.sharding import PartitionSpec as P
    from repro.data import make_batch_sharded
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    p = SyntheticLM(DataConfig(seed=0, vocab_size=100),
                    batch=4 * mesh.shape["data"], seq_len=16)
    batch = make_batch_sharded(p, 3, mesh, P("data", None))
    assert batch["tokens"].shape == (4 * mesh.shape["data"], 16)
    t = np.asarray(batch["tokens"])
    assert t.min() >= 0 and t.max() < 100

"""Property-based tests (Hypothesis) for the encoding and search-space
layers: encode/decode round-trips, ordinal monotonicity on geometric
ladders, one-hot exclusivity, shape-feature bounds, and ``project``
idempotence.

Hypothesis is a CI dependency, not a runtime one: locally these tests
skip when it is absent; in CI it is pin-installed (``HYPOTHESIS_PIN`` in
``.github/workflows/ci.yml``) so the suite runs there — the CI log must
show them as *passed*, never silently skipped. Strategies are
derandomized: a failure reproduces."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis is a CI-pinned extra; install it to "
                         "run the property suite locally")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import SearchSpace, grid, param  # noqa: E402
from repro.surrogate import SpaceEncoder, is_ordinal  # noqa: E402

settings.register_profile("repro", settings(derandomize=True, deadline=None,
                                            max_examples=60))
settings.load_profile("repro")

SPACE = grid(bm=(16, 32, 64, 128), mode=("row", "col", "tile"),
             unroll=(1, 2, 4))
SHAPES = grid(m=(128, 256, 512, 1024, 2048), dtype=("fp16", "fp32"))


def configs(space):
    """A drawn in-space configuration."""
    return st.fixed_dictionaries({p.name: st.sampled_from(list(p.values))
                                  for p in space.params})


# ------------------------------------------------------------- round-trips

@given(configs(SPACE))
def test_encode_decode_roundtrip(cfg):
    enc = SpaceEncoder(SPACE)
    assert enc.decode(enc.encode(cfg)) == cfg


@given(configs(SPACE))
def test_encode_is_deterministic(cfg):
    enc = SpaceEncoder(SPACE)
    assert np.array_equal(enc.encode(cfg), enc.encode(cfg))


@given(configs(SPACE), configs(SHAPES))
def test_joint_roundtrip_ignores_shape_block(cfg, shape):
    enc = SpaceEncoder(SPACE, shape_space=SHAPES)
    x = enc.encode(cfg, shape=shape)
    assert x.shape == (enc.dim,)
    assert enc.decode(x) == cfg


# ---------------------------------------------------- ordinal monotonicity

@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=3, max_value=8),
       st.integers(min_value=2, max_value=4))
def test_ordinal_monotone_on_geometric_ladders(lo_exp, length, ratio):
    """Encoded coordinate of a geometric ladder is strictly increasing in
    the level index — the surrogate sees tile ladders as ordered axes."""
    ladder = tuple((ratio ** lo_exp) * ratio ** i for i in range(length))
    assert is_ordinal(param("t", ladder))
    space = SearchSpace([param("t", ladder)])
    enc = SpaceEncoder(space)
    coords = [float(enc.encode({"t": v})[0]) for v in ladder]
    assert coords == sorted(coords)
    assert len(set(coords)) == len(coords)
    assert coords[0] == 0.0 and coords[-1] == 1.0


@given(st.sampled_from((128, 192, 256, 384, 512, 768, 1024, 2048, 4096)))
def test_shape_features_bounded_and_monotone(m):
    enc = SpaceEncoder(grid(bm=(16, 32)), shape_space=grid(m=(256, 1024)))
    f = enc.shape_features({"m": m})
    assert f.shape == (enc.dim - enc.config_dim,)
    assert 0.0 <= f[0] <= 1.0
    # monotone: a strictly larger m never maps below a smaller one
    assert f[0] >= enc.shape_features({"m": m // 2})[0]


# ------------------------------------------------------ one-hot exclusivity

@given(configs(SPACE))
def test_categorical_blocks_are_one_hot_exclusive(cfg):
    enc = SpaceEncoder(SPACE)
    x = enc.encode(cfg)
    # the 'mode' parameter is categorical: its block holds exactly one 1
    block = [i for i, name in enumerate(enc.feature_names)
             if name.startswith("mode=")]
    assert len(block) == 3
    assert sorted(x[block]) == [0.0, 0.0, 1.0]
    assert set(np.asarray(x).tolist()) <= {0.0, 1.0} or True  # bounded
    assert np.all(x >= 0.0) and np.all(x <= 1.0)


# ------------------------------------------------------ project idempotence

@given(st.fixed_dictionaries({
    "bm": st.one_of(st.integers(min_value=-10, max_value=300),
                    st.floats(allow_nan=False, allow_infinity=False,
                              min_value=-1e6, max_value=1e6),
                    st.text(max_size=3)),
    "mode": st.one_of(st.sampled_from(["row", "col", "tile", "zig"]),
                      st.integers()),
    "unroll": st.integers(min_value=-8, max_value=64),
}))
def test_project_is_idempotent(cfg):
    """Projecting an arbitrary (possibly out-of-space) config yields an
    in-space config that projects to itself."""
    once = SPACE.project(cfg)
    assert once is not None              # SPACE has no constraints
    for p in SPACE.params:
        assert once[p.name] in p.values
    assert SPACE.project(once) == once


@given(configs(SPACE))
def test_project_fixes_in_space_configs(cfg):
    assert SPACE.project(cfg) == cfg

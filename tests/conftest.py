"""Shared test fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see the host's real (single) device; only launch/dryrun.py forces the
512-device placeholder topology (and tests exercise it via subprocess)."""

import os
import pathlib

import jax
import pytest

jax.config.update("jax_enable_x64", False)

GOLDEN_DIR = pathlib.Path(__file__).resolve().parent / "golden"


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="rewrite tests/golden/* from the current output instead of "
             "comparing (equivalent to REGEN_GOLDEN=1); updated tests "
             "report as skipped so a regeneration run is never mistaken "
             "for a green comparison run")


class GoldenChecker:
    """Byte-compares rendered text against ``tests/golden/<name>``.

    In update mode the golden file is rewritten and the test *skips* —
    docs/history.md documents the workflow. Call it through the
    ``golden`` fixture: ``golden("dashboard.html", html)``.
    """

    def __init__(self, update: bool):
        self.update = update

    def __call__(self, name: str, text: str) -> None:
        golden = GOLDEN_DIR / name
        if self.update:
            golden.parent.mkdir(parents=True, exist_ok=True)
            golden.write_text(text, encoding="utf-8")
            pytest.skip(f"regenerated {golden}")
        assert golden.exists(), \
            f"missing golden file {golden}; run pytest --update-golden"
        assert text == golden.read_text(encoding="utf-8"), \
            f"{name} drifted from golden; pytest --update-golden if intentional"


@pytest.fixture
def golden(request):
    """Golden-file checker honoring ``--update-golden`` (and the legacy
    ``REGEN_GOLDEN=1`` environment switch)."""
    update = (request.config.getoption("--update-golden")
              or bool(os.environ.get("REGEN_GOLDEN")))
    return GoldenChecker(update)


@pytest.fixture(scope="session")
def rng():
    import numpy as np
    return np.random.default_rng(20210416)  # paper-era seed

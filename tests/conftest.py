"""Shared test fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see the host's real (single) device; only launch/dryrun.py forces the
512-device placeholder topology (and tests exercise it via subprocess)."""

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    import numpy as np
    return np.random.default_rng(20210416)  # paper-era seed
